# Root entry points.  Training is pure rust (rust/src/train: the
# chip-in-the-loop HAT loop writes manifest + CPT1 artifacts the engine
# loads directly); python runs at build time only for the AOT/XLA path
# (compile/aot.py) and the legacy jax training sweep (compile/train.py).
# See DESIGN.md §2–3, §train and README.md.

PY ?= python3
OUT ?= artifacts

.PHONY: artifacts train train-smoke train-py train-py-quick verify \
	bench-smoke drift-smoke trace-smoke chaos-smoke lint loom validate help

## AOT-lower the jax graphs to $(OUT)/*.hlo.txt + chip.json (compile.aot)
artifacts:
	cd python && $(PY) -m compile.aot --out ../$(OUT)

## Pure-rust hardware-aware training: noisy chip-in-the-loop forward,
## FFT-domain circulant gradients; writes $(OUT)/models/synth_shapes.json
## + synth_shapes_dpe.cpt for the engine / serving benches
train:
	cargo run --release --example hardware_aware_training -- --out $(OUT)

## CI-sized smoke run: few steps on synthetic data, no artifacts needed;
## asserts the loss decreases and the exported model serves a batch
train-smoke:
	cargo run --release --example hardware_aware_training -- --smoke

## Legacy python (jax) training sweep: manifests, CPT1 bundles, test
## sets, golden vectors and metrics.json (compile.train)
train-py:
	cd python && $(PY) -m compile.train --out ../$(OUT)

## CI-sized python training run (small data / few epochs)
train-py-quick:
	cd python && $(PY) -m compile.train --out ../$(OUT) --quick

## Tier-1 verification (what CI runs)
verify:
	cargo build --release --workspace
	cargo test -q --workspace

## Repo-specific source lint: no unwrap/expect/panic on the request
## path, no std::sync outside the util/sync shim, no allocation in the
## zero-alloc kernels or the tracing record path, bounded obs channels,
## named /metrics listener (escape: `// lint:allow(<rule>): <reason>`)
lint:
	cargo run --release --bin repo_lint

## Model-check the concurrency protocols (engine hot swap, drift
## single-flight gate, FFT plan cache) over every SC interleaving
loom:
	RUSTFLAGS="--cfg loom" cargo test --release -p cirptc --test loom_models

## Static artifact validation: the committed fixture set must split
## exactly into accepted valid artifacts and rejected corrupt ones
validate:
	cargo run --release --bin validate -- \
		--manifest rust/tests/fixtures/verify/valid_model.json \
		--bundle rust/tests/fixtures/verify/valid_model.cpt \
		--chip rust/tests/fixtures/verify/chip.json
	cargo run --release --bin validate -- --expect-invalid \
		--manifest rust/tests/fixtures/verify/corrupt_graph.json \
		--bundle rust/tests/fixtures/verify/valid_model.cpt
	cargo run --release --bin validate -- --expect-invalid \
		--manifest rust/tests/fixtures/verify/corrupt_quant.json \
		--bundle rust/tests/fixtures/verify/valid_model.cpt
	cargo run --release --bin validate -- --expect-invalid \
		--manifest rust/tests/fixtures/verify/valid_model.json \
		--bundle rust/tests/fixtures/verify/corrupt_blocks.cpt
	cargo run --release --bin validate -- --expect-invalid \
		--manifest rust/tests/fixtures/verify/valid_model.json \
		--bundle rust/tests/fixtures/verify/corrupt_dangling.cpt
	cargo run --release --bin validate -- --expect-invalid \
		--manifest rust/tests/fixtures/verify/valid_model.json \
		--bundle rust/tests/fixtures/verify/corrupt_spectra.cpt
	cargo run --release --bin validate -- --expect-invalid \
		--manifest rust/tests/fixtures/verify/valid_model.json \
		--bundle rust/tests/fixtures/verify/valid_model.cpt \
		--chip rust/tests/fixtures/verify/chip_tiny_mrr.json

## One-iteration serving + mvm bench smoke (works without artifacts —
## synthetic model); writes BENCH_serving.json / BENCH_mvm.json and diffs
## them against benches/baselines (fails only on >2x slowdowns or a
## planned-path speedup below its committed floor)
bench-smoke:
	cargo bench --bench serving -- --smoke
	cargo bench --bench mvm_paths -- --smoke
	cargo run --release --bin bench_diff -- --tolerance 2.0 \
		BENCH_serving.json BENCH_mvm.json

## Drift-subsystem smoke (what CI runs): tiny in-process model, drift
## clock accelerated to one tick per chip pass, a forced recalibration +
## zero-downtime engine hot swap through the live coordinator
drift-smoke:
	cargo bench --bench serving -- --drift-smoke

## Observability smoke (what CI runs): serve the synthetic drift farm
## with the trace recorder, the /metrics endpoint (self-scraped) and
## the JSONL sampler all live, then validate the Chrome trace file —
## request/stage/farm/drift span families, shard_pass + recalibrate —
## with trace_check
trace-smoke:
	cargo run --release --bin cirptc -- serve --smoke --chips 3 \
		--trace trace_smoke.json --metrics-addr 127.0.0.1:0 \
		--sample sample_smoke.jsonl --sample-ms 25
	cargo run --release --bin trace_check -- trace_smoke.json

## Self-healing chaos smoke (what CI runs): emit a seeded random fault
## plan with `cirptc chaos`, then serve the 3-member supervised farm
## under the pinned builtin schedule (one silent DeadChip + one
## detectable TransientPassError episode, shared across members) over a
## digital fallback lane.  The run itself asserts auto-quarantine,
## budgeted retry, degradation and probe-driven auto-restore with zero
## dropped or rejected requests, and that the retry / quarantine /
## restore / degraded span families land in the Chrome trace
chaos-smoke:
	cargo run --release --bin cirptc -- chaos --seed 7 --out chaos_plan.json
	cargo run --release --bin cirptc -- serve --chaos builtin \
		--trace chaos_smoke.json

help:
	@grep -B1 -E '^[a-z-]+:' Makefile | grep -E '^(##|[a-z-]+:)' | sed 's/:.*//'
