# Root entry points for the two-phase build: python runs at build time
# only (compile/aot.py, compile/train.py — both import compile/export.py
# for the CPT1/manifest interchange), then the rust binary serves from
# artifacts/ alone.  See DESIGN.md §2–3 and README.md.

PY ?= python3
OUT ?= artifacts

.PHONY: artifacts train train-quick verify bench-smoke help

## AOT-lower the jax graphs to $(OUT)/*.hlo.txt + chip.json (compile.aot)
artifacts:
	cd python && $(PY) -m compile.aot --out ../$(OUT)

## Hardware-aware training sweep: manifests, CPT1 weight bundles, test
## sets, golden vectors and metrics.json (compile.train)
train:
	cd python && $(PY) -m compile.train --out ../$(OUT)

## CI-sized training run (small data / few epochs)
train-quick:
	cd python && $(PY) -m compile.train --out ../$(OUT) --quick

## Tier-1 verification (what CI runs)
verify:
	cargo build --release --workspace
	cargo test -q --workspace

## One-iteration serving bench (works without artifacts — synthetic model)
bench-smoke:
	cargo bench --bench serving -- --smoke

help:
	@grep -B1 -E '^[a-z-]+:' Makefile | grep -E '^(##|[a-z-]+:)' | sed 's/:.*//'
