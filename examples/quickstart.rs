//! Quickstart: the three ways to run a block-circulant MVM with this crate.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! 1. pure-rust compressed BCM algebra (`cirptc::circulant`)
//! 2. the CirPTC photonic-chip simulator (quantization + crosstalk + dark)
//! 3. the AOT Pallas kernel via the PJRT runtime (`artifacts/bcm_*.hlo.txt`)

use std::path::PathBuf;

use cirptc::circulant::Bcm;
#[cfg(feature = "pjrt")]
use cirptc::runtime::Runtime;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::util::error::Result;
use cirptc::util::rng::Rng;

fn main() -> Result<()> {
    let dir = PathBuf::from("artifacts");

    // -- build a 48×48 order-4 BCM (the paper's peak-efficiency size) ----
    let (p, q, l, b) = (12usize, 12usize, 4usize, 16usize);
    let mut rng = Rng::new(2024);
    let mut w = vec![0.0f32; p * q * l];
    rng.fill_uniform(&mut w);
    let bcm = Bcm::new(p, q, l, w.clone());
    let mut xd = vec![0.0f32; q * l * b];
    rng.fill_uniform(&mut xd);
    let x = Tensor::new(&[q * l, b], xd);

    println!("BCM 48×48, order-4: {} stored parameters ({}× compression — \
              the paper's MN/l)", bcm.params(), (1.0 / bcm.compression()) as u32);

    // -- 1. pure rust ------------------------------------------------------
    let y_rust = bcm.matmul(&x);
    println!("[1] rust compressed matmul      y[0,0] = {:+.5}", y_rust.at2(0, 0));

    // FFT path (paper Eq. 2) agrees:
    let y_fft = bcm.mvm_fft(&{
        let xt = x.transpose2();
        xt.data[..q * l].to_vec()
    });
    println!("    fft path (Eq. 2) agrees:    y[0,0] = {:+.5}", y_fft[0]);

    // -- 2. photonic simulator --------------------------------------------
    let chip = ChipDescription::load(&dir.join("chip.json"))
        .unwrap_or_else(|_| ChipDescription::ideal(4));
    let mut sim = ChipSim::deterministic(chip);
    let y_sim = sim.forward(&bcm, &x);
    println!(
        "[2] CirPTC simulator (6/4-bit, Γ, dark)  y[0,0] = {:+.5}  \
         (max |Δ| vs fp32 = {:.4})",
        y_sim.at2(0, 0),
        y_sim.max_abs_diff(&y_rust)
    );

    // -- 3. AOT Pallas kernel via PJRT (pjrt feature only) -----------------
    #[cfg(feature = "pjrt")]
    match Runtime::new(&dir) {
        Ok(mut rt) => match rt.load("bcm_48x48_b16") {
            Ok(exe) => {
                let wt = Tensor::new(&[p, q, l], w);
                let y_xla = exe.run(&[&wt, &x])?;
                let diff = y_xla
                    .iter()
                    .zip(&y_rust.data)
                    .fold(0.0f32, |m, (a, c)| m.max((a - c).abs()));
                println!(
                    "[3] Pallas kernel via PJRT      y[0,0] = {:+.5}  \
                     (max |Δ| vs rust = {diff:.2e})",
                    y_xla[0]
                );
            }
            Err(e) => println!("[3] skipped (run `make artifacts`): {e:#}"),
        },
        Err(e) => println!("[3] PJRT unavailable: {e:#}"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("[3] skipped: pjrt feature disabled (cargo run --features pjrt)");

    println!("quickstart OK");
    Ok(())
}
