//! Benchmark-analysis walkthrough (paper Discussion + Figs. S5/S14/S16/S18):
//! sweeps the analytical models over matrix size and prints the headline
//! numbers next to the paper's values.
//!
//! ```bash
//! cargo run --release --example scaling_analysis
//! ```

use cirptc::analysis::spectral::{achievable_bits, required_q, FSR_NM};
use cirptc::analysis::{AreaModel, LatencyModel, PowerModel, WeightTech};
use cirptc::arch::CirPtcConfig;
use cirptc::photonic::waveguide::LossBudget;
use cirptc::photonic::LAMBDA_NM;

fn cfg(s: usize) -> CirPtcConfig {
    CirPtcConfig { n: s, m: s, l: 4, fold: 1, f_op: 10e9 }
}

fn main() {
    let area = AreaModel::paper();
    let power = PowerModel::paper();
    let lat = LatencyModel::paper();
    let loss = LossBudget::paper();

    println!("== throughput & latency (Eq. 3) ==");
    for s in [16usize, 48, 64, 128] {
        let c = cfg(s);
        println!(
            "  {s:>3}x{s:<3}  OPS = {:>7.2} TOPS   latency = {:>6.1} ps   \
             max f_op = {:>5.1} GHz {}",
            c.ops() / 1e12,
            lat.latency_s(&c) * 1e12,
            lat.max_f_op(&c) / 1e9,
            if lat.clock_feasible(&c) { "(10 GHz ok)" } else { "(!)" }
        );
    }

    println!("\n== insertion loss (Fig. S14: linear in size) ==");
    for s in [8usize, 16, 32, 48, 64, 96] {
        println!(
            "  {s:>3}x{s:<3}  CirPTC {:>6.2} dB   uncompressed {:>6.2} dB",
            loss.cirptc_critical_path_db(s, s, 4),
            loss.uncompressed_critical_path_db(s, s)
        );
    }

    println!("\n== power breakdown & efficiency (Fig. S16) ==");
    println!(
        "  {:>7}  {:>8} {:>8} {:>8} {:>8} {:>8}  {:>9} {:>6}",
        "size", "laser W", "MZM W", "MRR W", "ADC W", "TIA W", "TOPS/W", "laser%"
    );
    for s in [16usize, 32, 48, 64, 96, 128] {
        let c = cfg(s);
        let b = power.cirptc(&c, WeightTech::ThermoOptic);
        println!(
            "  {s:>3}x{s:<3}  {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3}  \
             {:>9.2} {:>5.1}%",
            b.laser_w,
            b.input_mzm_w,
            b.weight_mrr_w,
            b.adc_w,
            b.tia_w,
            power.efficiency_tops_w(&c, WeightTech::ThermoOptic),
            100.0 * b.laser_fraction()
        );
    }
    println!(
        "  paper anchors: 9.53 TOPS/W peak @48; laser 43.14% @64; decline \
         past the knee"
    );

    println!("\n== computing density ==");
    println!(
        "  48x48           {:>6.2} TOPS/mm²   (paper 4.85)",
        area.computing_density_tops_mm2(&CirPtcConfig::scaled_48())
    );
    println!(
        "  48x48 r=4 fold  {:>6.2} TOPS/mm²   (paper 5.48-5.84)",
        area.computing_density_tops_mm2(&CirPtcConfig::folded_48())
    );

    println!("\n== spectral folding (Fig. S18) ==");
    let folded = CirPtcConfig::folded_48();
    let base_unc =
        power.uncompressed_efficiency_tops_w(&CirPtcConfig::scaled_48(),
                                             WeightTech::ThermoOptic);
    let e_fold = power.efficiency_tops_w(&folded, WeightTech::ThermoOptic);
    let e_moscap = power.efficiency_tops_w(&folded, WeightTech::Moscap);
    println!(
        "  r=4 thermo   {e_fold:>6.2} TOPS/W = {:.2}x uncompressed  \
         (paper 17.13 / 6.87x)",
        e_fold / base_unc
    );
    println!(
        "  r=4 MOSCAP   {e_moscap:>6.2} TOPS/W                    \
         (paper 47.94)"
    );
    let b = power.cirptc(&folded, WeightTech::ThermoOptic);
    println!(
        "  folded breakdown: MRR thermal {:.2} W dominates (paper Fig. S18b): \
         laser {:.2} / ADC {:.2} / TIA {:.2} / MZM {:.2}",
        b.weight_mrr_w, b.laser_w, b.adc_w, b.tia_w, b.input_mzm_w
    );

    println!("\n== spectral scalability (Fig. S5) ==");
    for bits in [4u32, 6, 8] {
        let q = required_q(48, bits, FSR_NM, LAMBDA_NM);
        println!(
            "  N=48, {bits}-bit weights  ->  required Q = {q:.3e}  \
             (check: achievable {:.2} bits)",
            achievable_bits(48, q, FSR_NM, LAMBDA_NM)
        );
    }
    println!("  paper anchor: Q = 2.49e5 at N=48, 6-bit");
    println!("\nscaling_analysis OK");
}
