//! Fig. 3 reproduction: on-chip image processing with convolutional
//! kernels on the simulated CirPTC.
//!
//! * part 1 (Fig. 3a–d): 3×3 blur kernel over RGB images — the kernel is
//!   block-circulant-extended into a 12×4 BCM ("3 rows of padding"), run
//!   through the noisy chip simulator per 4-element subgroup, and compared
//!   to the ideal feature map.  The paper reports normalised RMSE 0.0243
//!   with a ~normal error distribution.
//! * part 2 (Fig. 3e): a CXR-like image processed by four kernels
//!   (blur / sobel-v / sobel-h / sharpen) with full-range weights via the
//!   paper's sign-split time multiplexing.
//!
//! ```bash
//! cargo run --release --example image_processing [-- --images 8 --cxr]
//! ```

use std::path::PathBuf;

use cirptc::data::datasets;
use cirptc::data::kernels::{self, extend_kernel};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{conv2d, im2col, Tensor};
use cirptc::util::cli::Args;
use cirptc::util::error::Result;

/// Run one 3×3 kernel over a (C,H,W) image on the simulated chip.
fn chip_convolve(
    sim: &mut ChipSim,
    img: &Tensor,
    kernel: &kernels::ImageKernel,
) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    let (oh, ow) = (h - 2, w - 2);
    let bcm = extend_kernel(kernel, sim.desc.l);
    let mut out = Tensor::zeros(&[c, oh, ow]);
    for ch in 0..c {
        let chan = Tensor::new(&[1, h, w],
            img.data[ch * h * w..(ch + 1) * h * w].to_vec());
        let xm = im2col(&chan, 3);                   // (9, oh*ow)
        let cols = xm.shape[1];
        let mut xp = Tensor::zeros(&[bcm.n(), cols]); // pad 9 -> 12
        xp.data[..9 * cols].copy_from_slice(&xm.data);
        // full-range kernels: sign-split (Fig. 3e) — two chip passes
        let y = sim.forward_signed(&bcm, &xp);
        out.data[ch * oh * ow..(ch + 1) * oh * ow]
            .copy_from_slice(&y.data[..cols]); // dense row 0 = the kernel
    }
    out
}

fn main() -> Result<()> {
    let args = Args::parse();
    let n_images = args.usize_or("images", 8);
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let chip = ChipDescription::load(&dir.join("chip.json"))
        .unwrap_or_else(|_| ChipDescription::ideal(4));

    // ---- part 1: blur over RGB texture images (Fig. 3a-d) ---------------
    println!("== Fig. 3a-d: 3x3 blur over {n_images} RGB 32x32 images ==");
    let split = datasets::synth_textures(n_images, 99);
    let blur = kernels::blur();
    let wmat = kernels::kernels_to_matrix(&[blur.clone()]);
    let mut sim = ChipSim::new(chip.clone());
    let mut rmses = Vec::new();
    let mut errs: Vec<f32> = Vec::new();
    for i in 0..n_images {
        let img = split.image(i);
        let got = chip_convolve(&mut sim, &img, &blur);
        // ideal per-channel blur
        let mut want = Tensor::zeros(&got.shape.clone());
        let (h, w) = (img.shape[1], img.shape[2]);
        for ch in 0..3 {
            let chan = Tensor::new(&[1, h, w],
                img.data[ch * h * w..(ch + 1) * h * w].to_vec());
            let y = conv2d(&chan, &wmat, 3, false);
            let sz = y.numel();
            want.data[ch * sz..(ch + 1) * sz].copy_from_slice(&y.data);
        }
        let rmse = got.normalized_rmse(&want);
        rmses.push(rmse);
        errs.extend(got.data.iter().zip(&want.data).map(|(a, b)| a - b));
    }
    let mean_rmse = rmses.iter().sum::<f32>() / rmses.len() as f32;
    let mu = errs.iter().sum::<f32>() / errs.len() as f32;
    let sd = (errs.iter().map(|e| (e - mu) * (e - mu)).sum::<f32>()
        / errs.len() as f32)
        .sqrt();
    // normality proxy: fraction within ±1σ / ±2σ (normal: 68.3 % / 95.4 %)
    let f1 = errs.iter().filter(|e| (**e - mu).abs() < sd).count() as f32
        / errs.len() as f32;
    let f2 = errs.iter().filter(|e| (**e - mu).abs() < 2.0 * sd).count() as f32
        / errs.len() as f32;
    println!(
        "  normalized RMSE = {mean_rmse:.4}   (paper: 0.0243)\n  \
         error dist: μ={mu:+.4} σ={sd:.4}  within ±1σ {:.1}% (68.3) \
         ±2σ {:.1}% (95.4)",
        f1 * 100.0,
        f2 * 100.0
    );

    // ---- part 2: CXR image with four kernels (Fig. 3e) -------------------
    if args.has("no-cxr") {
        return Ok(());
    }
    println!("== Fig. 3e: CXR-like 64x64 image, 4 kernels, sign-split ==");
    let cxr = datasets::synth_cxr(1, 7).image(0);
    for k in kernels::fig3e_kernels() {
        let mut sim = ChipSim::new(chip.clone());
        let got = chip_convolve(&mut sim, &cxr, &k);
        let want = conv2d(&cxr, &kernels::kernels_to_matrix(&[k.clone()]), 3, false);
        let rmse = got.normalized_rmse(&want);
        let energy: f32 =
            got.data.iter().map(|v| v * v).sum::<f32>() / got.numel() as f32;
        println!(
            "  {:<8} normalized RMSE = {rmse:.4}  feature energy = {energy:.4}  \
             ({} chip passes)",
            k.name,
            sim.passes()
        );
    }
    println!("image_processing OK");
    Ok(())
}
