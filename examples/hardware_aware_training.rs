//! Hardware-aware training (paper Fig. 1d) entirely in rust — no python
//! on the compile path either: synthetic data → chip-in-the-loop HAT loop
//! (`cirptc::train`) → manifest + CPT1 artifacts → reloaded through the
//! serving engine.
//!
//! ```bash
//! make train          # full run, writes artifacts/models/synth_shapes.*
//! make train-smoke    # CI-sized run: few steps, temp-dir artifacts,
//!                     # asserts the loss decreases end-to-end
//! ```
//!
//! Flags: `--out DIR` (default `artifacts`), `--dataset synth_shapes`,
//! `--epochs N`, `--batch N`, `--lr F`, `--train-n N`, `--seed N`,
//! `--digital` (disable the chip in the loop), `--smoke`.

use std::path::PathBuf;

use cirptc::data::datasets::{
    self, SHAPES_MANIFEST_JSON as SHAPES_MANIFEST, Split,
};
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::train::{
    evaluate, fit, gather_batch, Optimizer, TrainBackend, TrainConfig,
    TrainModel,
};
use cirptc::util::cli::Args;
use cirptc::util::error::Result;

/// Chip description for training: `artifacts/chip.json` when present (the
/// as-fabricated chip the python side exports), else a representative
/// non-ideal chip so the example runs with zero artifacts.
fn chip_desc(out: &std::path::Path) -> ChipDescription {
    ChipDescription::load(&out.join("chip.json")).unwrap_or_else(|_| {
        let mut d = ChipDescription::ideal(4);
        d.gamma = vec![
            0.94, 0.03, 0.02, 0.01, //
            0.02, 0.94, 0.03, 0.01, //
            0.01, 0.03, 0.94, 0.02, //
            0.02, 0.01, 0.03, 0.94,
        ];
        d.resp = vec![1.0, 0.98, 1.02, 0.99];
        d.dark = 0.01;
        d.sigma_rel = 0.01;
        d.sigma_abs = 0.002;
        d.w_bits = 6;
        d.x_bits = 4;
        d.seed = 7;
        d
    })
}

fn main() -> Result<()> {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let digital = args.has("digital");
    let out = if smoke {
        std::env::temp_dir().join("cirptc_train_smoke")
    } else {
        PathBuf::from(args.str_or("out", "artifacts"))
    };
    let dataset = args.str_or("dataset", "synth_shapes");
    let epochs = args.usize_or("epochs", if smoke { 4 } else { 12 });
    let batch = args.usize_or("batch", 16);
    let lr = args.f64_or("lr", 5e-3) as f32;
    let train_n = args.usize_or("train-n", if smoke { 96 } else { 512 });
    let seed = args.usize_or("seed", 2025) as u64;
    if dataset != "synth_shapes" {
        cirptc::bail!("only synth_shapes is wired up (got '{dataset}')");
    }

    println!(
        "hardware-aware training: {dataset}, {} backend, {epochs} epochs, \
         batch {batch}, lr {lr}, n {train_n}",
        if digital { "digital" } else { "chip-in-the-loop (noisy)" }
    );

    // -- data + model ------------------------------------------------------
    let split: Split = datasets::synth_shapes(train_n, seed);
    let eval_split = datasets::synth_shapes(train_n / 2, seed ^ 0xEE);
    let manifest = Manifest::parse(SHAPES_MANIFEST)?;
    let mut model = TrainModel::init(manifest, seed)?;

    // -- the HAT loop ------------------------------------------------------
    let mut backend = if digital {
        TrainBackend::Digital
    } else {
        // noisy lookup-mode forward, deterministic-surrogate gradients
        TrainBackend::Chip(ChipSim::new(chip_desc(&out)))
    };
    let mut opt = Optimizer::adam(lr);
    let cfg = TrainConfig {
        epochs,
        batch,
        max_steps: if smoke { 24 } else { 0 },
        seed: seed ^ 0x5EED,
    };
    let hist = fit(&mut model, &mut backend, &mut opt, &split, &cfg)?;
    for (ep, loss) in hist.iter().enumerate() {
        println!("  epoch {:>2}  loss {loss:.4}", ep + 1);
    }
    let first = hist.first().copied().unwrap_or(f32::NAN);
    let last = hist.last().copied().unwrap_or(f32::NAN);
    if last.is_nan() || last >= first {
        cirptc::bail!("loss did not decrease: {first:.4} -> {last:.4}");
    }

    // -- BN calibration + eval (paper's one-shot chip calibration) ---------
    let nb = (split.n / batch).min(6);
    let calib: Vec<_> = (0..nb)
        .map(|i| {
            let idx: Vec<usize> = (i * batch..(i + 1) * batch).collect();
            gather_batch(&split, &idx).0
        })
        .collect();
    model.recalibrate_bn(&calib, &mut backend)?;
    let acc = evaluate(&model, &mut backend, &eval_split, batch)?;
    println!("  eval accuracy ({} images): {acc:.4}", eval_split.n);

    // -- rust-written artifacts → served by the engine ---------------------
    let (mpath, wpath) = model.save_artifacts(&out, &dataset)?;
    println!("  wrote {} + {}", mpath.display(), wpath.display());
    let engine = Engine::load(&mpath, &wpath)?;
    let imgs: Vec<_> = (0..eval_split.n.min(8))
        .map(|i| eval_split.image(i))
        .collect();
    let served = engine.forward_batch(&imgs, &mut Backend::Digital)?;
    let mut ok = 0usize;
    for (row, want) in served.iter().zip(&eval_split.labels) {
        if cirptc::tensor::argmax(row) == *want as usize {
            ok += 1;
        }
    }
    if !served
        .iter()
        .all(|r| r.len() == 3 && r.iter().all(|v| v.is_finite()))
    {
        cirptc::bail!("engine served non-finite logits");
    }
    println!(
        "  engine reload: served a batch of {} ({} / {} top-1 agree with labels)",
        served.len(),
        ok,
        served.len()
    );
    println!("hardware-aware training OK");
    Ok(())
}
