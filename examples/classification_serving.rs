//! END-TO-END driver (DESIGN.md §6): serve the trained StrC-ONN models
//! through the full L3 stack — router → dynamic batcher → worker pool —
//! over three backends, reporting accuracy, latency (p50/p99) and
//! throughput per configuration:
//!
//! * `digital`   — pure-rust fp32 engine (paper's digital baseline)
//! * `photonic`  — CirPTC chip simulator with noise (paper's on-chip
//!   lookup-mode inference, Fig. 4)
//! * `xla-aot`   — the AOT HLO artifact (L1 Pallas + L2 jax graph) on PJRT
//!
//! ```bash
//! make artifacts && cargo run --release --example classification_serving
//! ```
//!
//! Results are recorded in EXPERIMENTS.md.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use cirptc::coordinator::worker::EngineBackend;
#[cfg(feature = "pjrt")]
use cirptc::coordinator::worker::XlaBackend;
use cirptc::coordinator::{BackendFactory, BatcherConfig, Coordinator};
use cirptc::data::Bundle;
use cirptc::obs;
use cirptc::onn::{Backend, Engine};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{argmax, Tensor};
use cirptc::util::cli::Args;
use cirptc::util::error::Result;

struct RunResult {
    acc: f64,
    throughput: f64,
    p50_us: u64,
    p99_us: u64,
    mean_batch: f64,
    confusion: Vec<Vec<u32>>,
}

fn run_backends(
    images: &[Tensor],
    labels: &[i32],
    classes: usize,
    backends: Vec<BackendFactory>,
    max_batch: usize,
    json: bool,
) -> Result<RunResult> {
    let coord = Coordinator::start(
        backends,
        BatcherConfig { max_batch, max_wait_us: 1500, queue_cap: 0 },
    );
    let t0 = Instant::now();
    let responses = coord.classify_all(images)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut confusion = vec![vec![0u32; classes]; classes];
    let mut correct = 0usize;
    for (r, &y) in responses.iter().zip(labels) {
        let pred = argmax(&r.logits);
        confusion[y as usize][pred] += 1;
        if pred == y as usize {
            correct += 1;
        }
    }
    let (p50, p99) = coord.metrics.latency_percentiles_us();
    // the shared end-of-run report (obs::report's render): summary-format
    // text by default, the full-resolution export with `--json`
    println!(
        "  {}",
        obs::render_report(
            &coord.metrics,
            &[("rps", images.len() as f64 / wall)],
            json,
        )
    );
    Ok(RunResult {
        acc: correct as f64 / images.len() as f64,
        throughput: images.len() as f64 / wall,
        p50_us: p50,
        p99_us: p99,
        mean_batch: coord.metrics.mean_batch_size(),
        confusion,
    })
}

fn print_result(label: &str, r: &RunResult) {
    println!(
        "  {label}  acc={:.4}  throughput={:>7.1} req/s  p50={}µs  \
         p99={}µs  mean_batch={:.1}",
        r.acc, r.throughput, r.p50_us, r.p99_us, r.mean_batch
    );
}

fn main() -> Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let workers = args.usize_or("workers", 2);
    let max_batch = args.usize_or("batch", 8);
    let limit = args.usize_or("limit", 128);
    let json = args.has("json");
    let models: Vec<String> = match args.get("model") {
        Some(m) => vec![m.to_string()],
        None => ["synth_cxr", "synth_digits", "synth_textures"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };

    let chip = ChipDescription::load(&dir.join("chip.json"))?;
    for model in &models {
        let manifest = dir.join(format!("models/{model}.json"));
        if !manifest.exists() {
            println!("[{model}] missing — run `make train-py` first");
            continue;
        }
        // two weight bundles: the DPE (hardware-aware) model serves the
        // photonic path; the digitally-trained circulant baseline serves
        // the digital / XLA paths (BN calibration is substrate-specific —
        // see python/compile/recalib.py)
        let engine = Arc::new(Engine::load(
            &manifest,
            &dir.join(format!("models/{model}_dpe.cpt")),
        )?);
        let digital_bundle = dir.join(format!("models/{model}_digital.cpt"));
        let engine_dig = if digital_bundle.exists() {
            Arc::new(Engine::load(&manifest, &digital_bundle)?)
        } else {
            Arc::clone(&engine)
        };
        let test = Bundle::load(&dir.join(format!("models/{model}_testset.cpt")))?;
        let (c, h) = engine.manifest.input_shape();
        let classes = engine.manifest.classes;
        let xs = test.get("x")?.as_f32()?;
        let ys = test.get("y")?.as_i32()?;
        let n = ys.len().min(limit);
        let images: Vec<Tensor> = (0..n)
            .map(|i| {
                Tensor::new(
                    &[c, h, h],
                    xs[i * c * h * h..(i + 1) * c * h * h].to_vec(),
                )
            })
            .collect();
        let labels = &ys[..n];
        let (dense, stored) = engine.manifest.param_counts();
        println!(
            "\n== {model}: {n} requests, {workers} workers, batch {max_batch} \
             (params {stored} vs dense {dense}: {:.2}% reduction) ==",
            100.0 * (1.0 - stored as f64 / dense as f64)
        );

        // -- digital -------------------------------------------------------
        let factories: Vec<BackendFactory> = (0..workers)
            .map(|_| {
                let engine = Arc::clone(&engine_dig);
                Box::new(move || {
                    Box::new(EngineBackend { engine, mode: Backend::Digital })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let r =
            run_backends(&images, labels, classes, factories, max_batch, json)?;
        print_result("digital ", &r);

        // -- photonic sim (each worker owns an independent chip instance) --
        let factories: Vec<BackendFactory> = (0..workers)
            .map(|i| {
                let engine = Arc::clone(&engine);
                let mut d = chip.clone();
                d.seed ^= i as u64;
                Box::new(move || {
                    Box::new(EngineBackend {
                        engine,
                        mode: Backend::PhotonicSim(ChipSim::new(d)),
                    })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let r =
            run_backends(&images, labels, classes, factories, max_batch, json)?;
        print_result("photonic", &r);
        if classes <= 3 {
            println!("  photonic confusion matrix: {:?}", r.confusion);
        }

        // -- XLA AOT artifact (PJRT client built on the worker thread;
        //    pjrt feature only — the default build serves digital+photonic)
        #[cfg(feature = "pjrt")]
        {
            let art = dir.clone();
            let mname = format!("model_{model}");
            let chw = (c, h, h);
            let factory: BackendFactory = Box::new(move || {
                Box::new(
                    XlaBackend::new(&art, &mname, 8, classes, chw)
                        .expect("XLA backend"),
                ) as Box<dyn cirptc::coordinator::InferenceBackend>
            });
            let r =
                run_backends(&images, labels, classes, vec![factory], 8, json)?;
            print_result("xla-aot ", &r);
        }
        #[cfg(not(feature = "pjrt"))]
        println!("  xla-aot   skipped (build with --features pjrt)");
    }
    println!("\nclassification_serving OK");
    Ok(())
}
