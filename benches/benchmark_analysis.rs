//! Bench: the paper's Discussion / supplementary analysis —
//! Eq. 3 throughput, computing density, power efficiency & breakdown
//! (Fig. S16), insertion loss (Fig. S14), spectral Q requirement (Fig. S5),
//! spectral folding (Fig. S18) and the SOTA comparison (Table S6).
//! Every row prints measured-vs-paper.

use cirptc::analysis::sota;
use cirptc::analysis::spectral::{required_q, FSR_NM};
use cirptc::analysis::{AreaModel, LatencyModel, PowerModel, WeightTech};
use cirptc::arch::CirPtcConfig;
use cirptc::photonic::waveguide::LossBudget;
use cirptc::photonic::LAMBDA_NM;
use cirptc::util::bench::{row, section};

fn cfg(s: usize) -> CirPtcConfig {
    CirPtcConfig { n: s, m: s, l: 4, fold: 1, f_op: 10e9 }
}

fn main() {
    let area = AreaModel::paper();
    let power = PowerModel::paper();
    let loss = LossBudget::paper();
    let lat = LatencyModel::paper();

    section("Eq. 3: OPS = 2*M*N*f_op");
    let c48 = CirPtcConfig::scaled_48();
    row("48x48 @ 10 GHz", &[
        ("tops", format!("{:.2}", c48.ops() / 1e12)),
        ("exact", "46.08".into()),
    ]);

    section("computing density (paper: 4.85 TOPS/mm2 @48x48; 5.48-5.84 folded)");
    row("48x48", &[
        ("tops_per_mm2", format!("{:.2}", area.computing_density_tops_mm2(&c48))),
        ("paper", "4.85".into()),
    ]);
    row("48x48 r=4", &[
        ("tops_per_mm2",
         format!("{:.2}", area.computing_density_tops_mm2(&CirPtcConfig::folded_48()))),
        ("paper", "5.48-5.84".into()),
    ]);

    section("Fig S14: insertion loss, linear in size");
    for s in [8usize, 16, 32, 48, 64, 96] {
        row(&format!("{s}x{s}"), &[
            ("cirptc_db", format!("{:.2}", loss.cirptc_critical_path_db(s, s, 4))),
            ("uncompressed_db", format!("{:.2}", loss.uncompressed_critical_path_db(s, s))),
        ]);
    }

    section("Fig S16: power breakdown & efficiency vs size");
    let mut peak = (0usize, 0.0f64);
    for s in [16usize, 32, 48, 64, 96, 128] {
        let c = cfg(s);
        let b = power.cirptc(&c, WeightTech::ThermoOptic);
        let e = power.efficiency_tops_w(&c, WeightTech::ThermoOptic);
        if e > peak.1 {
            peak = (s, e);
        }
        row(&format!("{s}x{s}"), &[
            ("tops_w", format!("{e:.2}")),
            ("laser_w", format!("{:.3}", b.laser_w)),
            ("laser_pct", format!("{:.1}", 100.0 * b.laser_fraction())),
            ("total_w", format!("{:.2}", b.total_w())),
        ]);
    }
    row("peak", &[
        ("at", format!("{}x{}", peak.0, peak.0)),
        ("tops_w", format!("{:.2}", peak.1)),
        ("paper", "9.53 @48x48".into()),
    ]);
    let f64c = power.cirptc(&cfg(64), WeightTech::ThermoOptic);
    row("laser share @64", &[
        ("pct", format!("{:.1}", 100.0 * f64c.laser_fraction())),
        ("paper", "43.14".into()),
    ]);
    let ratio48 = power.efficiency_tops_w(&c48, WeightTech::ThermoOptic)
        / power.uncompressed_efficiency_tops_w(&c48, WeightTech::ThermoOptic);
    row("vs uncompressed @48", &[
        ("ratio", format!("{ratio48:.2}x")),
        ("paper", "3.82x".into()),
    ]);

    section("Fig S18: spectral folding r=4");
    let folded = CirPtcConfig::folded_48();
    let e_fold = power.efficiency_tops_w(&folded, WeightTech::ThermoOptic);
    let e_moscap = power.efficiency_tops_w(&folded, WeightTech::Moscap);
    let unc = power.uncompressed_efficiency_tops_w(&c48, WeightTech::ThermoOptic);
    row("r=4 thermo", &[
        ("tops_w", format!("{e_fold:.2}")),
        ("ratio", format!("{:.2}x", e_fold / unc)),
        ("paper", "17.13 / 6.87x".into()),
    ]);
    row("r=4 MOSCAP", &[
        ("tops_w", format!("{e_moscap:.2}")),
        ("paper", "47.94".into()),
    ]);
    let bf = power.cirptc(&folded, WeightTech::ThermoOptic);
    row("dominant term (folded)", &[
        ("mrr_w", format!("{:.2}", bf.weight_mrr_w)),
        ("next", format!("adc {:.2}", bf.adc_w)),
        ("paper", "MRR thermal dominates (S18b)".into()),
    ]);

    section("Fig S5: required Q vs weight resolution (N=48)");
    for bits in [2u32, 4, 6, 8] {
        row(&format!("{bits}-bit"), &[
            ("q", format!("{:.3e}", required_q(48, bits, FSR_NM, LAMBDA_NM))),
            ("paper", if bits == 6 { "2.49e5".into() } else { "-".to_string() }),
        ]);
    }

    section("latency feasibility (single-cycle MVM constraint)");
    for s in [48usize, 256, 1024] {
        let c = cfg(s);
        row(&format!("{s}x{s}"), &[
            ("latency_ps", format!("{:.1}", lat.latency_s(&c) * 1e12)),
            ("max_f_op_ghz", format!("{:.1}", lat.max_f_op(&c) / 1e9)),
            ("10ghz_ok", format!("{}", lat.clock_feasible(&c))),
        ]);
    }

    section("Table S6: SOTA comparison (CirPTC rows computed live)");
    for e in sota::literature().iter().chain(sota::cirptc_rows().iter()) {
        row(e.name, &[
            ("tech", e.technology.to_string()),
            ("tops_mm2", e.density_tops_mm2.map(|d| format!("{d:.2}"))
                .unwrap_or_else(|| "-".into())),
            ("tops_w", e.efficiency_tops_w.map(|d| format!("{d:.2}"))
                .unwrap_or_else(|| "-".into())),
        ]);
    }
}
