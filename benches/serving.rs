//! Bench: L3 serving — batching-policy sweep and coordinator overhead.
//!
//! The paper's system contribution is the hardware; the serving layer is
//! our operationalisation (DESIGN.md §4).  Targets: the coordinator adds
//! <10 % overhead vs a bare engine loop, and the batch-size sweep shows
//! the standard throughput/latency trade-off.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use cirptc::coordinator::worker::EngineBackend;
use cirptc::coordinator::{BackendFactory, BatcherConfig, Coordinator};
use cirptc::data::Bundle;
use cirptc::onn::{Backend, Engine};
use cirptc::tensor::Tensor;
use cirptc::util::bench::{row, section};

fn main() {
    let dir = PathBuf::from("artifacts");
    let manifest = dir.join("models/synth_cxr.json");
    if !manifest.exists() {
        println!("serving bench skipped — run `make train` first");
        return;
    }
    let engine = Arc::new(
        Engine::load(&manifest, &dir.join("models/synth_cxr_dpe.cpt")).unwrap(),
    );
    let test = Bundle::load(&dir.join("models/synth_cxr_testset.cpt")).unwrap();
    let xs = test.get("x").unwrap().as_f32().unwrap();
    let n = 64usize;
    let images: Vec<Tensor> = (0..n)
        .map(|i| Tensor::new(&[1, 64, 64], xs[i * 64 * 64..(i + 1) * 64 * 64].to_vec()))
        .collect();

    section("bare engine loop (digital, single thread) — baseline");
    let t0 = Instant::now();
    let mut be = Backend::Digital;
    for im in &images {
        let _ = engine.forward(im, &mut be).unwrap();
    }
    let bare = t0.elapsed().as_secs_f64();
    row("bare loop", &[
        ("req_s", format!("{:.1}", n as f64 / bare)),
        ("total_s", format!("{bare:.3}")),
    ]);

    section("coordinator overhead (1 digital worker, batch 8)");
    let engine2 = Arc::clone(&engine);
    let coord = Coordinator::start(
        vec![Box::new(move || {
            Box::new(EngineBackend { engine: engine2, mode: Backend::Digital })
                as Box<dyn cirptc::coordinator::InferenceBackend>
        })],
        BatcherConfig { max_batch: 8, max_wait_us: 500 },
    );
    let t0 = Instant::now();
    coord.classify_all(&images).unwrap();
    let coord_s = t0.elapsed().as_secs_f64();
    row("coordinator", &[
        ("req_s", format!("{:.1}", n as f64 / coord_s)),
        ("overhead_pct", format!("{:.1}", 100.0 * (coord_s - bare) / bare)),
        ("target", "<10%".into()),
    ]);
    drop(coord);

    section("batch-size sweep (2 digital workers)");
    for batch in [1usize, 2, 4, 8, 16] {
        let factories: Vec<BackendFactory> = (0..2)
            .map(|_| {
                let engine = Arc::clone(&engine);
                Box::new(move || {
                    Box::new(EngineBackend { engine, mode: Backend::Digital })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let coord = Coordinator::start(
            factories,
            BatcherConfig { max_batch: batch, max_wait_us: 400 },
        );
        let t0 = Instant::now();
        coord.classify_all(&images).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let (p50, p99) = coord.metrics.latency_percentiles_us();
        row(&format!("batch={batch}"), &[
            ("req_s", format!("{:.1}", n as f64 / wall)),
            ("p50_us", format!("{p50}")),
            ("p99_us", format!("{p99}")),
            ("mean_batch", format!("{:.1}", coord.metrics.mean_batch_size())),
        ]);
    }

    section("worker scaling (digital, batch 8)");
    for workers in [1usize, 2, 4] {
        let factories: Vec<BackendFactory> = (0..workers)
            .map(|_| {
                let engine = Arc::clone(&engine);
                Box::new(move || {
                    Box::new(EngineBackend { engine, mode: Backend::Digital })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let coord = Coordinator::start(
            factories,
            BatcherConfig { max_batch: 8, max_wait_us: 400 },
        );
        let t0 = Instant::now();
        coord.classify_all(&images).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        row(&format!("workers={workers}"), &[(
            "req_s",
            format!("{:.1}", n as f64 / wall),
        )]);
    }
}
