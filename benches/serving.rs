//! Bench: L3 serving — batch-major engine throughput and coordinator
//! overhead (DESIGN.md §4, §9).
//!
//! Sections:
//!   1. per-image `forward()` loop — the pre-batching baseline;
//!   2. batch-major `forward_batch` sweep — one layer-graph walk and one
//!      multi-column BCM multiply per layer per batch (the acceptance
//!      check: images/sec at batch ≥ 8 must beat the per-image loop);
//!   3. coordinator overhead + batching-policy sweep + worker scaling;
//!   4. farm scaling (DESIGN.md §farm): a partitioned engine over
//!      N ∈ {1, 2, 4} chips with one compute thread per chip, vs the
//!      single-chip baseline, plus the throughput retained when one of
//!      three farm members is forced Failed mid-stream;
//!   5. drifting-chip scenario sweep (`-- --drift` full, `-- --drift-smoke`
//!      CI-sized with a forced recalibration): accuracy-over-time and tail
//!      latency with the drift monitor + background recalibrator on vs.
//!      off (DESIGN.md §drift).
//!
//! Runs against trained artifacts when present (`make train-py`), otherwise
//! falls back to a synthetic in-memory model so the serving path is
//! always exercised (CI bench smoke: `cargo bench --bench serving --
//! --smoke`).

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cirptc::coordinator::worker::EngineBackend;
use cirptc::coordinator::{
    BackendFactory, BatcherConfig, Coordinator, EngineSource, InferenceBackend,
    Metrics, Staged, StagedFactory,
};
use cirptc::data::datasets::{self, Split};
use cirptc::data::Bundle;
use cirptc::drift::{
    DriftBackend, DriftConfig, DriftModel, DriftMonitor, DriftShared,
    MonitorConfig, RecalConfig, Recalibrator,
};
use cirptc::farm::{
    ChipHealth, Farm, FarmConfig, FarmMember, PartitionPlan,
    PartitionedEngine, DEFAULT_DRIFTING_PPM,
};
use cirptc::fault::{
    ChipSupervisor, Episode, FaultKind, FaultPlan, SupervisorConfig,
};
use cirptc::obs::{self, trace};
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{argmax, Tensor};
use cirptc::train::{
    fit, gather_batch, Optimizer, TrainBackend, TrainConfig, TrainModel,
};
use cirptc::util::bench::{row, section, workspace_path, JsonReport};
use cirptc::util::cli::Args;
use cirptc::util::rng::Rng;
use cirptc::util::scratch;

/// Synthetic circ model (conv→relu→pool→flatten→fc on 32×32 inputs) so
/// the bench runs without trained artifacts.
fn synthetic_engine() -> Engine {
    let manifest = Manifest::parse(
        r#"{
          "dataset": "synth_bench", "classes": 4,
          "layers": [
            {"kind": "conv", "cin": 1, "cout": 8, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "fc", "cin": 2048, "cout": 4, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0}
          ]}"#,
    )
    .unwrap();
    let mut bundle = Bundle::default();
    let mut rng = Rng::new(17);
    // conv: cout 8 -> P=2, n_in 9 -> Q=3
    let mut w0 = vec![0.0f32; 2 * 3 * 4];
    rng.fill_uniform(&mut w0);
    for v in w0.iter_mut() {
        *v = (*v - 0.5) * 0.5;
    }
    bundle.insert_f32("layer0.w", &[2, 3, 4], w0);
    bundle.insert_f32("layer0.b", &[8], vec![0.0; 8]);
    // fc: 2048 -> 4: P=1, Q=512
    let mut w4 = vec![0.0f32; 512 * 4];
    rng.fill_uniform(&mut w4);
    for v in w4.iter_mut() {
        *v = (*v - 0.5) * 0.1;
    }
    bundle.insert_f32("layer4.w", &[1, 512, 4], w4);
    bundle.insert_f32("layer4.b", &[4], vec![0.1, 0.2, 0.3, 0.4]);
    Engine::from_parts(manifest, &bundle).unwrap()
}

fn synthetic_images(n: usize) -> Vec<Tensor> {
    let mut rng = Rng::new(18);
    (0..n)
        .map(|_| {
            let mut d = vec![0.0f32; 32 * 32];
            rng.fill_uniform(&mut d);
            Tensor::new(&[1, 32, 32], d)
        })
        .collect()
}

/// Wider synthetic model for the farm section: both circ layers carry
/// P=4 block-rows (conv cout 16 → grid [4, 3, 4], fc 4096→16 → grid
/// [4, 1024, 4]), so every farm width in {1, 2, 4} shards each linear
/// layer non-trivially.
fn farm_engine() -> Engine {
    let manifest = Manifest::parse(
        r#"{
          "dataset": "synth_farm", "classes": 16,
          "layers": [
            {"kind": "conv", "cin": 1, "cout": 16, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "fc", "cin": 4096, "cout": 16, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0}
          ]}"#,
    )
    .unwrap();
    let mut bundle = Bundle::default();
    let mut rng = Rng::new(19);
    let mut w0 = vec![0.0f32; 4 * 3 * 4];
    rng.fill_uniform(&mut w0);
    for v in w0.iter_mut() {
        *v = (*v - 0.5) * 0.5;
    }
    bundle.insert_f32("layer0.w", &[4, 3, 4], w0);
    bundle.insert_f32("layer0.b", &[16], vec![0.0; 16]);
    let mut w4 = vec![0.0f32; 4 * 1024 * 4];
    rng.fill_uniform(&mut w4);
    for v in w4.iter_mut() {
        *v = (*v - 0.5) * 0.1;
    }
    bundle.insert_f32("layer4.w", &[4, 1024, 4], w4);
    bundle.insert_f32("layer4.b", &[16], vec![0.1; 16]);
    Engine::from_parts(manifest, &bundle).unwrap()
}

/// The as-calibrated chip the drift scenario deploys on.
fn drift_chip() -> ChipDescription {
    let mut d = ChipDescription::ideal(4);
    d.w_bits = 6;
    d.x_bits = 4;
    d.dark = 0.01;
    d.seed = 11;
    d
}

fn serve_eval_round(coord: &Coordinator, eval: &Split) -> f64 {
    let mut correct = 0usize;
    let mut s = 0usize;
    while s < eval.n {
        let e = (s + 8).min(eval.n);
        let imgs: Vec<Tensor> = (s..e).map(|i| eval.image(i)).collect();
        let responses = coord.classify_all(&imgs).unwrap();
        for (r, i) in responses.iter().zip(s..e) {
            if argmax(&r.logits) == eval.labels[i] as usize {
                correct += 1;
            }
        }
        s = e;
    }
    correct as f64 / eval.n as f64
}

/// Drifting-chip scenario sweep: the same seeded drift episode served
/// with recalibration off, then on — accuracy over time plus tail
/// latency.  In smoke mode the trigger is set so low that the first
/// post-cooldown probe *forces* a recalibration + hot swap, and the run
/// fails loudly if none lands (the CI contract of `make drift-smoke`).
fn drift_scenario(smoke: bool) {
    section("drifting-chip serving: accuracy over time, recal off vs on");
    // tiny in-process model (release-mode training takes well under a
    // second, so the scenario needs no artifacts)
    let manifest = Manifest::parse(datasets::SHAPES_MANIFEST_JSON).unwrap();
    let train_split = datasets::synth_shapes(192, 0xB1);
    let calib_split = datasets::synth_shapes(128, 0xB2);
    let eval_split = datasets::synth_shapes(if smoke { 64 } else { 128 }, 0xB3);
    let mut model = TrainModel::init(manifest.clone(), 0xB4).unwrap();
    let mut opt = Optimizer::adam(5e-3);
    let tcfg = TrainConfig {
        epochs: if smoke { 4 } else { 8 },
        batch: 16,
        max_steps: 0,
        seed: 0xB5,
    };
    fit(&mut model, &mut TrainBackend::Digital, &mut opt, &train_split, &tcfg)
        .unwrap();
    let calib_batches: Vec<Tensor> = (0..6)
        .map(|i| {
            let idx: Vec<usize> = (i * 16..(i + 1) * 16).collect();
            gather_batch(&train_split, &idx).0
        })
        .collect();
    model
        .recalibrate_bn(
            &calib_batches,
            &mut TrainBackend::Chip(ChipSim::deterministic(drift_chip())),
        )
        .unwrap();
    let bundle = model.export_bundle();

    let dcfg = DriftConfig {
        seed: 0xB6,
        passes_per_tick: 1,
        gamma_walk: 2e-3,
        resp_tilt: 4e-3,
        dark_creep: 2e-4,
        max_ticks: 120,
    };
    let rounds = if smoke { 6 } else { 10 };
    for recal_on in [false, true] {
        let metrics = Arc::new(Metrics::default());
        let engine = Engine::from_parts(manifest.clone(), &bundle).unwrap();
        let shared = DriftShared::new(engine, Arc::clone(&metrics));
        let (tx, rx) = mpsc::channel();
        let _recal = if recal_on {
            let rcfg = RecalConfig {
                fine_tune_steps: if smoke { 16 } else { 32 },
                lr: 2e-3,
                batch: 16,
                bn_batches: 6,
                seed: 0xB7,
                noisy: false,
                snapshot_dir: None,
            };
            Some(
                Recalibrator::new(
                    model.clone(),
                    calib_split.clone(),
                    rcfg,
                    Arc::clone(&shared),
                )
                .spawn(rx),
            )
        } else {
            drop(rx);
            None
        };
        let mcfg = MonitorConfig {
            probe_every: 1,
            residual_trigger: if !recal_on {
                f32::INFINITY
            } else if smoke {
                1e-6 // force a recalibration on the first cooled-down probe
            } else {
                0.04
            },
            cooldown_passes: if smoke { 24 } else { 40 },
            ..MonitorConfig::default()
        };
        let factory: BackendFactory = {
            let shared = Arc::clone(&shared);
            let dcfg = dcfg.clone();
            Box::new(move || {
                let desc = drift_chip();
                let mut sim = ChipSim::deterministic(desc.clone());
                sim.set_drift(DriftModel::new(dcfg));
                let monitor = DriftMonitor::new(mcfg, &desc);
                Box::new(DriftBackend::new(shared, sim, monitor, tx))
                    as Box<dyn InferenceBackend>
            })
        };
        let coord = Coordinator::start_with_metrics(
            vec![factory],
            BatcherConfig { max_batch: 8, max_wait_us: 20_000, queue_cap: 0 },
            Arc::clone(&metrics),
        );
        for round in 0..rounds {
            let acc = serve_eval_round(&coord, &eval_split);
            let (_, p99) = metrics.latency_percentiles_us();
            row(
                &format!("recal={} round={round}", if recal_on { "on " } else { "off" }),
                &[
                    ("acc", format!("{acc:.3}")),
                    ("p99_us", format!("{p99}")),
                    ("recals", format!("{}", metrics.recalibrations.get())),
                    ("ticks", format!("{}", metrics.drift_ticks.get())),
                    (
                        "probe_res_ppm",
                        format!("{}", metrics.last_probe_residual_ppm.get()),
                    ),
                ],
            );
        }
        if recal_on {
            // a recalibration may still be in flight; give it time to land
            let deadline = Instant::now() + Duration::from_secs(120);
            while metrics.recalibrations.get() == 0 {
                assert!(
                    Instant::now() < deadline,
                    "drift scenario: no recalibration landed: {}",
                    metrics.summary()
                );
                serve_eval_round(&coord, &eval_split);
                std::thread::sleep(Duration::from_millis(50));
            }
            assert_eq!(metrics.errors.get(), 0, "requests failed during swap");
        }
        println!("  {}", obs::render_report(&metrics, &[], false));
        drop(coord);
    }
    println!("drift scenario OK");
}

fn main() {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let mut rep = JsonReport::new("serving");
    if args.has("drift-smoke") {
        drift_scenario(true);
        return;
    }
    let dir = PathBuf::from("artifacts");
    let manifest = dir.join("models/synth_cxr.json");
    let (engine, images, source) = if manifest.exists() {
        let engine =
            Engine::load(&manifest, &dir.join("models/synth_cxr_dpe.cpt"))
                .unwrap();
        let test =
            Bundle::load(&dir.join("models/synth_cxr_testset.cpt")).unwrap();
        let xs = test.get("x").unwrap().as_f32().unwrap();
        let n = if smoke { 16usize } else { 64 };
        let images: Vec<Tensor> = (0..n)
            .map(|i| {
                Tensor::new(
                    &[1, 64, 64],
                    xs[i * 64 * 64..(i + 1) * 64 * 64].to_vec(),
                )
            })
            .collect();
        (engine, images, "trained artifacts")
    } else {
        println!("artifacts missing — using the synthetic in-memory model");
        let n = if smoke { 16 } else { 64 };
        (synthetic_engine(), synthetic_images(n), "synthetic model")
    };
    let engine = Arc::new(engine);
    let n = images.len();
    println!("serving bench over {n} images ({source}, smoke={smoke})");

    section("bare engine loop (digital, per image) — baseline");
    let t0 = Instant::now();
    let mut be = Backend::Digital;
    for im in &images {
        let _ = engine.forward(im, &mut be).unwrap();
    }
    let bare = t0.elapsed().as_secs_f64();
    row("bare loop", &[
        ("req_s", format!("{:.1}", n as f64 / bare)),
        ("total_s", format!("{bare:.3}")),
    ]);
    rep.metric("bare_loop_req_s", n as f64 / bare);

    section("batch-major forward_batch sweep (digital) vs per-image loop");
    for batch in [1usize, 2, 4, 8, 16, 32, 64] {
        if batch > n {
            break;
        }
        let mut be = Backend::Digital;
        let t0 = Instant::now();
        for chunk in images.chunks(batch) {
            let _ = engine.forward_batch(chunk, &mut be).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        row(&format!("forward_batch b={batch}"), &[
            ("img_s", format!("{:.1}", n as f64 / wall)),
            ("speedup_vs_loop", format!("{:.2}x", bare / wall)),
        ]);
        rep.metric(&format!("digital_b{batch}_img_s"), n as f64 / wall);
    }

    section("batch-major forward_batch sweep (deterministic photonic sim)");
    for batch in [1usize, 8, 32] {
        if batch > n {
            break;
        }
        let mut be = Backend::PhotonicSim(ChipSim::deterministic(
            ChipDescription::ideal(4),
        ));
        let t0 = Instant::now();
        for chunk in images.chunks(batch) {
            let _ = engine.forward_batch(chunk, &mut be).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let (passes, tiles) = match &be {
            Backend::PhotonicSim(sim) => (sim.passes(), sim.tiles_executed),
            Backend::Digital => unreachable!(),
        };
        row(&format!("photonic b={batch}"), &[
            ("img_s", format!("{:.1}", n as f64 / wall)),
            ("chip_passes", format!("{passes}")),
            ("tiles", format!("{tiles}")),
        ]);
        rep.metric(&format!("photonic_b{batch}_img_s"), n as f64 / wall);
        rep.metric(&format!("photonic_b{batch}_chip_passes"), passes as f64);
    }
    // allocs-per-batch proxy: this driver thread's scratch counters after
    // the photonic sweep (planned path; warm pools stop missing)
    let st = scratch::stats();
    rep.metric("scratch_takes", st.takes as f64);
    rep.metric("scratch_misses", st.misses as f64);

    section("coordinator overhead (1 digital worker, batch 8)");
    let engine2 = Arc::clone(&engine);
    let coord = Coordinator::start(
        vec![Box::new(move || {
            Box::new(EngineBackend { engine: engine2, mode: Backend::Digital })
                as Box<dyn cirptc::coordinator::InferenceBackend>
        })],
        BatcherConfig { max_batch: 8, max_wait_us: 500, queue_cap: 0 },
    );
    let t0 = Instant::now();
    coord.classify_all(&images).unwrap();
    let coord_s = t0.elapsed().as_secs_f64();
    row("coordinator", &[
        ("req_s", format!("{:.1}", n as f64 / coord_s)),
        ("overhead_pct", format!("{:.1}", 100.0 * (coord_s - bare) / bare)),
        ("target", "<10%".into()),
    ]);
    println!("  {}", obs::render_report(&coord.metrics, &[], false));
    let (p50, p99) = coord.metrics.latency_percentiles_us();
    rep.metric("coordinator_req_s", n as f64 / coord_s);
    rep.metric("coordinator_p50_us", p50 as f64);
    rep.metric("coordinator_p99_us", p99 as f64);
    rep.metric(
        "worker_scratch_misses",
        coord.metrics.scratch_misses.get() as f64,
    );
    rep.metric(
        "worker_scratch_takes",
        coord.metrics.scratch_takes.get() as f64,
    );
    drop(coord);

    section("pipelined vs sequential serving (photonic, 1 worker)");
    // same engine, same deterministic chip, same batch policy: the only
    // difference is the worker loop — monolithic forward_batch vs the
    // pre/chip/post stage pipeline (batch i+1's electronic operand prep
    // overlaps batch i's chip passes, bit-identical by construction)
    let photonic_chip = || ChipSim::deterministic(ChipDescription::ideal(4));
    let reps = if smoke { 3 } else { 4 };
    let mut best_speedup = 0.0f64;
    let mut pipe_rps_b8 = 0.0f64;
    for batch in [8usize, 32] {
        if batch > n {
            continue;
        }
        let measure = |pipelined: bool| -> (f64, Arc<Metrics>) {
            let coord = if pipelined {
                let engine = Arc::clone(&engine);
                Coordinator::start_pipelined(
                    vec![Box::new(move || {
                        Staged::new(
                            EngineSource::Fixed(engine),
                            Backend::PhotonicSim(photonic_chip()),
                        )
                    }) as StagedFactory],
                    BatcherConfig {
                        max_batch: batch,
                        max_wait_us: 2_000,
                        queue_cap: 0,
                    },
                )
            } else {
                let engine = Arc::clone(&engine);
                Coordinator::start(
                    vec![Box::new(move || {
                        Box::new(EngineBackend {
                            engine,
                            mode: Backend::PhotonicSim(photonic_chip()),
                        })
                            as Box<dyn InferenceBackend>
                    }) as BackendFactory],
                    BatcherConfig {
                        max_batch: batch,
                        max_wait_us: 2_000,
                        queue_cap: 0,
                    },
                )
            };
            // warm: plan caches, scratch arenas, encoded chip tiles
            coord.classify_all(&images[..batch.min(n)]).unwrap();
            let t0 = Instant::now();
            for _ in 0..reps {
                coord.classify_all(&images).unwrap();
            }
            (t0.elapsed().as_secs_f64(), Arc::clone(&coord.metrics))
        };
        let (seq_s, _) = measure(false);
        let (pipe_s, pm) = measure(true);
        let served = (n * reps) as f64;
        let speedup = seq_s / pipe_s;
        best_speedup = best_speedup.max(speedup);
        row(&format!("photonic b={batch}"), &[
            ("seq_img_s", format!("{:.1}", served / seq_s)),
            ("pipe_img_s", format!("{:.1}", served / pipe_s)),
            ("speedup", format!("{speedup:.2}x")),
            (
                "stage_p99_us (pre/chip/post)",
                format!(
                    "≤{}/≤{}/≤{}",
                    pm.stage_pre_us.percentile(0.99),
                    pm.stage_chip_us.percentile(0.99),
                    pm.stage_post_us.percentile(0.99)
                ),
            ),
        ]);
        rep.metric(&format!("pipelined_speedup_photonic_b{batch}"), speedup);
        rep.metric(
            &format!("pipelined_photonic_b{batch}_img_s"),
            served / pipe_s,
        );
        if batch == 8 {
            pipe_rps_b8 = served / pipe_s;
            rep.metric(
                "stage_pre_p99_us",
                pm.stage_pre_us.percentile(0.99) as f64,
            );
            rep.metric(
                "stage_chip_p99_us",
                pm.stage_chip_us.percentile(0.99) as f64,
            );
            rep.metric(
                "stage_post_p99_us",
                pm.stage_post_us.percentile(0.99) as f64,
            );
            rep.metric(
                "batch_wait_p99_us",
                pm.batch_wait_us.percentile(0.99) as f64,
            );
        }
    }
    rep.metric("pipelined_speedup_photonic_best", best_speedup);

    section("open-loop Poisson traffic (pipelined photonic, admission ctl)");
    // arrivals are scheduled on a wall clock independent of completions
    // (open loop), at fractions of the capacity just measured — so the
    // load points mean the same thing on any machine.  The SLO budget is
    // likewise relative: 20 batch-times at b=8.
    let capacity_rps = pipe_rps_b8.max(1.0);
    let batch_time_us = 8.0 * 1e6 / capacity_rps;
    let budget_us = (20.0 * batch_time_us) as u64;
    let loads: &[f64] = if smoke { &[0.8] } else { &[0.5, 0.8, 0.95] };
    for &load in loads {
        let rate = capacity_rps * load;
        let n_req = if smoke { 64 } else { 256 };
        let engine2 = Arc::clone(&engine);
        let coord = Coordinator::start_pipelined(
            vec![Box::new(move || {
                Staged::new(
                    EngineSource::Fixed(engine2),
                    Backend::PhotonicSim(photonic_chip()),
                )
            }) as StagedFactory],
            // bounded queue: above-capacity bursts shed at the door
            // instead of queueing past the deadline
            BatcherConfig { max_batch: 8, max_wait_us: 2_000, queue_cap: 64 },
        );
        let mut rng = Rng::new(0x9015_5011);
        let mut accepted = Vec::with_capacity(n_req);
        let mut shed = 0usize;
        let mut due = 0.0f64;
        let t0 = Instant::now();
        for i in 0..n_req {
            due += -(1.0 - rng.f64()).ln() / rate;
            let target = Duration::from_secs_f64(due);
            let now = t0.elapsed();
            if target > now {
                std::thread::sleep(target - now);
            }
            let adm = coord.submit(images[i % n].clone());
            if adm.is_shed() {
                shed += 1;
            } else {
                accepted.push(adm);
            }
        }
        let n_acc = accepted.len();
        for adm in accepted {
            adm.wait().expect("accepted request must complete");
        }
        let (p50, p99) = coord.metrics.latency_percentiles_us();
        let headroom = budget_us as f64 / p99.max(1) as f64;
        let accept_frac = n_acc as f64 / n_req as f64;
        row(&format!("load={:.2}", load), &[
            ("rps", format!("{rate:.1}")),
            ("p50_us", format!("{p50}")),
            ("p99_us", format!("{p99}")),
            ("shed", format!("{shed}")),
            ("slo_headroom", format!("{headroom:.2}")),
        ]);
        if (load - 0.8).abs() < 1e-9 {
            rep.metric("poisson_p99_us_load80", p99 as f64);
            rep.metric("poisson_slo_headroom_load80", headroom);
            rep.metric("poisson_accept_frac_load80", accept_frac);
        }
        drop(coord);
    }

    section("farm scaling: partitioned engine over N chips (1 thread/chip)");
    // one photonic chip is one fixed-rate compute lane, so this section
    // pins engine.threads = 1: the single-chip baseline walks every
    // block-row serially, while an N-chip partition runs N row-shard
    // passes concurrently on its own lanes.  The result is bit-identical
    // across widths by construction (propchecked in tests/farm_e2e.rs);
    // this section only prices the shard fan-out + electronic reduce.
    let fe = {
        let mut e = farm_engine();
        e.threads = 1;
        Arc::new(e)
    };
    let fimgs = synthetic_images(if smoke { 8 } else { 32 });
    let fcount = fimgs.len();
    let freps = if smoke { 2 } else { 4 };
    let farm_chip = || {
        Backend::PhotonicSim(ChipSim::deterministic(ChipDescription::ideal(4)))
    };
    let single_s = {
        let mut be = farm_chip();
        // warm: FFT plans, encoded chip tiles, scratch arenas
        fe.forward_batch(&fimgs, &mut be).unwrap();
        let t0 = Instant::now();
        for _ in 0..freps {
            fe.forward_batch(&fimgs, &mut be).unwrap();
        }
        t0.elapsed().as_secs_f64()
    };
    row("single chip", &[
        ("img_s", format!("{:.1}", (fcount * freps) as f64 / single_s)),
    ]);
    for chips_n in [1usize, 2, 4] {
        let plan = PartitionPlan::plan(&fe.manifest, chips_n);
        let part = PartitionedEngine::new(Arc::clone(&fe), plan).unwrap();
        let mut chips: Vec<Backend> =
            (0..chips_n).map(|_| farm_chip()).collect();
        part.forward_batch(&fimgs, &mut chips).unwrap();
        let t0 = Instant::now();
        for _ in 0..freps {
            part.forward_batch(&fimgs, &mut chips).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let speedup = single_s / wall;
        row(&format!("farm n={chips_n}"), &[
            ("img_s", format!("{:.1}", (fcount * freps) as f64 / wall)),
            ("speedup_vs_single", format!("{speedup:.2}x")),
        ]);
        rep.metric(
            &format!("farm_n{chips_n}_img_s"),
            (fcount * freps) as f64 / wall,
        );
        if chips_n == 4 {
            rep.metric("farm_speedup_n4", speedup);
        }
    }

    section("farm failover: 3 replica members, one forced Failed");
    // identical fixed members; the router's health preference order
    // reroutes around the failed chip with zero drops, and the metric
    // pins the fraction of healthy throughput that survives
    let fmetrics = Arc::new(Metrics::default());
    let members: Vec<FarmMember> = (0..3)
        .map(|_| FarmMember::fixed(Arc::clone(&engine), farm_chip()))
        .collect();
    let farm = Farm::start(
        members,
        FarmConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait_us: 2_000,
                queue_cap: 0,
            },
            ..FarmConfig::default()
        },
        Arc::clone(&fmetrics),
    );
    // two warm rounds so round-robin touches every member pipeline
    farm.coord.classify_all(&images).unwrap();
    farm.coord.classify_all(&images).unwrap();
    let t0 = Instant::now();
    for _ in 0..freps {
        farm.coord.classify_all(&images).unwrap();
    }
    let healthy_s = t0.elapsed().as_secs_f64();
    farm.status[1].fail();
    let t0 = Instant::now();
    for _ in 0..freps {
        farm.coord.classify_all(&images).unwrap();
    }
    let failed_s = t0.elapsed().as_secs_f64();
    let retained = healthy_s / failed_s;
    assert_eq!(fmetrics.errors.get(), 0, "farm failover dropped requests");
    row("failover", &[
        ("healthy_img_s", format!("{:.1}", (n * freps) as f64 / healthy_s)),
        ("failed_img_s", format!("{:.1}", (n * freps) as f64 / failed_s)),
        ("throughput_retained", format!("{retained:.2}")),
        ("rerouted", format!("{}", fmetrics.farm_rerouted.get())),
        ("transitions", format!("{}", fmetrics.farm_transitions.get())),
    ]);
    rep.metric("farm_reroute_overhead", retained);
    println!("  {}", obs::render_report(&fmetrics, &[], false));
    drop(farm);

    section("tracing overhead: recorder installed + disabled vs no recorder");
    // A/A throughput comparison over the identical coordinator
    // construction: arm 1 runs with no recorder installed, arm 2 installs
    // one and leaves it *disabled* — the production configuration of a
    // binary built with tracing support but not asked to trace, where
    // every span site degrades to one relaxed atomic load.  The floor
    // pins the disabled-tracing penalty at < 5% (enabled is reported for
    // information only; it pays ring-buffer writes by design).
    let overhead_reps = if smoke { 2 } else { 4 };
    let measure_rps = || -> f64 {
        let engine2 = Arc::clone(&engine);
        let coord = Coordinator::start(
            vec![Box::new(move || {
                Box::new(EngineBackend {
                    engine: engine2,
                    mode: Backend::Digital,
                }) as Box<dyn InferenceBackend>
            })],
            BatcherConfig { max_batch: 8, max_wait_us: 500, queue_cap: 0 },
        );
        coord.classify_all(&images).unwrap(); // warm
        let mut best = 0.0f64;
        for _ in 0..3 {
            let t0 = Instant::now();
            for _ in 0..overhead_reps {
                coord.classify_all(&images).unwrap();
            }
            best = best
                .max((n * overhead_reps) as f64 / t0.elapsed().as_secs_f64());
        }
        best
    };
    let base_rps = measure_rps();
    // install the recorder (process-global, sticky) but leave it disabled
    trace::install(trace::TraceRecorder::new(1 << 14));
    trace::set_enabled(false);
    let disabled_rps = measure_rps();
    trace::set_enabled(true);
    let enabled_rps = measure_rps();
    trace::set_enabled(false);
    let frac = disabled_rps / base_rps.max(1e-9);
    row("tracing", &[
        ("base_req_s", format!("{base_rps:.1}")),
        ("disabled_req_s", format!("{disabled_rps:.1}")),
        ("enabled_req_s", format!("{enabled_rps:.1}")),
        ("disabled_frac", format!("{frac:.3}")),
        ("target", "≥0.95".into()),
    ]);
    rep.metric("trace_overhead_frac", frac);
    rep.metric("trace_enabled_frac", enabled_rps / base_rps.max(1e-9));

    section("chaos: supervised farm under a seeded fault plan");
    // every member rides the same episode schedule on its own noise
    // stream, so the DeadChip window is a total-loss window: the run
    // exercises probe-driven quarantine, batch retry, degradation to the
    // digital fallback lane, and probation restore.  The floored metric
    // pins the completed/submitted fraction at exactly 1.0 — the
    // self-healing loop may never drop a request.
    let cmetrics = Arc::new(Metrics::default());
    let cimgs = synthetic_images(32);
    let episodes = vec![
        Episode { start_pass: 8, duration: 50, kind: FaultKind::DeadChip },
        Episode {
            start_pass: 4,
            duration: 40,
            kind: FaultKind::TransientPassError { p: 0.5 },
        },
    ];
    let mut cmembers = Vec::new();
    for k in 0..3usize {
        let cengine = synthetic_engine();
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.01;
        desc.seed = 0xBE ^ k as u64;
        let mut sim = ChipSim::deterministic(desc.clone());
        sim.set_fault(FaultPlan::new(0xC405 ^ k as u64, episodes.clone()));
        // monitor-only: probe every batch for the supervisor, never
        // request a recalibration (nothing services the channel here)
        let monitor = DriftMonitor::new(
            MonitorConfig {
                probe_every: 1,
                residual_trigger: f32::INFINITY,
                ..MonitorConfig::default()
            },
            &desc,
        );
        let (member, recal_rx) = FarmMember::supervised(
            cengine,
            sim,
            monitor,
            ChipSupervisor::new(SupervisorConfig {
                residual_ceiling: 0.05,
                consecutive_failures: 2,
                probation_probes: 2,
                max_probations: 100_000,
            }),
            DEFAULT_DRIFTING_PPM,
            Duration::from_millis(2),
            Arc::clone(&cmetrics),
        );
        drop(recal_rx);
        cmembers.push(member);
    }
    let cstatus: Vec<_> =
        cmembers.iter().map(|m| Arc::clone(&m.status)).collect();
    let cfb_engine = Arc::new(synthetic_engine());
    let cfallback: cirptc::coordinator::worker::BackendFactory =
        Box::new(move || {
            Box::new(EngineBackend {
                engine: cfb_engine,
                mode: Backend::Digital,
            }) as Box<dyn InferenceBackend>
        });
    let cfarm = Farm::start_with_fallback(
        cmembers,
        Some(cfallback),
        FarmConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait_us: 2_000,
                queue_cap: 0,
            },
            ..FarmConfig::default()
        },
        Arc::clone(&cmetrics),
    );
    let cdeadline = Instant::now() + Duration::from_secs(180);
    let mut healed = false;
    while Instant::now() < cdeadline {
        cfarm.coord.classify_all(&cimgs).unwrap();
        let serving = cstatus
            .iter()
            .filter(|st| st.health() != ChipHealth::Failed)
            .count();
        if cmetrics.quarantines.get() >= 1
            && cmetrics.retries.get() >= 1
            && serving >= 2
        {
            healed = true;
            break;
        }
    }
    assert!(healed, "chaos farm never healed: {}", cmetrics.summary());
    cfarm.coord.classify_all(&cimgs).unwrap();
    let recovery = cmetrics.completed.get() as f64
        / cmetrics.submitted.get().max(1) as f64;
    row("chaos", &[
        ("recovery_frac", format!("{recovery:.3}")),
        ("retries", format!("{}", cmetrics.retries.get())),
        ("quarantines", format!("{}", cmetrics.quarantines.get())),
        ("degraded", format!("{}", cmetrics.degraded_batches.get())),
    ]);
    rep.metric("chaos_recovery_frac", recovery);
    println!("  {}", obs::render_report(&cmetrics, &[], false));
    drop(cfarm);

    if smoke {
        println!("\nsmoke mode: skipping policy sweep + worker scaling");
        rep.save(&workspace_path("BENCH_serving.json"))
            .expect("write BENCH_serving.json");
        return;
    }

    section("batch-size sweep (2 digital workers)");
    for batch in [1usize, 2, 4, 8, 16] {
        let factories: Vec<BackendFactory> = (0..2)
            .map(|_| {
                let engine = Arc::clone(&engine);
                Box::new(move || {
                    Box::new(EngineBackend { engine, mode: Backend::Digital })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let coord = Coordinator::start(
            factories,
            BatcherConfig { max_batch: batch, max_wait_us: 400, queue_cap: 0 },
        );
        let t0 = Instant::now();
        coord.classify_all(&images).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let (p50, p99) = coord.metrics.latency_percentiles_us();
        row(&format!("batch={batch}"), &[
            ("req_s", format!("{:.1}", n as f64 / wall)),
            ("p50_us", format!("{p50}")),
            ("p99_us", format!("{p99}")),
            ("mean_batch", format!("{:.1}", coord.metrics.mean_batch_size())),
            (
                "batch_p99_us",
                format!("≤{}", coord.metrics.batch_compute_us.percentile(0.99)),
            ),
        ]);
    }

    section("worker scaling (digital, batch 8)");
    for workers in [1usize, 2, 4] {
        let factories: Vec<BackendFactory> = (0..workers)
            .map(|_| {
                let engine = Arc::clone(&engine);
                Box::new(move || {
                    Box::new(EngineBackend { engine, mode: Backend::Digital })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let coord = Coordinator::start(
            factories,
            BatcherConfig { max_batch: 8, max_wait_us: 400, queue_cap: 0 },
        );
        let t0 = Instant::now();
        coord.classify_all(&images).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        row(&format!("workers={workers}"), &[(
            "req_s",
            format!("{:.1}", n as f64 / wall),
        )]);
    }

    if args.has("drift") {
        drift_scenario(false);
    } else {
        println!("\n(drifting-chip scenario sweep: re-run with -- --drift)");
    }

    rep.save(&workspace_path("BENCH_serving.json"))
        .expect("write BENCH_serving.json");
}
