//! Bench: L3 serving — batch-major engine throughput and coordinator
//! overhead (DESIGN.md §4, §9).
//!
//! Sections:
//!   1. per-image `forward()` loop — the pre-batching baseline;
//!   2. batch-major `forward_batch` sweep — one layer-graph walk and one
//!      multi-column BCM multiply per layer per batch (the acceptance
//!      check: images/sec at batch ≥ 8 must beat the per-image loop);
//!   3. coordinator overhead + batching-policy sweep + worker scaling.
//!
//! Runs against trained artifacts when present (`make train-py`), otherwise
//! falls back to a synthetic in-memory model so the serving path is
//! always exercised (CI bench smoke: `cargo bench --bench serving --
//! --smoke`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use cirptc::coordinator::worker::EngineBackend;
use cirptc::coordinator::{BackendFactory, BatcherConfig, Coordinator};
use cirptc::data::Bundle;
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::util::bench::{row, section};
use cirptc::util::cli::Args;
use cirptc::util::rng::Rng;

/// Synthetic circ model (conv→relu→pool→flatten→fc on 32×32 inputs) so
/// the bench runs without trained artifacts.
fn synthetic_engine() -> Engine {
    let manifest = Manifest::parse(
        r#"{
          "dataset": "synth_bench", "classes": 4,
          "layers": [
            {"kind": "conv", "cin": 1, "cout": 8, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "fc", "cin": 2048, "cout": 4, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0}
          ]}"#,
    )
    .unwrap();
    let mut bundle = Bundle::default();
    let mut rng = Rng::new(17);
    // conv: cout 8 -> P=2, n_in 9 -> Q=3
    let mut w0 = vec![0.0f32; 2 * 3 * 4];
    rng.fill_uniform(&mut w0);
    for v in w0.iter_mut() {
        *v = (*v - 0.5) * 0.5;
    }
    bundle.insert_f32("layer0.w", &[2, 3, 4], w0);
    bundle.insert_f32("layer0.b", &[8], vec![0.0; 8]);
    // fc: 2048 -> 4: P=1, Q=512
    let mut w4 = vec![0.0f32; 512 * 4];
    rng.fill_uniform(&mut w4);
    for v in w4.iter_mut() {
        *v = (*v - 0.5) * 0.1;
    }
    bundle.insert_f32("layer4.w", &[1, 512, 4], w4);
    bundle.insert_f32("layer4.b", &[4], vec![0.1, 0.2, 0.3, 0.4]);
    Engine::from_parts(manifest, &bundle).unwrap()
}

fn synthetic_images(n: usize) -> Vec<Tensor> {
    let mut rng = Rng::new(18);
    (0..n)
        .map(|_| {
            let mut d = vec![0.0f32; 32 * 32];
            rng.fill_uniform(&mut d);
            Tensor::new(&[1, 32, 32], d)
        })
        .collect()
}

fn main() {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let dir = PathBuf::from("artifacts");
    let manifest = dir.join("models/synth_cxr.json");
    let (engine, images, source) = if manifest.exists() {
        let engine =
            Engine::load(&manifest, &dir.join("models/synth_cxr_dpe.cpt"))
                .unwrap();
        let test =
            Bundle::load(&dir.join("models/synth_cxr_testset.cpt")).unwrap();
        let xs = test.get("x").unwrap().as_f32().unwrap();
        let n = if smoke { 16usize } else { 64 };
        let images: Vec<Tensor> = (0..n)
            .map(|i| {
                Tensor::new(
                    &[1, 64, 64],
                    xs[i * 64 * 64..(i + 1) * 64 * 64].to_vec(),
                )
            })
            .collect();
        (engine, images, "trained artifacts")
    } else {
        println!("artifacts missing — using the synthetic in-memory model");
        let n = if smoke { 16 } else { 64 };
        (synthetic_engine(), synthetic_images(n), "synthetic model")
    };
    let engine = Arc::new(engine);
    let n = images.len();
    println!("serving bench over {n} images ({source}, smoke={smoke})");

    section("bare engine loop (digital, per image) — baseline");
    let t0 = Instant::now();
    let mut be = Backend::Digital;
    for im in &images {
        let _ = engine.forward(im, &mut be).unwrap();
    }
    let bare = t0.elapsed().as_secs_f64();
    row("bare loop", &[
        ("req_s", format!("{:.1}", n as f64 / bare)),
        ("total_s", format!("{bare:.3}")),
    ]);

    section("batch-major forward_batch sweep (digital) vs per-image loop");
    for batch in [1usize, 2, 4, 8, 16, 32, 64] {
        if batch > n {
            break;
        }
        let mut be = Backend::Digital;
        let t0 = Instant::now();
        for chunk in images.chunks(batch) {
            let _ = engine.forward_batch(chunk, &mut be).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        row(&format!("forward_batch b={batch}"), &[
            ("img_s", format!("{:.1}", n as f64 / wall)),
            ("speedup_vs_loop", format!("{:.2}x", bare / wall)),
        ]);
    }

    section("batch-major forward_batch sweep (deterministic photonic sim)");
    for batch in [1usize, 8, 32] {
        if batch > n {
            break;
        }
        let mut be = Backend::PhotonicSim(ChipSim::deterministic(
            ChipDescription::ideal(4),
        ));
        let t0 = Instant::now();
        for chunk in images.chunks(batch) {
            let _ = engine.forward_batch(chunk, &mut be).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let (passes, tiles) = match &be {
            Backend::PhotonicSim(sim) => (sim.passes(), sim.tiles_executed),
            Backend::Digital => unreachable!(),
        };
        row(&format!("photonic b={batch}"), &[
            ("img_s", format!("{:.1}", n as f64 / wall)),
            ("chip_passes", format!("{passes}")),
            ("tiles", format!("{tiles}")),
        ]);
    }

    section("coordinator overhead (1 digital worker, batch 8)");
    let engine2 = Arc::clone(&engine);
    let coord = Coordinator::start(
        vec![Box::new(move || {
            Box::new(EngineBackend { engine: engine2, mode: Backend::Digital })
                as Box<dyn cirptc::coordinator::InferenceBackend>
        })],
        BatcherConfig { max_batch: 8, max_wait_us: 500 },
    );
    let t0 = Instant::now();
    coord.classify_all(&images).unwrap();
    let coord_s = t0.elapsed().as_secs_f64();
    row("coordinator", &[
        ("req_s", format!("{:.1}", n as f64 / coord_s)),
        ("overhead_pct", format!("{:.1}", 100.0 * (coord_s - bare) / bare)),
        ("target", "<10%".into()),
    ]);
    println!("  metrics: {}", coord.metrics.summary());
    drop(coord);

    if smoke {
        println!("\nsmoke mode: skipping policy sweep + worker scaling");
        return;
    }

    section("batch-size sweep (2 digital workers)");
    for batch in [1usize, 2, 4, 8, 16] {
        let factories: Vec<BackendFactory> = (0..2)
            .map(|_| {
                let engine = Arc::clone(&engine);
                Box::new(move || {
                    Box::new(EngineBackend { engine, mode: Backend::Digital })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let coord = Coordinator::start(
            factories,
            BatcherConfig { max_batch: batch, max_wait_us: 400 },
        );
        let t0 = Instant::now();
        coord.classify_all(&images).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let (p50, p99) = coord.metrics.latency_percentiles_us();
        row(&format!("batch={batch}"), &[
            ("req_s", format!("{:.1}", n as f64 / wall)),
            ("p50_us", format!("{p50}")),
            ("p99_us", format!("{p99}")),
            ("mean_batch", format!("{:.1}", coord.metrics.mean_batch_size())),
            (
                "batch_p99_us",
                format!("≤{}", coord.metrics.batch_compute_us.percentile(0.99)),
            ),
        ]);
    }

    section("worker scaling (digital, batch 8)");
    for workers in [1usize, 2, 4] {
        let factories: Vec<BackendFactory> = (0..workers)
            .map(|_| {
                let engine = Arc::clone(&engine);
                Box::new(move || {
                    Box::new(EngineBackend { engine, mode: Backend::Digital })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let coord = Coordinator::start(
            factories,
            BatcherConfig { max_batch: 8, max_wait_us: 400 },
        );
        let t0 = Instant::now();
        coord.classify_all(&images).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        row(&format!("workers={workers}"), &[(
            "req_s",
            format!("{:.1}", n as f64 / wall),
        )]);
    }
}
