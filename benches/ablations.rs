//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! * spectral-fold count r — efficiency & density vs the paper's r=4 pick
//! * block order l — compression vs projection error (why order-4)
//! * calibration DAC resolution — residual detuning vs trim granularity
//! * chip-farm scaling — tile-scheduler latency vs number of chips
//! * nonideality sensitivity — output error vs crosstalk ε and noise σ

use cirptc::analysis::{AreaModel, PowerModel, WeightTech};
use cirptc::arch::calibration::Calibration;
use cirptc::arch::{CirPtcConfig, WavelengthPlan};
use cirptc::circulant::Bcm;
use cirptc::coordinator::scheduler::TileScheduler;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::util::bench::{row, section};
use cirptc::util::rng::Rng;

fn main() {
    let power = PowerModel::paper();
    let area = AreaModel::paper();

    section("fold count r (48x48 physical, thermo weights)");
    for r in [1usize, 2, 4, 8] {
        let c = CirPtcConfig { n: 48, m: 48, l: 4, fold: r, f_op: 10e9 };
        row(&format!("r={r}"), &[
            ("tops", format!("{:.1}", c.ops() / 1e12)),
            ("tops_w", format!("{:.2}",
                power.efficiency_tops_w(&c, WeightTech::ThermoOptic))),
            ("tops_mm2", format!("{:.2}", area.computing_density_tops_mm2(&c))),
            ("laser_lines", format!("{}", c.effective_n())),
        ]);
    }
    println!("  (paper picks r=4: efficiency gain saturates as MRR thermal \
              dominates, Fig. S18b)");

    section("block order l: compression vs dense-projection error");
    let mut rng = Rng::new(5);
    let mut dense_data = vec![0.0f32; 64 * 64];
    rng.fill_uniform(&mut dense_data);
    let dense = Tensor::new(&[64, 64], dense_data);
    for l in [2usize, 4, 8, 16] {
        let b = Bcm::project_dense(&dense, l);
        let back = b.expand();
        let err = back.max_abs_diff(&dense);
        row(&format!("l={l}"), &[
            ("params", format!("{}", b.params())),
            ("compression", format!("{:.1}%", 100.0 * (1.0 - b.compression()))),
            ("projection_err", format!("{err:.3}")),
        ]);
    }
    println!("  (training embeds the constraint instead of projecting — the \
              error column shows why naive conversion fails and why l=4 \
              balances compression vs expressivity)");

    section("calibration DAC step vs residual detuning (8x8 crossbar)");
    let plan = WavelengthPlan::uniform(4, 1545.0, 38.0);
    let mut r = Rng::new(6);
    let offsets: Vec<f64> = (0..64).map(|_| r.normal() * 0.4).collect();
    for step in [0.05, 0.02, 0.01, 0.005, 0.001] {
        let cal = Calibration::run(&plan, 8, 8, &offsets, 0.25, step);
        row(&format!("dac_step={step}nm"), &[
            ("worst_residual_nm", format!("{:.4}", cal.worst_residual_nm())),
            ("trim_mw", format!("{:.1}", cal.total_trim_mw())),
        ]);
    }

    section("tile-scheduler scaling (192x192 BCM on 48x48 chips, batch 32)");
    for chips in [1usize, 2, 4, 8] {
        let sched = TileScheduler::new(CirPtcConfig::scaled_48(), chips);
        let s = sched.schedule(48, 48); // 192/4 blocks each way
        let cycles = sched.estimated_cycles(&s, 32, 10);
        row(&format!("chips={chips}"), &[
            ("tiles", format!("{}", s.tiles.len())),
            ("cycles", format!("{cycles}")),
            ("speedup", format!("{:.2}x",
                TileScheduler::new(CirPtcConfig::scaled_48(), 1)
                    .estimated_cycles(&TileScheduler::new(
                        CirPtcConfig::scaled_48(), 1).schedule(48, 48), 32, 10)
                    as f64 / cycles as f64)),
        ]);
    }

    section("nonideality sensitivity: max output error vs ε / σ (48x48)");
    let mut w = vec![0.0f32; 12 * 12 * 4];
    Rng::new(7).fill_uniform(&mut w);
    let bcm = Bcm::new(12, 12, 4, w);
    let mut xd = vec![0.0f32; 48 * 8];
    Rng::new(8).fill_uniform(&mut xd);
    let x = Tensor::new(&[48, 8], xd);
    let ideal = bcm.matmul(&x);
    for eps in [0.0f32, 0.01, 0.02, 0.05, 0.1] {
        let mut d = ChipDescription::ideal(4);
        d.w_bits = 6;
        d.x_bits = 4;
        // build ε-crosstalk Γ (row-normalised)
        for i in 0..4usize {
            let mut sum = 0.0f32;
            let mut vals = [0.0f32; 4];
            for (j, v) in vals.iter_mut().enumerate() {
                *v = eps.powi((i as i32 - j as i32).abs());
                sum += *v;
            }
            for j in 0..4 {
                d.gamma[i * 4 + j] = vals[j] / sum;
            }
        }
        let mut sim = ChipSim::deterministic(d);
        let y = sim.forward(&bcm, &x);
        row(&format!("eps={eps}"), &[(
            "max_err",
            format!("{:.4}", y.max_abs_diff(&ideal)),
        )]);
    }
    println!("  (the DPE's Γ̂ absorbs exactly this deterministic component — \
              paper Fig. 4e chip-no-DPE vs chip+DPE gap)");
}
