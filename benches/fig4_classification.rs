//! Bench: paper Fig. 4 — end-to-end classification.
//!
//! Regenerates the Fig. 4e comparison ladder per dataset by combining
//! (a) the python-side training metrics (`artifacts/metrics.json`: fp32
//! GEMM, digital circulant, chip w/o DPE, chip + DPE — configs trained at
//! build time) with (b) a live rust-serving accuracy measurement of the
//! DPE model on the photonic simulator, confirming the exported weights
//! reproduce the python lookup-mode numbers through the L3 stack.

use std::path::PathBuf;
use std::sync::Arc;

use cirptc::coordinator::worker::EngineBackend;
use cirptc::coordinator::{BackendFactory, BatcherConfig, Coordinator};
use cirptc::data::Bundle;
use cirptc::onn::{Backend, Engine};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{argmax, Tensor};
use cirptc::util::bench::{row, section};
use cirptc::util::json::Json;

fn live_accuracy(dir: &PathBuf, model: &str, photonic: bool, limit: usize) -> Option<f64> {
    let manifest = dir.join(format!("models/{model}.json"));
    if !manifest.exists() {
        return None;
    }
    // the DPE bundle serves the photonic path; the digitally-trained
    // bundle serves the digital path (BN calibration is substrate-specific)
    let variant = if photonic { "dpe" } else { "digital" };
    let bundle = dir.join(format!("models/{model}_{variant}.cpt"));
    let bundle = if bundle.exists() {
        bundle
    } else {
        dir.join(format!("models/{model}_dpe.cpt"))
    };
    let engine = Arc::new(Engine::load(&manifest, &bundle).ok()?);
    let chip = ChipDescription::load(&dir.join("chip.json")).ok()?;
    let test = Bundle::load(&dir.join(format!("models/{model}_testset.cpt"))).ok()?;
    let (c, h) = engine.manifest.input_shape();
    let xs = test.get("x").ok()?.as_f32().ok()?;
    let ys = test.get("y").ok()?.as_i32().ok()?;
    let n = ys.len().min(limit);
    let images: Vec<Tensor> = (0..n)
        .map(|i| Tensor::new(&[c, h, h], xs[i * c * h * h..(i + 1) * c * h * h].to_vec()))
        .collect();
    let factories: Vec<BackendFactory> = (0..2)
        .map(|i| {
            let engine = Arc::clone(&engine);
            let mut d = chip.clone();
            d.seed ^= i as u64;
            Box::new(move || {
                let mode = if photonic {
                    Backend::PhotonicSim(ChipSim::new(d))
                } else {
                    Backend::Digital
                };
                Box::new(EngineBackend { engine, mode })
                    as Box<dyn cirptc::coordinator::InferenceBackend>
            }) as BackendFactory
        })
        .collect();
    let coord = Coordinator::start(
        factories,
        BatcherConfig { max_batch: 8, max_wait_us: 1000, queue_cap: 0 },
    );
    let rs = coord.classify_all(&images).ok()?;
    Some(
        rs.iter()
            .zip(&ys[..n])
            .filter(|(r, &y)| argmax(&r.logits) == y as usize)
            .count() as f64
            / n as f64,
    )
}

fn main() {
    let dir = PathBuf::from("artifacts");
    let metrics_path = dir.join("metrics.json");

    section("Fig 4e: accuracy ladder per dataset (python build-time metrics)");
    let metrics = std::fs::read_to_string(&metrics_path)
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    match &metrics {
        Some(j) => {
            for (name, paper) in [
                ("synth_digits", "SVHN 88.08%"),
                ("synth_textures", "CIFAR-10 80.04%"),
                ("synth_cxr", "COVID-QU-Ex 92.6%"),
            ] {
                if let Some(d) = j.get(name) {
                    let g = |k: &str| {
                        d.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN)
                    };
                    row(name, &[
                        ("gemm_fp32", format!("{:.4}", g("acc_gemm_digital"))),
                        ("circ_digital", format!("{:.4}", g("acc_circ_digital"))),
                        ("chip_no_dpe", format!("{:.4}", g("acc_chip_vanilla"))),
                        ("chip_dpe", format!("{:.4}", g("acc_chip_dpe"))),
                        ("paper_chip", paper.into()),
                    ]);
                    if let Some(p) = d.get("params") {
                        row("  param reduction", &[(
                            "pct",
                            format!(
                                "{:.2}% (paper 74.91%)",
                                p.get("reduction_pct")
                                    .and_then(Json::as_f64)
                                    .unwrap_or(f64::NAN)
                            ),
                        )]);
                    }
                }
            }
        }
        None => println!("  metrics.json missing — run `make train-py`"),
    }

    section("Fig 4 live: DPE model served through the rust L3 stack");
    for model in ["synth_cxr", "synth_digits", "synth_textures"] {
        let dig = live_accuracy(&dir, model, false, 96);
        let pho = live_accuracy(&dir, model, true, 96);
        match (dig, pho) {
            (Some(d), Some(p)) => row(model, &[
                ("rust_digital", format!("{d:.4}")),
                ("rust_photonic_sim", format!("{p:.4}")),
            ]),
            _ => println!("  {model}: skipped (run `make train-py`)"),
        }
    }

    section("Fig 4a-d: confusion matrix (chip+DPE, from metrics.json)");
    if let Some(j) = &metrics {
        if let Some(conf) = j
            .get("synth_cxr")
            .and_then(|d| d.get("confusion_chip_dpe"))
            .and_then(Json::as_arr)
        {
            for (i, r) in conf.iter().enumerate() {
                println!("  true {i}: {:?}", r.as_f32_flat());
            }
            let sens = j
                .get("synth_cxr")
                .and_then(|d| d.get("sensitivity_covid"))
                .and_then(Json::as_f64);
            let spec = j
                .get("synth_cxr")
                .and_then(|d| d.get("specificity_covid"))
                .and_then(Json::as_f64);
            row("covid class", &[
                ("sensitivity", format!("{:.3} (paper 0.963)", sens.unwrap_or(f64::NAN))),
                ("specificity", format!("{:.3} (paper 0.980)", spec.unwrap_or(f64::NAN))),
            ]);
        }
    }
}
