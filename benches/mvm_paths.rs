//! Bench: MVM execution-path ablation (DESIGN.md §7 design choices).
//!
//! * **planned vs unplanned** batched Eq. (2) at serving shapes — the
//!   headline of the planned execution path (cached plan + weight
//!   spectra + scratch arena + scoped threads vs the per-call-rebuild
//!   reference); also the measured crossover behind
//!   `circulant::fft::use_fft_path`.
//! * direct compressed BCM multiply vs FFT path (Eq. 2) vs dense expansion
//!   — at the paper's order-4 the direct path should win; FFT crosses over
//!   at large block order (this is the ablation behind choosing the direct
//!   form for the L1 kernel's MXU mapping).
//! * the AOT Pallas artifact via PJRT (per-call overhead included).
//! * photonic-simulator overhead vs bare fp32.
//!
//! Writes `BENCH_mvm.json` (throughput + p50/p99 per kernel, planned
//! speedups, scratch-arena alloc proxy) so the perf trajectory is
//! tracked across PRs; `-- --smoke` runs the planned section only with a
//! reduced budget (the CI bench-smoke step).

use std::path::PathBuf;
use std::time::Duration;

use cirptc::circulant::{fft, Bcm};
#[cfg(feature = "pjrt")]
use cirptc::runtime::Runtime;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::Tensor;
use cirptc::util::bench::{
    bench, bench_cfg, black_box, row, section, workspace_path, JsonReport,
};
use cirptc::util::cli::Args;
use cirptc::util::rng::Rng;
use cirptc::util::scratch;
use cirptc::util::threadpool::ThreadPool;

fn rand_bcm(p: usize, q: usize, l: usize, seed: u64) -> Bcm {
    let mut r = Rng::new(seed);
    let mut w = vec![0.0f32; p * q * l];
    r.fill_uniform(&mut w);
    Bcm::new(p, q, l, w)
}

/// Planned vs unplanned batched Eq. (2) at the serving shapes the
/// acceptance tracks (l=64, B ∈ {8, 32}): the unplanned reference is the
/// PR-4 kernel (plan + weight spectra rebuilt per call, serial), the
/// planned path is what `Engine::forward_batch` now runs.
fn planned_vs_unplanned(rep: &mut JsonReport, smoke: bool) {
    section("planned vs unplanned batched Eq.2 at serving shapes");
    let threads = ThreadPool::default_size();
    let (warmup, iters, budget) = if smoke {
        (2, 20, Duration::from_millis(800))
    } else {
        (3, 40, Duration::from_secs(2))
    };
    let l = 64usize;
    let blocks = 1024 / l; // logical 1024×1024, P = Q = 16
    let bcm = rand_bcm(blocks, blocks, l, 9);
    let plan = fft::plan_for(l);
    let spec = fft::WeightSpectra::new(&bcm, &plan);
    for cols in [8usize, 32] {
        let mut xd = vec![0.0f32; bcm.n() * cols];
        Rng::new(10 + cols as u64).fill_uniform(&mut xd);
        let x = Tensor::new(&[bcm.n(), cols], xd);
        // the two paths must agree bit for bit before we time them
        assert_eq!(
            fft::bcm_mmm_fft_planned(&bcm, &x, &plan, &spec, threads).data,
            bcm.mmm_fft(&x).data,
            "planned path must be bit-identical to the reference"
        );
        let s_unplanned = bench_cfg(
            &format!("unplanned mmm_fft l={l} B={cols}"),
            warmup,
            iters,
            budget,
            &mut || {
                black_box(bcm.mmm_fft(&x));
            },
        );
        let s_planned = bench_cfg(
            &format!("planned   mmm_fft l={l} B={cols} t={threads}"),
            warmup,
            iters,
            budget,
            &mut || {
                black_box(fft::bcm_mmm_fft_planned(
                    &bcm, &x, &plan, &spec, threads,
                ));
            },
        );
        let speedup = s_unplanned.mean_ns / s_planned.mean_ns;
        row(&format!("l={l} B={cols}"), &[
            ("planned_speedup", format!("{speedup:.2}x")),
            ("target", "≥1.5x".into()),
        ]);
        rep.stat(
            &format!("mmm_fft_unplanned_l{l}_b{cols}"),
            &s_unplanned,
            cols as f64,
        );
        rep.stat(
            &format!("mmm_fft_planned_l{l}_b{cols}"),
            &s_planned,
            cols as f64,
        );
        rep.metric(&format!("planned_speedup_l{l}_b{cols}"), speedup);
    }
    let st = scratch::stats();
    rep.metric("scratch_takes", st.takes as f64);
    rep.metric("scratch_misses", st.misses as f64);
}

fn main() {
    let args = Args::parse();
    let mut rep = JsonReport::new("mvm_paths");
    if args.has("smoke") {
        planned_vs_unplanned(&mut rep, true);
        rep.save(&workspace_path("BENCH_mvm.json"))
            .expect("write BENCH_mvm.json");
        return;
    }
    planned_vs_unplanned(&mut rep, false);
    let dir = PathBuf::from("artifacts");

    section("order-4 48x48: direct vs FFT vs dense expansion (batch 16)");
    let bcm = rand_bcm(12, 12, 4, 1);
    let mut r = Rng::new(2);
    let mut xd = vec![0.0f32; 48 * 16];
    r.fill_uniform(&mut xd);
    let x = Tensor::new(&[48, 16], xd.clone());
    let xcol = xd[..48].to_vec();

    let s_direct = bench("direct compressed matmul 48x48xB16", || {
        black_box(bcm.matmul(&x));
    });
    let dense = bcm.expand();
    let s_dense = bench("dense expanded matmul 48x48xB16", || {
        black_box(dense.matmul(&x));
    });
    bench("dense expansion itself", || {
        black_box(bcm.expand());
    });
    let s_fft = bench("fft path (Eq.2) single column x16", || {
        for _ in 0..16 {
            black_box(bcm.mvm_fft(&xcol));
        }
    });
    row("order-4 verdict", &[
        ("direct_vs_dense", format!("{:.2}x", s_dense.mean_ns / s_direct.mean_ns)),
        ("direct_vs_fft", format!("{:.2}x", s_fft.mean_ns / s_direct.mean_ns)),
    ]);
    rep.stat("direct_48x48_b16", &s_direct, 16.0);
    rep.metric("order4_direct_vs_fft", s_fft.mean_ns / s_direct.mean_ns);

    section("FFT crossover with block order (fixed 1024-dim, 1 column)");
    for l in [4usize, 16, 64, 256] {
        let blocks = 1024 / l;
        let b = rand_bcm(blocks.min(16), blocks, l, 3);
        let mut xc = vec![0.0f32; b.n()];
        Rng::new(4).fill_uniform(&mut xc);
        let sd = bench(&format!("direct l={l}"), || {
            black_box(b.mvm(&xc));
        });
        let sf = bench(&format!("fft    l={l}"), || {
            black_box(b.mvm_fft(&xc));
        });
        row(&format!("l={l}"), &[(
            "fft_speedup",
            format!("{:.2}x", sd.mean_ns / sf.mean_ns),
        )]);
        // the measured crossover behind `fft::use_fft_path`
        rep.metric(&format!("fft_speedup_l{l}"), sd.mean_ns / sf.mean_ns);
    }

    section("batched Eq.2 (mmm_fft): one weight-spectrum per block, B columns");
    for l in [16usize, 64] {
        let blocks = 1024 / l;
        let b = rand_bcm(blocks.min(16), blocks, l, 5);
        for cols in [1usize, 8, 32] {
            let mut xd = vec![0.0f32; b.n() * cols];
            Rng::new(6).fill_uniform(&mut xd);
            let x = Tensor::new(&[b.n(), cols], xd);
            let s_direct = bench(&format!("direct  l={l} B={cols}"), || {
                black_box(b.matmul(&x));
            });
            let s_mmm = bench(&format!("mmm_fft l={l} B={cols}"), || {
                black_box(b.mmm_fft(&x));
            });
            // per-column re-FFT baseline: columns pre-split so the timed
            // region measures FFT work, not layout conversion
            let split: Vec<Vec<f32>> = (0..cols)
                .map(|c| (0..b.n()).map(|i| x.data[i * cols + c]).collect())
                .collect();
            let s_percol = bench(&format!("mvm_fft l={l} ×{cols}"), || {
                for col in &split {
                    black_box(b.mvm_fft(col));
                }
            });
            row(&format!("l={l} B={cols}"), &[
                (
                    "mmm_fft_vs_direct",
                    format!("{:.2}x", s_direct.mean_ns / s_mmm.mean_ns),
                ),
                (
                    "mmm_fft_vs_per_col",
                    format!("{:.2}x", s_percol.mean_ns / s_mmm.mean_ns),
                ),
            ]);
        }
    }

    section("threaded direct mmm (block-rows via scoped parallel-for)");
    {
        let b = rand_bcm(32, 32, 16, 7); // 512×512 logical
        let mut xd = vec![0.0f32; b.n() * 64];
        Rng::new(8).fill_uniform(&mut xd);
        let x = Tensor::new(&[b.n(), 64], xd);
        let s1 = bench("mmm 512x512xB64 threads=1", || {
            black_box(b.mmm(&x, 1));
        });
        for t in [2usize, 4, 8] {
            let st = bench(&format!("mmm 512x512xB64 threads={t}"), || {
                black_box(b.mmm(&x, t));
            });
            row(&format!("threads={t}"), &[(
                "speedup",
                format!("{:.2}x", s1.mean_ns / st.mean_ns),
            )]);
        }
    }

    section("photonic-sim overhead vs bare fp32 (48x48, batch 16)");
    let chip = ChipDescription::load(&dir.join("chip.json"))
        .unwrap_or_else(|_| ChipDescription::ideal(4));
    let mut sim = ChipSim::new(chip);
    let s_sim = bench("chip sim forward (quant+Γ+noise)", || {
        black_box(sim.forward(&bcm, &x));
    });
    let mut sim_signed = ChipSim::new(ChipDescription::ideal(4));
    bench("chip sim forward_signed (2 passes)", || {
        black_box(sim_signed.forward_signed(&bcm, &x));
    });
    row("sim overhead", &[(
        "vs_direct",
        format!("{:.2}x", s_sim.mean_ns / s_direct.mean_ns),
    )]);

    section("AOT Pallas artifact via PJRT (includes dispatch overhead)");
    #[cfg(feature = "pjrt")]
    match Runtime::new(&dir) {
        Ok(mut rt) => match rt.load("bcm_48x48_b16") {
            Ok(_) => {
                let wt = Tensor::new(&[12, 12, 4], bcm.w.clone());
                let exe = rt.load("bcm_48x48_b16").unwrap();
                let s_xla = bench("pallas bcm_48x48_b16 via PJRT", || {
                    black_box(exe.run(&[&wt, &x]).unwrap());
                });
                row("xla dispatch", &[(
                    "vs_direct",
                    format!("{:.2}x", s_xla.mean_ns / s_direct.mean_ns),
                )]);
            }
            Err(e) => println!("  skipped: {e:#}"),
        },
        Err(e) => println!("  skipped (PJRT): {e:#}"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("  skipped: pjrt feature disabled (cargo bench --features pjrt)");

    rep.save(&workspace_path("BENCH_mvm.json"))
        .expect("write BENCH_mvm.json");
}
