//! Bench: paper Fig. 3 — on-chip convolution.
//!
//! Regenerates the Fig. 3 rows: normalised RMSE of chip-vs-ideal feature
//! maps over a batch of RGB images (3a–d) and for the four CXR kernels
//! (3e), plus the timing of the on-chip convolution pipeline (im2col →
//! BCM extension → sign-split chip passes) at the prototype data-path
//! granularity.

use std::path::PathBuf;

use cirptc::data::datasets;
use cirptc::data::kernels::{self, extend_kernel};
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{conv2d, im2col, Tensor};
use cirptc::util::bench::{bench, black_box, row, section};

fn chip_convolve(sim: &mut ChipSim, img: &Tensor, k: &kernels::ImageKernel) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    let (oh, ow) = (h - 2, w - 2);
    let bcm = extend_kernel(k, sim.desc.l);
    let mut out = Tensor::zeros(&[c, oh, ow]);
    for ch in 0..c {
        let chan =
            Tensor::new(&[1, h, w], img.data[ch * h * w..(ch + 1) * h * w].to_vec());
        let xm = im2col(&chan, 3);
        let cols = xm.shape[1];
        let mut xp = Tensor::zeros(&[bcm.n(), cols]);
        xp.data[..9 * cols].copy_from_slice(&xm.data);
        let y = sim.forward_signed(&bcm, &xp);
        out.data[ch * oh * ow..(ch + 1) * oh * ow].copy_from_slice(&y.data[..cols]);
    }
    out
}

fn main() {
    let dir = PathBuf::from("artifacts");
    let chip = ChipDescription::load(&dir.join("chip.json"))
        .unwrap_or_else(|_| ChipDescription::ideal(4));

    section("Fig 3a-d: blur kernel over CIFAR-scale RGB images (RMSE)");
    let split = datasets::synth_textures(16, 99);
    let blur = kernels::blur();
    let wmat = kernels::kernels_to_matrix(&[blur.clone()]);
    let mut sim = ChipSim::new(chip.clone());
    let mut rmses = Vec::new();
    for i in 0..split.n {
        let img = split.image(i);
        let got = chip_convolve(&mut sim, &img, &blur);
        let (h, w) = (img.shape[1], img.shape[2]);
        let mut want = Tensor::zeros(&got.shape.clone());
        for ch in 0..3 {
            let chan = Tensor::new(
                &[1, h, w],
                img.data[ch * h * w..(ch + 1) * h * w].to_vec(),
            );
            let y = conv2d(&chan, &wmat, 3, false);
            want.data[ch * y.numel()..(ch + 1) * y.numel()]
                .copy_from_slice(&y.data);
        }
        rmses.push(got.normalized_rmse(&want));
    }
    let mean = rmses.iter().sum::<f32>() / rmses.len() as f32;
    let worst = rmses.iter().cloned().fold(0.0f32, f32::max);
    row("blur/RGB-32x32 (16 images)", &[
        ("rmse_mean", format!("{mean:.4}")),
        ("rmse_worst", format!("{worst:.4}")),
        ("paper", "0.0243".into()),
    ]);

    section("Fig 3e: four kernels on CXR-like image (RMSE, sign-split)");
    let cxr = datasets::synth_cxr(1, 7).image(0);
    for k in kernels::fig3e_kernels() {
        let mut sim = ChipSim::new(chip.clone());
        let got = chip_convolve(&mut sim, &cxr, &k);
        let want = conv2d(&cxr, &kernels::kernels_to_matrix(&[k.clone()]), 3, false);
        row(k.name, &[
            ("rmse", format!("{:.4}", got.normalized_rmse(&want))),
            ("chip_passes", format!("{}", sim.passes())),
        ]);
    }

    section("on-chip conv pipeline timing (32x32 RGB, blur)");
    let img = split.image(0);
    let mut sim = ChipSim::new(chip.clone());
    let s = bench("chip_convolve 3ch 32x32", || {
        black_box(chip_convolve(&mut sim, &img, &blur));
    });
    // each channel: (30*30) MVM columns x 12x4 BCM x 2 sign passes
    let mvms = 3.0 * 900.0 * 2.0;
    row("effective MVM rate", &[
        ("mvms_per_s", format!("{:.0}", s.per_second(mvms))),
        ("paper_prototype", "12.5 Kbaud input rate".into()),
    ]);
}
