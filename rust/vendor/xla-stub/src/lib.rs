//! Offline stub of the `xla` (xla-rs) API surface that `cirptc` uses
//! behind its `pjrt` cargo feature.
//!
//! Purpose: `cargo check -p cirptc --features pjrt` must type-check the
//! XLA execution path hermetically — no network, no `xla_extension`
//! native libraries.  Every PJRT entry point therefore returns a clear
//! runtime error instead of dispatching; deployments repoint the `xla`
//! path dependency in rust/Cargo.toml at the real binding (`[patch]`
//! cannot override a path dependency — see the repo README, §PJRT).
//!
//! Kept deliberately tiny and dependency-free: only the symbols
//! referenced by `cirptc::runtime` and `cirptc::coordinator::worker`
//! exist here, with the same shapes (ownership, generics, `Result`
//! plumbing, and `!Send` thread-locality) as xla-rs.

use std::fmt;
use std::marker::PhantomData;
use std::rc::Rc;

/// Stand-in for `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: xla stub build — the PJRT native runtime is not linked; \
         patch in a real `xla` crate (xla-rs + xla_extension) to execute \
         artifacts"
    ))
}

/// PJRT client handle.  The `Rc` marker mirrors the real binding's
/// `!Send` thread-locality, so the worker-thread factory discipline in
/// `cirptc::coordinator` stays honest even against the stub.
pub struct PjRtClient {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host-side literal.  Construction and reshape work (pure metadata);
/// anything touching device buffers errors.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_stub_build() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[2, 2]).is_ok());
        assert!(lit.reshape(&[3, 2]).is_err());
    }
}
