//! Single import surface for synchronization primitives (DESIGN.md
//! §verify).
//!
//! Everything in the crate that locks, swaps or counts across threads
//! imports from here, never from `std::sync` directly (`repo_lint`
//! enforces it).  Normally the re-exports are exactly `std::sync`; under
//! `--cfg loom` the lock and atomic types swap for the instrumented
//! versions in [`model`], so the protocol tests in
//! `rust/tests/loom_models.rs` can model-check the very same primitives
//! the serving stack runs on.
//!
//! The shared protocols themselves live here too, as small generic
//! types the hot paths and the model tests both use verbatim:
//! [`Slot`] (the hot-swap publication cell behind
//! [`crate::drift::EngineSlot`]) and [`SingleFlight`] (the drift
//! monitor's recalibration gate).

pub mod model;

#[cfg(not(loom))]
pub use std::sync::{
    atomic, mpsc, Arc, Condvar, LockResult, Mutex, MutexGuard, OnceLock,
    PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard, Weak,
};

#[cfg(loom)]
pub use std::sync::{mpsc, Arc, Condvar, LockResult, OnceLock, PoisonError, Weak};

#[cfg(loom)]
pub use self::model::{
    Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

/// Atomic types, instrumented under `--cfg loom`.
#[cfg(loom)]
pub mod atomic {
    pub use super::model::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize};
    pub use std::sync::atomic::Ordering;
}

/// A read-mostly publication cell: many readers grab the current value,
/// one writer atomically replaces it (the hot-swap half of the drift
/// protocol — readers in flight keep the `Arc` they captured, new
/// readers see the replacement).
///
/// Poisoning recovers rather than cascades: a reader never mutates, and
/// the writer replaces the whole `Arc`, so a panic mid-critical-section
/// cannot leave a torn value behind.
pub struct Slot<T> {
    inner: RwLock<Arc<T>>,
}

impl<T> Slot<T> {
    pub fn new(value: T) -> Slot<T> {
        Slot { inner: RwLock::new(Arc::new(value)) }
    }

    /// The currently published value.
    pub fn current(&self) -> Arc<T> {
        Arc::clone(&self.inner.read().unwrap_or_else(PoisonError::into_inner))
    }

    /// Atomically publish a replacement value.
    pub fn swap(&self, value: T) {
        self.publish(Arc::new(value));
    }

    /// Atomically publish an already-shared replacement.
    pub fn publish(&self, value: Arc<T>) {
        *self.inner.write().unwrap_or_else(PoisonError::into_inner) = value;
    }
}

/// A single-admission gate: the first `try_begin` wins and everyone else
/// is refused until the winner calls `finish` (the drift monitor's
/// "exactly one recalibration in flight" protocol).
pub struct SingleFlight {
    busy: atomic::AtomicBool,
}

impl SingleFlight {
    pub const fn new() -> SingleFlight {
        SingleFlight { busy: atomic::AtomicBool::new(false) }
    }

    /// Try to become the single admitted flight; true exactly once per
    /// `finish` cycle, over every interleaving (see `loom_models.rs`).
    pub fn try_begin(&self) -> bool {
        !self.busy.swap(true, atomic::Ordering::SeqCst)
    }

    /// Reopen the gate (called by whoever owns the completed flight).
    pub fn finish(&self) {
        self.busy.store(false, atomic::Ordering::SeqCst);
    }

    /// Whether a flight currently holds the gate.
    pub fn in_flight(&self) -> bool {
        self.busy.load(atomic::Ordering::SeqCst)
    }
}

impl Default for SingleFlight {
    fn default() -> Self {
        SingleFlight::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn slot_swaps_under_concurrent_readers() {
        let slot = Arc::new(Slot::new(0u64));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let slot = Arc::clone(&slot);
                thread::spawn(move || {
                    let mut last = 0;
                    for _ in 0..500 {
                        let v = *slot.current();
                        assert!(v >= last, "published values are monotone");
                        last = v;
                    }
                })
            })
            .collect();
        for g in 1..=50u64 {
            slot.swap(g);
        }
        for r in readers {
            r.join().expect("reader");
        }
        assert_eq!(*slot.current(), 50);
    }

    #[test]
    fn single_flight_admits_exactly_one() {
        let gate = SingleFlight::new();
        assert!(gate.try_begin());
        assert!(!gate.try_begin(), "second entry refused");
        assert!(gate.in_flight());
        gate.finish();
        assert!(!gate.in_flight());
        assert!(gate.try_begin(), "gate reopens after finish");
    }

    #[test]
    fn single_flight_races_admit_one_winner() {
        let gate = Arc::new(SingleFlight::new());
        let winners: Vec<bool> = (0..8)
            .map(|_| {
                let gate = Arc::clone(&gate);
                thread::spawn(move || gate.try_begin())
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("racer"))
            .collect();
        assert_eq!(winners.iter().filter(|w| **w).count(), 1);
    }
}
