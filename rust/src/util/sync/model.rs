//! Bounded model checker for the crate's lock/atomic protocols (loom is
//! not in the offline vendor set — same from-scratch philosophy as
//! `util/propcheck.rs`).
//!
//! [`Checker::check`] re-runs a small multi-threaded scenario under every
//! reachable thread interleaving: model threads run as real OS threads,
//! but each instrumented operation (lock acquire, atomic access) first
//! parks on a scheduling gate, and a controller thread enumerates the
//! schedules by depth-first search over the per-step choice of which
//! runnable thread proceeds.  Exactly one model thread runs between
//! decisions, so every execution is deterministic given its schedule and
//! replay is exact.
//!
//! Scope, stated honestly: the checker explores **sequentially
//! consistent** interleavings at instrumented-operation granularity.  It
//! catches lost updates, ordering bugs between sync operations, double
//! entry through gates, and deadlocks (no runnable thread while blocked
//! threads remain) — it does *not* model weak-memory reorderings the way
//! real loom does, so `Relaxed`-ordering bugs that need hardware
//! reordering to surface are out of reach.  The protocols it guards
//! (`Slot` hot swap, `SingleFlight`, the FFT plan cache) are
//! `SeqCst`/lock-based, where this is the relevant semantics.
//!
//! The instrumented [`Mutex`]/[`RwLock`]/atomic types compile in every
//! configuration; outside a model run they fall back to plain spin-lock /
//! raw-atomic behaviour.  Under `--cfg loom` the [`crate::util::sync`]
//! shim re-exports them as *the* sync primitives, so the whole crate's
//! protocols run instrumented inside `rust/tests/loom_models.rs`.

use std::cell::RefCell;
use std::cell::UnsafeCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering as StdOrdering;
use std::sync::{Arc, Condvar, Mutex as StdMutex};
use std::thread;

pub use std::sync::atomic::Ordering;
pub use std::sync::{LockResult, PoisonError};

/// Sentinel panic message for threads torn down by deadlock abort; the
/// controller reports the deadlock itself, not these unwinds.
const ABORT_MSG: &str = "__cirptc_model_abort__";

// ---------------------------------------------------------------------
// scheduler core
// ---------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    /// running real code between two instrumented operations
    Running,
    /// parked at a scheduling gate, eligible to be granted a step
    AtYield,
    /// parked waiting for a resource (mutex/rwlock) to be released
    Blocked(usize),
    Finished,
}

struct SchedState {
    statuses: Vec<Status>,
    /// thread currently granted its next step
    grant: Option<usize>,
    /// panic messages collected from model threads (tid, message)
    panics: Vec<(usize, String)>,
    /// set on deadlock teardown: parked threads unwind instead of waiting
    abort: bool,
}

struct Sched {
    state: StdMutex<SchedState>,
    cv: Condvar,
}

impl Sched {
    fn new(n_threads: usize) -> Sched {
        Sched {
            state: StdMutex::new(SchedState {
                statuses: vec![Status::Running; n_threads],
                grant: None,
                panics: Vec::new(),
                abort: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SchedState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Park as `mark`, then wait until granted the next step (or aborted).
    fn park(&self, tid: usize, mark: Status) {
        let mut st = self.lock();
        st.statuses[tid] = mark;
        self.cv.notify_all();
        while st.grant != Some(tid) && !st.abort {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.abort {
            drop(st);
            panic!("{ABORT_MSG}");
        }
        // controller already marked us Running when granting
        st.grant = None;
    }

    fn yield_op(&self, tid: usize) {
        self.park(tid, Status::AtYield);
    }

    fn block_on(&self, tid: usize, resource: usize) {
        self.park(tid, Status::Blocked(resource));
    }

    /// A resource was released: every thread blocked on it becomes
    /// schedulable again.  Never parks (safe during unwinding).
    fn release(&self, resource: usize) {
        let mut st = self.lock();
        for s in st.statuses.iter_mut() {
            if *s == Status::Blocked(resource) {
                *s = Status::AtYield;
            }
        }
        self.cv.notify_all();
    }

    fn finish(&self, tid: usize, panic_msg: Option<String>) {
        let mut st = self.lock();
        st.statuses[tid] = Status::Finished;
        if let Some(m) = panic_msg {
            st.panics.push((tid, m));
        }
        self.cv.notify_all();
    }
}

/// Per-thread handle into the active scheduler.
struct ThreadCtx {
    sched: Arc<Sched>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Arc<ThreadCtx>>> = const { RefCell::new(None) };
}

fn current() -> Option<Arc<ThreadCtx>> {
    CTX.with(|c| c.borrow().clone())
}

/// Scheduling gate before an instrumented operation; no-op outside a
/// model run and while unwinding (guard drops during a panic must not
/// park — the controller would never see the thread finish).
fn sync_point() {
    if thread::panicking() {
        return;
    }
    if let Some(ctx) = current() {
        ctx.sched.yield_op(ctx.tid);
    }
}

// ---------------------------------------------------------------------
// explorer
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct Decision {
    /// index chosen into the sorted runnable list
    choice: usize,
    /// how many threads were runnable at this step
    options: usize,
}

type Body = Box<dyn FnOnce() + Send + 'static>;

/// One scheduled execution being assembled by the scenario closure:
/// register thread bodies with [`Run::thread`] and an optional
/// post-condition with [`Run::after`].
#[derive(Default)]
pub struct Run {
    bodies: Vec<Body>,
    after: Option<Box<dyn FnOnce()>>,
}

impl Run {
    /// Register a model thread for this execution.
    pub fn thread<F: FnOnce() + Send + 'static>(&mut self, f: F) {
        self.bodies.push(Box::new(f));
    }

    /// Register a check that runs after every schedule completes (on the
    /// controller thread, with scheduling disabled).
    pub fn after<F: FnOnce() + 'static>(&mut self, f: F) {
        self.after = Some(Box::new(f));
    }
}

/// Result of a completed exploration.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    /// distinct schedules executed
    pub schedules: usize,
}

/// Exhaustive schedule explorer; see the module docs for semantics.
pub struct Checker {
    name: String,
    max_schedules: usize,
}

impl Checker {
    pub fn new(name: &str) -> Checker {
        Checker { name: name.to_string(), max_schedules: 100_000 }
    }

    /// Cap on explored schedules; exceeding it fails the check loudly
    /// (silent truncation would read as full coverage).
    pub fn max_schedules(mut self, n: usize) -> Checker {
        self.max_schedules = n;
        self
    }

    /// Run `scenario` under every reachable interleaving.  The closure is
    /// invoked once per schedule to build fresh state and register the
    /// thread bodies; panics inside model threads (assertion failures,
    /// detected deadlocks) propagate with the offending schedule attached.
    pub fn check<F: Fn(&mut Run)>(self, scenario: F) -> Summary {
        let mut prefix: Vec<usize> = Vec::new();
        let mut schedules = 0usize;
        loop {
            let mut run = Run::default();
            scenario(&mut run);
            let trace = self.execute(run.bodies, &prefix);
            if let Some(after) = run.after {
                after();
            }
            schedules += 1;
            assert!(
                schedules <= self.max_schedules,
                "model '{}': exploration cap {} exceeded — state space too \
                 large for an exhaustive check",
                self.name,
                self.max_schedules
            );
            match next_prefix(&trace) {
                Some(p) => prefix = p,
                None => break,
            }
        }
        Summary { schedules }
    }

    /// Execute one schedule: spawn the bodies, drive them step by step
    /// replaying `prefix` then defaulting to the first runnable thread,
    /// and return the full decision trace.
    fn execute(&self, bodies: Vec<Body>, prefix: &[usize]) -> Vec<Decision> {
        let n = bodies.len();
        let sched = Arc::new(Sched::new(n));
        let mut handles = Vec::with_capacity(n);
        for (tid, body) in bodies.into_iter().enumerate() {
            let sched = Arc::clone(&sched);
            handles.push(thread::spawn(move || {
                let ctx = Arc::new(ThreadCtx { sched: Arc::clone(&sched), tid });
                CTX.with(|c| *c.borrow_mut() = Some(ctx));
                // start gate: no body code runs until the controller
                // grants the first step (keeps replays deterministic)
                let gate = catch_unwind(AssertUnwindSafe(|| {
                    sched.yield_op(tid);
                    body();
                }));
                CTX.with(|c| *c.borrow_mut() = None);
                sched.finish(tid, gate.err().map(panic_message));
            }));
        }

        let mut decisions: Vec<Decision> = Vec::new();
        let deadlock: Option<Vec<(usize, usize)>> = loop {
            let mut st = sched.lock();
            while st.statuses.iter().any(|s| *s == Status::Running) {
                st = sched.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            if st.statuses.iter().all(|s| *s == Status::Finished) {
                break None;
            }
            let runnable: Vec<usize> = st
                .statuses
                .iter()
                .enumerate()
                .filter(|(_, s)| **s == Status::AtYield)
                .map(|(i, _)| i)
                .collect();
            if runnable.is_empty() {
                // deadlock: tear parked threads down so join() returns
                let blocked: Vec<(usize, usize)> = st
                    .statuses
                    .iter()
                    .enumerate()
                    .filter_map(|(i, s)| match s {
                        Status::Blocked(r) => Some((i, *r)),
                        _ => None,
                    })
                    .collect();
                st.abort = true;
                sched.cv.notify_all();
                break Some(blocked);
            }
            let d = decisions.len();
            let choice = if d < prefix.len() { prefix[d] } else { 0 };
            assert!(
                choice < runnable.len(),
                "model '{}': non-deterministic scenario (replay diverged at \
                 step {d}: choice {choice} of {} runnable)",
                self.name,
                runnable.len()
            );
            let tid = runnable[choice];
            decisions.push(Decision { choice, options: runnable.len() });
            st.statuses[tid] = Status::Running;
            st.grant = Some(tid);
            sched.cv.notify_all();
        };

        for h in handles {
            let _ = h.join();
        }
        if let Some(blocked) = deadlock {
            panic!(
                "model '{}': deadlock under schedule {:?} — blocked: {:?}",
                self.name,
                choices(&decisions),
                blocked
            );
        }
        let st = sched.lock();
        if let Some((tid, msg)) = st.panics.iter().find(|(_, m)| m != ABORT_MSG) {
            panic!(
                "model '{}': thread {tid} panicked under schedule {:?}: {msg}",
                self.name,
                choices(&decisions)
            );
        }
        drop(st);
        decisions
    }
}

fn choices(trace: &[Decision]) -> Vec<usize> {
    trace.iter().map(|d| d.choice).collect()
}

/// Deepest decision with an unexplored sibling, as the next DFS prefix.
fn next_prefix(trace: &[Decision]) -> Option<Vec<usize>> {
    for i in (0..trace.len()).rev() {
        if trace[i].choice + 1 < trace[i].options {
            let mut p = choices(&trace[..i]);
            p.push(trace[i].choice + 1);
            return Some(p);
        }
    }
    None
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

// ---------------------------------------------------------------------
// instrumented sync primitives
// ---------------------------------------------------------------------

/// A mutex whose acquire is a scheduling point inside a model run;
/// outside one it degrades to a spin lock.  `lock()` always returns `Ok`
/// (no poisoning), so `std`-style call sites compile against both.
pub struct Mutex<T> {
    held: std::sync::atomic::AtomicBool,
    cell: UnsafeCell<T>,
}

// Safety: `held` enforces exclusive access to `cell` (CAS outside model
// runs; single-running-thread serialization inside them).
unsafe impl<T: Send> Send for Mutex<T> {}
unsafe impl<T: Send> Sync for Mutex<T> {}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

impl<T> Mutex<T> {
    pub const fn new(value: T) -> Mutex<T> {
        Mutex {
            held: std::sync::atomic::AtomicBool::new(false),
            cell: UnsafeCell::new(value),
        }
    }

    fn id(&self) -> usize {
        self as *const Mutex<T> as usize
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match current() {
            Some(ctx) if !thread::panicking() => {
                ctx.sched.yield_op(ctx.tid);
                while self.held.swap(true, StdOrdering::SeqCst) {
                    ctx.sched.block_on(ctx.tid, self.id());
                }
            }
            _ => {
                while self.held.swap(true, StdOrdering::SeqCst) {
                    std::hint::spin_loop();
                }
            }
        }
        Ok(MutexGuard { m: self })
    }
}

pub struct MutexGuard<'a, T> {
    m: &'a Mutex<T>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // Safety: exclusive while `held`
        unsafe { &*self.m.cell.get() }
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // Safety: exclusive while `held`
        unsafe { &mut *self.m.cell.get() }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        self.m.held.store(false, StdOrdering::SeqCst);
        if let Some(ctx) = current() {
            ctx.sched.release(self.m.id());
        }
    }
}

/// Reader–writer lock; same instrumentation contract as [`Mutex`].
pub struct RwLock<T> {
    writer: std::sync::atomic::AtomicBool,
    readers: std::sync::atomic::AtomicUsize,
    cell: UnsafeCell<T>,
}

// Safety: writer/readers flags enforce the usual shared-xor-mut protocol.
unsafe impl<T: Send> Send for RwLock<T> {}
unsafe impl<T: Send + Sync> Sync for RwLock<T> {}

impl<T: Default> Default for RwLock<T> {
    fn default() -> RwLock<T> {
        RwLock::new(T::default())
    }
}

impl<T> RwLock<T> {
    pub const fn new(value: T) -> RwLock<T> {
        RwLock {
            writer: std::sync::atomic::AtomicBool::new(false),
            readers: std::sync::atomic::AtomicUsize::new(0),
            cell: UnsafeCell::new(value),
        }
    }

    fn id(&self) -> usize {
        self as *const RwLock<T> as usize
    }

    fn try_read(&self) -> bool {
        if self.writer.load(StdOrdering::SeqCst) {
            return false;
        }
        self.readers.fetch_add(1, StdOrdering::SeqCst);
        if self.writer.load(StdOrdering::SeqCst) {
            self.readers.fetch_sub(1, StdOrdering::SeqCst);
            return false;
        }
        true
    }

    fn try_write(&self) -> bool {
        if self
            .writer
            .compare_exchange(false, true, StdOrdering::SeqCst, StdOrdering::SeqCst)
            .is_err()
        {
            return false;
        }
        if self.readers.load(StdOrdering::SeqCst) != 0 {
            self.writer.store(false, StdOrdering::SeqCst);
            return false;
        }
        true
    }

    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        self.acquire(Self::try_read);
        Ok(RwLockReadGuard { l: self })
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        self.acquire(Self::try_write);
        Ok(RwLockWriteGuard { l: self })
    }

    fn acquire(&self, try_op: fn(&RwLock<T>) -> bool) {
        match current() {
            Some(ctx) if !thread::panicking() => {
                ctx.sched.yield_op(ctx.tid);
                while !try_op(self) {
                    ctx.sched.block_on(ctx.tid, self.id());
                }
            }
            _ => {
                while !try_op(self) {
                    std::hint::spin_loop();
                }
            }
        }
    }
}

pub struct RwLockReadGuard<'a, T> {
    l: &'a RwLock<T>,
}

impl<T> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // Safety: shared access held via the readers count
        unsafe { &*self.l.cell.get() }
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        self.l.readers.fetch_sub(1, StdOrdering::SeqCst);
        if let Some(ctx) = current() {
            ctx.sched.release(self.l.id());
        }
    }
}

pub struct RwLockWriteGuard<'a, T> {
    l: &'a RwLock<T>,
}

impl<T> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // Safety: exclusive while `writer`
        unsafe { &*self.l.cell.get() }
    }
}

impl<T> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // Safety: exclusive while `writer`
        unsafe { &mut *self.l.cell.get() }
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        self.l.writer.store(false, StdOrdering::SeqCst);
        if let Some(ctx) = current() {
            ctx.sched.release(self.l.id());
        }
    }
}

/// Instrumented boolean atomic: every access is a scheduling point.
pub struct AtomicBool {
    inner: std::sync::atomic::AtomicBool,
}

impl AtomicBool {
    pub const fn new(v: bool) -> AtomicBool {
        AtomicBool { inner: std::sync::atomic::AtomicBool::new(v) }
    }
    pub fn load(&self, order: Ordering) -> bool {
        sync_point();
        self.inner.load(order)
    }
    pub fn store(&self, v: bool, order: Ordering) {
        sync_point();
        self.inner.store(v, order);
    }
    pub fn swap(&self, v: bool, order: Ordering) -> bool {
        sync_point();
        self.inner.swap(v, order)
    }
}

impl Default for AtomicBool {
    fn default() -> AtomicBool {
        AtomicBool::new(false)
    }
}

macro_rules! instrumented_int_atomic {
    ($name:ident, $std:ty, $prim:ty) => {
        /// Instrumented integer atomic: every access is a scheduling point.
        pub struct $name {
            inner: $std,
        }

        impl $name {
            pub const fn new(v: $prim) -> $name {
                $name { inner: <$std>::new(v) }
            }
            pub fn load(&self, order: Ordering) -> $prim {
                sync_point();
                self.inner.load(order)
            }
            pub fn store(&self, v: $prim, order: Ordering) {
                sync_point();
                self.inner.store(v, order);
            }
            pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                sync_point();
                self.inner.fetch_add(v, order)
            }
            pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                sync_point();
                self.inner.fetch_sub(v, order)
            }
        }

        impl Default for $name {
            fn default() -> $name {
                $name::new(0)
            }
        }
    };
}

instrumented_int_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
instrumented_int_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
instrumented_int_atomic!(AtomicI64, std::sync::atomic::AtomicI64, i64);

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as PlainMutex;

    #[test]
    fn explores_every_interleaving_of_two_counters() {
        let finals: Arc<PlainMutex<Vec<usize>>> = Arc::default();
        let f2 = Arc::clone(&finals);
        let summary = Checker::new("two-counters").check(move |run| {
            let n = Arc::new(AtomicUsize::new(0));
            let (na, nb) = (Arc::clone(&n), Arc::clone(&n));
            run.thread(move || {
                na.fetch_add(1, Ordering::SeqCst);
                na.fetch_add(1, Ordering::SeqCst);
            });
            run.thread(move || {
                nb.fetch_add(1, Ordering::SeqCst);
                nb.fetch_add(1, Ordering::SeqCst);
            });
            let sink = Arc::clone(&f2);
            run.after(move || {
                sink.lock().unwrap().push(n.load(Ordering::SeqCst));
            });
        });
        assert!(summary.schedules > 1, "must explore > 1 schedule");
        let finals = finals.lock().unwrap();
        assert_eq!(finals.len(), summary.schedules);
        assert!(finals.iter().all(|v| *v == 4), "fetch_add is atomic");
    }

    #[test]
    fn finds_lost_update_in_unlocked_rmw() {
        // non-atomic read-modify-write: load, then store — some schedule
        // must lose an update, which is exactly what the checker is for
        let finals: Arc<PlainMutex<Vec<usize>>> = Arc::default();
        let f2 = Arc::clone(&finals);
        Checker::new("lost-update").check(move |run| {
            let n = Arc::new(AtomicUsize::new(0));
            for _ in 0..2 {
                let n = Arc::clone(&n);
                run.thread(move || {
                    let v = n.load(Ordering::SeqCst);
                    n.store(v + 1, Ordering::SeqCst);
                });
            }
            let sink = Arc::clone(&f2);
            run.after(move || {
                sink.lock().unwrap().push(n.load(Ordering::SeqCst));
            });
        });
        let finals = finals.lock().unwrap();
        assert!(finals.contains(&2), "serial schedules reach 2");
        assert!(finals.contains(&1), "interleaved schedules lose an update");
    }

    #[test]
    fn mutex_restores_atomicity() {
        let finals: Arc<PlainMutex<Vec<usize>>> = Arc::default();
        let f2 = Arc::clone(&finals);
        Checker::new("mutex-rmw").check(move |run| {
            let n = Arc::new(Mutex::new(0usize));
            for _ in 0..2 {
                let n = Arc::clone(&n);
                run.thread(move || {
                    let mut g = n.lock().unwrap();
                    *g += 1;
                });
            }
            let sink = Arc::clone(&f2);
            run.after(move || {
                sink.lock().unwrap().push(*n.lock().unwrap());
            });
        });
        assert!(finals.lock().unwrap().iter().all(|v| *v == 2));
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn detects_abba_deadlock() {
        Checker::new("abba").check(|run| {
            let a = Arc::new(Mutex::new(0u32));
            let b = Arc::new(Mutex::new(0u32));
            let (a1, b1) = (Arc::clone(&a), Arc::clone(&b));
            run.thread(move || {
                let _ga = a1.lock().unwrap();
                let _gb = b1.lock().unwrap();
            });
            run.thread(move || {
                let _gb = b.lock().unwrap();
                let _ga = a.lock().unwrap();
            });
        });
    }

    #[test]
    #[should_panic(expected = "thread 0 panicked")]
    fn propagates_thread_assertions() {
        Checker::new("assert").check(|run| {
            let n = Arc::new(AtomicUsize::new(0));
            run.thread(move || {
                assert_eq!(n.load(Ordering::SeqCst), 99, "forced failure");
            });
        });
    }

    #[test]
    fn rwlock_excludes_writers_from_readers() {
        Checker::new("rwlock").max_schedules(50_000).check(|run| {
            // writer publishes (a, b) as a pair with a scheduling point
            // mid-update; readers must never see a torn pair — RwLock
            // write exclusivity is the whole invariant
            let cell = Arc::new(RwLock::new((0u32, 0u32)));
            let tick = Arc::new(AtomicUsize::new(0));
            let w = Arc::clone(&cell);
            let wt = Arc::clone(&tick);
            run.thread(move || {
                let mut g = w.write().unwrap();
                g.0 = 1;
                // a broken lock would let a reader run right here
                wt.fetch_add(1, Ordering::SeqCst);
                g.1 = 1;
            });
            for _ in 0..2 {
                let r = Arc::clone(&cell);
                run.thread(move || {
                    let g = r.read().unwrap();
                    assert_eq!(g.0, g.1, "torn read through RwLock");
                });
            }
        });
    }

    #[test]
    fn fallback_mode_works_without_a_model_run() {
        // outside Checker::check the instrumented types act as plain
        // spin locks / raw atomics (this is the --cfg loom fallback path)
        let m = Mutex::new(5i32);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 6);
        let l = RwLock::new(7i32);
        assert_eq!(*l.read().unwrap(), 7);
        *l.write().unwrap() = 8;
        assert_eq!(*l.read().unwrap(), 8);
        let a = AtomicU64::new(1);
        a.fetch_add(2, Ordering::SeqCst);
        assert_eq!(a.load(Ordering::SeqCst), 3);
    }
}
