//! Tiny declarative CLI argument parser (clap is not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, typed
//! accessors with defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
    spec: Vec<(String, String)>, // (name, help) for --help
    program: String,
}

impl Args {
    /// Parse from an iterator of raw arguments (not including argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(program: &str, it: I) -> Args {
        let mut a = Args {
            program: program.to_string(),
            ..Default::default()
        };
        let mut iter = it.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    a.opts.insert(rest.to_string(), v);
                } else {
                    a.flags.push(rest.to_string());
                }
            } else {
                a.pos.push(arg);
            }
        }
        a
    }

    /// Parse the process arguments.
    pub fn parse() -> Args {
        let mut argv = std::env::args();
        let program = argv.next().unwrap_or_default();
        Args::parse_from(&program, argv)
    }

    /// Register help text for an option (used by `usage()`).
    pub fn describe(&mut self, name: &str, help: &str) -> &mut Self {
        self.spec.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("usage: {} [options]\n", self.program);
        for (name, help) in &self.spec {
            s.push_str(&format!("  --{name:<24} {help}\n"));
        }
        s
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse_from("prog", s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = args(&["--size", "48", "--mode=photonic"]);
        assert_eq!(a.usize_or("size", 0), 48);
        assert_eq!(a.str_or("mode", ""), "photonic");
    }

    #[test]
    fn flags_and_positionals() {
        // note: `--flag value`-style ambiguity is resolved greedily (the
        // next non--- token becomes the value), so boolean flags go last
        // or use `--flag=`; this matches the documented grammar.
        let a = args(&["serve", "model.hlo", "--verbose"]);
        assert!(a.has("verbose"));
        assert_eq!(a.positional(), &["serve", "model.hlo"]);
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.usize_or("batch", 16), 16);
        assert_eq!(a.f64_or("eps", 0.02), 0.02);
        assert!(!a.has("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args(&["--fast", "--n", "3"]);
        assert!(a.has("fast"));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn usage_lists_described() {
        let mut a = args(&[]);
        a.describe("size", "matrix size");
        assert!(a.usage().contains("--size"));
        assert!(a.usage().contains("matrix size"));
    }
}
