//! Benchmark harness (criterion is not vendored offline).
//!
//! Provides warmup + timed iterations with mean / p50 / p99 statistics and
//! a uniform one-line report format shared by all `benches/` binaries so
//! `cargo bench` output reads like the paper's tables.  [`JsonReport`]
//! additionally writes the numbers as machine-readable `BENCH_*.json`
//! files so the perf trajectory is tracked across PRs (`bench_diff`
//! compares them against the committed baselines in CI).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Statistics over a set of per-iteration wall times.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub total: Duration,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total: f64 = ns.iter().sum();
        let pick = |q: f64| ns[((ns.len() - 1) as f64 * q) as usize];
        Stats {
            iters: ns.len(),
            mean_ns: total / ns.len() as f64,
            p50_ns: pick(0.50),
            p99_ns: pick(0.99),
            min_ns: ns[0],
            total: Duration::from_nanos(total as u64),
        }
    }

    /// Throughput in "units/s" given units of work per iteration.
    pub fn per_second(&self, units_per_iter: f64) -> f64 {
        units_per_iter / (self.mean_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f` with automatic warmup; bounded by both a target iteration count
/// and a wall-clock budget.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Stats {
    bench_cfg(name, 3, 30, Duration::from_secs(2), &mut f)
}

/// Fully configurable variant.
pub fn bench_cfg<F: FnMut()>(
    name: &str,
    warmup: usize,
    target_iters: usize,
    budget: Duration,
    f: &mut F,
) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(target_iters);
    let start = Instant::now();
    while samples.len() < target_iters
        && (samples.is_empty() || start.elapsed() < budget)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let s = Stats::from_samples(samples);
    println!(
        "bench {name:<42} mean {:>10}  p50 {:>10}  p99 {:>10}  ({} iters)",
        fmt_ns(s.mean_ns),
        fmt_ns(s.p50_ns),
        fmt_ns(s.p99_ns),
        s.iters
    );
    s
}

/// Prevent the optimizer from discarding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section header used by the bench binaries to mirror paper table titles.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// A paper-style table row: label + columns.
pub fn row(label: &str, cols: &[(&str, String)]) {
    let cells: Vec<String> = cols
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    println!("  {label:<36} {}", cells.join("  "));
}

/// Machine-readable bench results: named timing entries (`results`, one
/// object of `mean_ns`/`p50_ns`/`p99_ns`/`per_s` each) plus free-form
/// scalar `metrics` (speedups, throughput, alloc proxies).  `bench_diff`
/// compares the `results` timings of two files with a generous tolerance
/// and checks `metrics` floors declared in the baseline.
pub struct JsonReport {
    bench: String,
    results: BTreeMap<String, Json>,
    metrics: BTreeMap<String, Json>,
}

impl JsonReport {
    pub fn new(bench: &str) -> JsonReport {
        JsonReport {
            bench: bench.to_string(),
            results: BTreeMap::new(),
            metrics: BTreeMap::new(),
        }
    }

    /// Record a timed entry.  `units_per_iter` > 0 adds a `per_s`
    /// throughput field (images, columns, requests — the caller's unit).
    pub fn stat(&mut self, name: &str, s: &Stats, units_per_iter: f64) {
        let mut obj = vec![
            ("mean_ns", Json::Num(s.mean_ns)),
            ("p50_ns", Json::Num(s.p50_ns)),
            ("p99_ns", Json::Num(s.p99_ns)),
            ("iters", Json::Num(s.iters as f64)),
        ];
        if units_per_iter > 0.0 {
            obj.push(("per_s", Json::Num(s.per_second(units_per_iter))));
        }
        self.results.insert(name.to_string(), Json::obj(obj));
    }

    /// Record a free-form scalar metric (speedup ratio, req/s, …).
    pub fn metric(&mut self, name: &str, v: f64) {
        self.metrics.insert(name.to_string(), Json::Num(v));
    }

    /// Serialize to the `BENCH_*.json` layout.
    pub fn dump(&self) -> String {
        Json::obj(vec![
            ("bench", Json::Str(self.bench.clone())),
            ("results", Json::Obj(self.results.clone())),
            ("metrics", Json::Obj(self.metrics.clone())),
        ])
        .dump()
    }

    /// Write the report; prints the destination so bench logs say where
    /// the machine-readable numbers went.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.dump())?;
        println!("\nwrote {}", path.display());
        Ok(())
    }
}

/// Workspace-root path for a bench artifact: cargo runs bench binaries
/// with the *package* dir (`rust/`) as cwd, but the machine-readable
/// results belong at the workspace root, where CI's artifact upload and
/// `bench_diff` (run via `cargo run`, which keeps the invocation cwd)
/// expect them.
pub fn workspace_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.p50_ns, 50.0);
        assert_eq!(s.p99_ns, 99.0);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut n = 0;
        let s = bench_cfg("noop", 1, 5, Duration::from_secs(1), &mut || {
            n += 1;
        });
        assert_eq!(s.iters, 5);
        assert_eq!(n, 6); // warmup + 5
    }

    #[test]
    fn per_second() {
        let s = Stats::from_samples(vec![1e9]); // 1 s per iter
        assert!((s.per_second(10.0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn json_report_roundtrips() {
        let mut rep = JsonReport::new("unit");
        rep.stat("kernel_a", &Stats::from_samples(vec![1e6, 3e6]), 16.0);
        rep.metric("speedup", 1.75);
        let j = Json::parse(&rep.dump()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("unit"));
        let a = j.get("results").unwrap().get("kernel_a").unwrap();
        assert_eq!(a.get("mean_ns").unwrap().as_f64(), Some(2e6));
        assert_eq!(a.get("per_s").unwrap().as_f64(), Some(16.0 / (2e6 * 1e-9)));
        assert_eq!(
            j.get("metrics").unwrap().get("speedup").unwrap().as_f64(),
            Some(1.75)
        );
    }
}
