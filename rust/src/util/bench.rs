//! Benchmark harness (criterion is not vendored offline).
//!
//! Provides warmup + timed iterations with mean / p50 / p99 statistics and
//! a uniform one-line report format shared by all `benches/` binaries so
//! `cargo bench` output reads like the paper's tables.

use std::time::{Duration, Instant};

/// Statistics over a set of per-iteration wall times.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub total: Duration,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total: f64 = ns.iter().sum();
        let pick = |q: f64| ns[((ns.len() - 1) as f64 * q) as usize];
        Stats {
            iters: ns.len(),
            mean_ns: total / ns.len() as f64,
            p50_ns: pick(0.50),
            p99_ns: pick(0.99),
            min_ns: ns[0],
            total: Duration::from_nanos(total as u64),
        }
    }

    /// Throughput in "units/s" given units of work per iteration.
    pub fn per_second(&self, units_per_iter: f64) -> f64 {
        units_per_iter / (self.mean_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f` with automatic warmup; bounded by both a target iteration count
/// and a wall-clock budget.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Stats {
    bench_cfg(name, 3, 30, Duration::from_secs(2), &mut f)
}

/// Fully configurable variant.
pub fn bench_cfg<F: FnMut()>(
    name: &str,
    warmup: usize,
    target_iters: usize,
    budget: Duration,
    f: &mut F,
) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(target_iters);
    let start = Instant::now();
    while samples.len() < target_iters
        && (samples.is_empty() || start.elapsed() < budget)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let s = Stats::from_samples(samples);
    println!(
        "bench {name:<42} mean {:>10}  p50 {:>10}  p99 {:>10}  ({} iters)",
        fmt_ns(s.mean_ns),
        fmt_ns(s.p50_ns),
        fmt_ns(s.p99_ns),
        s.iters
    );
    s
}

/// Prevent the optimizer from discarding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section header used by the bench binaries to mirror paper table titles.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// A paper-style table row: label + columns.
pub fn row(label: &str, cols: &[(&str, String)]) {
    let cells: Vec<String> = cols
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    println!("  {label:<36} {}", cells.join("  "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.p50_ns, 50.0);
        assert_eq!(s.p99_ns, 99.0);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut n = 0;
        let s = bench_cfg("noop", 1, 5, Duration::from_secs(1), &mut || {
            n += 1;
        });
        assert_eq!(s.iters, 5);
        assert_eq!(n, 6); // warmup + 5
    }

    #[test]
    fn per_second() {
        let s = Stats::from_samples(vec![1e9]); // 1 s per iter
        assert!((s.per_second(10.0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
