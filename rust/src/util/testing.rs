//! Shared failure-injection backends for tests and chaos tooling.
//!
//! Promoted from `rust/tests/failure_injection.rs` so every suite that
//! needs a misbehaving [`InferenceBackend`] (failure_injection, farm_e2e,
//! chaos_e2e) exercises the *same* failure modes instead of re-declaring
//! ad-hoc copies.  Not `#[cfg(test)]`-gated: integration tests link the
//! crate as a dependency and the chaos CLI smoke uses them too.

use crate::bail;
use crate::coordinator::InferenceBackend;
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::Arc;

/// Fails every other batch; successful batches answer `[1.0, 0.0]`.
pub struct FlakyBackend {
    pub calls: Arc<AtomicUsize>,
}

impl FlakyBackend {
    pub fn new() -> FlakyBackend {
        FlakyBackend { calls: Arc::new(AtomicUsize::new(0)) }
    }
}

impl Default for FlakyBackend {
    fn default() -> FlakyBackend {
        FlakyBackend::new()
    }
}

impl InferenceBackend for FlakyBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if n % 2 == 1 {
            bail!("injected failure on batch {n}");
        }
        Ok(imgs.iter().map(|_| vec![1.0, 0.0]).collect())
    }

    fn name(&self) -> String {
        "flaky".into()
    }
}

/// Always succeeds with fixed `[1.0, 0.0]` logits — a stand-in for the
/// digital fallback lane in degradation tests.
pub struct ConstBackend;

impl InferenceBackend for ConstBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        Ok(imgs.iter().map(|_| vec![1.0, 0.0]).collect())
    }

    fn name(&self) -> String {
        "const".into()
    }
}

/// Always fails.
pub struct DeadBackend;

impl InferenceBackend for DeadBackend {
    fn infer_batch(&mut self, _imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        bail!("dead backend")
    }

    fn name(&self) -> String {
        "dead".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flaky_alternates_and_dead_always_fails() {
        let imgs = [Tensor::full(&[1, 2, 2], 0.5)];
        let mut flaky = FlakyBackend::new();
        assert!(flaky.infer_batch(&imgs).is_ok());
        assert!(flaky.infer_batch(&imgs).is_err());
        assert!(flaky.infer_batch(&imgs).is_ok());
        assert_eq!(flaky.calls.load(Ordering::SeqCst), 3);
        let mut dead = DeadBackend;
        assert!(dead.infer_batch(&imgs).is_err());
        assert_eq!(dead.name(), "dead");
    }
}
