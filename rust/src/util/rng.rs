//! Deterministic PRNG: splitmix64 seeding + xoshiro256**, plus Gaussian and
//! uniform helpers.  Used by the simulator's noise injection, the synthetic
//! dataset generators, and propcheck.  Deterministic across platforms (no
//! `std::collections::HashMap` iteration order, no OS entropy).

/// xoshiro256** — fast, high-quality, reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller variate
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-worker / per-tile RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (bias < 2^-32 for the sizes we use).
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller (caching the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with uniform [0,1) f32.
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.f32();
        }
    }

    /// Fill a slice with N(0, std²) f32 samples (Kaiming-style init).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Random permutation of 0..n (Fisher-Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            p.swap(i, self.below(i + 1));
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(10);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(11);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_independent() {
        let mut r = Rng::new(12);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
