//! Miniature property-based testing harness (proptest is not vendored).
//!
//! A property is a closure over a seeded [`Gen`]; `check` runs it across
//! many seeds and, on failure, reports the seed so the case can be replayed
//! deterministically:
//!
//! ```ignore
//! propcheck::check("mvm linear", 200, |g| {
//!     let w = g.vec_f32(16, -1.0, 1.0);
//!     ...
//!     prop_assert!(err < 1e-5, "err={err}");
//! });
//! ```

use super::rng::Rng;

/// Value generator wrapping a seeded RNG.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo as f64, hi as f64) as f32
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Outcome of one property execution.
pub type PropResult = Result<(), String>;

/// Run `cases` seeded executions of `prop`; panic with the failing seed on
/// the first failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    for seed in 0..cases {
        let mut g = Gen { rng: Rng::new(0xC1AC0 ^ seed.wrapping_mul(0x9E3779B97F4A7C15)), seed };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single seed (for debugging a reported failure).
pub fn replay<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let mut g = Gen { rng: Rng::new(0xC1AC0 ^ seed.wrapping_mul(0x9E3779B97F4A7C15)), seed };
    if let Err(msg) = prop(&mut g) {
        panic!("property '{name}' failed at replayed seed {seed}: {msg}");
    }
}

/// Assert inside a property, returning Err instead of panicking.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert two f32 slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol {
            return Err(format!("elem {i}: {x} vs {y} (atol {atol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add commutes", 100, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-9);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_replay() {
        // the same seed must generate the same values
        let mut v1 = None;
        replay("capture", 3, |g| {
            v1 = Some(g.vec_f32(8, 0.0, 1.0));
            Ok(())
        });
        let mut v2 = None;
        replay("capture", 3, |g| {
            v2 = Some(g.vec_f32(8, 0.0, 1.0));
            Ok(())
        });
        assert_eq!(v1, v2);
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5).is_err());
    }

    #[test]
    fn gen_ranges() {
        check("usize_in bounds", 50, |g| {
            let x = g.usize_in(3, 9);
            prop_assert!((3..=9).contains(&x), "x={x}");
            Ok(())
        });
    }
}
