//! Infrastructure substrates built from scratch (the offline vendor set has
//! no serde / rand / clap / rayon / criterion / proptest / anyhow — see
//! DESIGN.md §4).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod scratch;
pub mod sync;
pub mod testing;
pub mod threadpool;
