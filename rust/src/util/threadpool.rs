//! Fixed-size thread pool with a scoped parallel-for (rayon/tokio are not
//! vendored offline).  The coordinator's worker pool and the simulator's
//! tile-parallel execution are built on this.

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared queue.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cirptc-worker-{i}"))
                    .spawn(move || loop {
                        let msg = rx.lock().unwrap().recv();
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, size }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Default pool size: available parallelism.
    pub fn default_size() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Blocking parallel map over `0..n`, preserving order.
    ///
    /// Splits into `size * 4` chunks for load balancing; `f` must be
    /// cloneable state-free (wrap shared state in Arc).
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let chunks = (self.size * 4).min(n);
        let chunk = n.div_ceil(chunks);
        let (tx, rx) = mpsc::channel::<(usize, Vec<T>)>();
        let mut sent = 0;
        for (ci, start) in (0..n).step_by(chunk).enumerate() {
            let end = (start + chunk).min(n);
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let out: Vec<T> = (start..end).map(|i| f(i)).collect();
                let _ = tx.send((ci, out));
            });
            sent += 1;
        }
        drop(tx);
        let mut parts: Vec<(usize, Vec<T>)> = rx.iter().collect();
        assert_eq!(parts.len(), sent, "worker panicked");
        parts.sort_by_key(|(ci, _)| *ci);
        parts.into_iter().flat_map(|(_, v)| v).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Scoped parallel-for over fixed-size chunks of a mutable buffer.
///
/// Splits `data` into consecutive `chunk_len` chunks and calls
/// `f(chunk_index, chunk)` once per chunk, distributing contiguous runs of
/// chunks across up to `threads` scoped worker threads.  Unlike
/// [`ThreadPool::map`], the closure may borrow non-`'static` state (the
/// threads are scoped), which is what the batched BCM / engine kernels
/// need to fill disjoint output tiles in place without `Arc`-wrapping
/// their weights.  `threads <= 1` (or a single chunk) degrades to the
/// plain serial loop, so callers can thread a configurable worker count
/// straight through without branching.
pub fn scoped_chunks<F>(threads: usize, data: &mut [f32], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len);
    if threads <= 1 || n_chunks <= 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let threads = threads.min(n_chunks);
    let per = n_chunks.div_ceil(threads);
    let mut groups: Vec<Vec<(usize, &mut [f32])>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (i, c) in data.chunks_mut(chunk_len).enumerate() {
        groups[i / per].push((i, c));
    }
    let f = &f;
    std::thread::scope(|s| {
        // run the first group on the calling thread (it would otherwise
        // sit parked in scope teardown): threads-1 spawns, full core use
        let mut iter = groups.into_iter();
        let first = iter.next();
        for group in iter {
            s.spawn(move || {
                for (i, c) in group {
                    f(i, c);
                }
            });
        }
        if let Some(group) = first {
            for (i, c) in group {
                f(i, c);
            }
        }
    });
}

/// Spawn a named thread inside a [`std::thread::scope`].  The pipeline
/// executor's pre/post stages borrow stage channels and the snapshot slot
/// from the executor's stack frame, so they must be scoped (non-`'static`)
/// — and named, so stalls show up attributably in thread dumps.
pub fn spawn_scoped_named<'scope, 'env, F>(
    scope: &'scope thread::Scope<'scope, 'env>,
    name: &str,
    f: F,
) -> thread::ScopedJoinHandle<'scope, ()>
where
    F: FnOnce() + Send + 'scope,
{
    thread::Builder::new()
        .name(name.to_string())
        .spawn_scoped(scope, f)
        .expect("spawn scoped thread")
}

/// Global chunked-work counter useful for progress metrics in benches.
pub struct WorkCounter(AtomicUsize);

impl WorkCounter {
    pub const fn new() -> Self {
        WorkCounter(AtomicUsize::new(0))
    }
    pub fn add(&self, n: usize) -> usize {
        self.0.fetch_add(n, Ordering::Relaxed)
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for WorkCounter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(1000, |i| i * i);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn execute_runs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join workers
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_worker_pool() {
        let pool = ThreadPool::new(1);
        let out = pool.map(17, |i| i + 1);
        assert_eq!(out[16], 17);
    }

    #[test]
    fn scoped_chunks_covers_all_chunks_in_order() {
        // 10 chunks of 3 (last ragged: len 2), 4 threads
        let mut data = vec![0.0f32; 29];
        scoped_chunks(4, &mut data, 3, |i, c| {
            for v in c.iter_mut() {
                *v = i as f32 + 1.0;
            }
        });
        for (j, v) in data.iter().enumerate() {
            assert_eq!(*v, (j / 3) as f32 + 1.0, "elem {j}");
        }
    }

    #[test]
    fn scoped_chunks_serial_matches_parallel() {
        let fill = |threads: usize| {
            let mut data = vec![0.0f32; 64];
            scoped_chunks(threads, &mut data, 4, |i, c| {
                for (k, v) in c.iter_mut().enumerate() {
                    *v = (i * 100 + k) as f32;
                }
            });
            data
        };
        assert_eq!(fill(1), fill(8));
    }

    #[test]
    fn scoped_chunks_borrows_locals() {
        // the whole point vs ThreadPool::map: non-'static borrows
        let weights: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 8];
        scoped_chunks(2, &mut out, 2, |i, c| {
            for (k, v) in c.iter_mut().enumerate() {
                *v = weights[i * 2 + k] * 2.0;
            }
        });
        assert_eq!(out[7], 14.0);
    }

    #[test]
    fn scoped_chunks_empty() {
        let mut data: Vec<f32> = Vec::new();
        scoped_chunks(4, &mut data, 8, |_, _| panic!("no chunks"));
    }

    #[test]
    fn work_counter() {
        let c = WorkCounter::new();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
    }
}
