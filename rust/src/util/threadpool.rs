//! Fixed-size thread pool with a scoped parallel-for (rayon/tokio are not
//! vendored offline).  The coordinator's worker pool and the simulator's
//! tile-parallel execution are built on this.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared queue.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cirptc-worker-{i}"))
                    .spawn(move || loop {
                        let msg = rx.lock().unwrap().recv();
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, size }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Default pool size: available parallelism.
    pub fn default_size() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Blocking parallel map over `0..n`, preserving order.
    ///
    /// Splits into `size * 4` chunks for load balancing; `f` must be
    /// cloneable state-free (wrap shared state in Arc).
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let chunks = (self.size * 4).min(n);
        let chunk = n.div_ceil(chunks);
        let (tx, rx) = mpsc::channel::<(usize, Vec<T>)>();
        let mut sent = 0;
        for (ci, start) in (0..n).step_by(chunk).enumerate() {
            let end = (start + chunk).min(n);
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let out: Vec<T> = (start..end).map(|i| f(i)).collect();
                let _ = tx.send((ci, out));
            });
            sent += 1;
        }
        drop(tx);
        let mut parts: Vec<(usize, Vec<T>)> = rx.iter().collect();
        assert_eq!(parts.len(), sent, "worker panicked");
        parts.sort_by_key(|(ci, _)| *ci);
        parts.into_iter().flat_map(|(_, v)| v).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Global chunked-work counter useful for progress metrics in benches.
pub struct WorkCounter(AtomicUsize);

impl WorkCounter {
    pub const fn new() -> Self {
        WorkCounter(AtomicUsize::new(0))
    }
    pub fn add(&self, n: usize) -> usize {
        self.0.fetch_add(n, Ordering::Relaxed)
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for WorkCounter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map(1000, |i| i * i);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn execute_runs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join workers
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_worker_pool() {
        let pool = ThreadPool::new(1);
        let out = pool.map(17, |i| i + 1);
        assert_eq!(out[16], 17);
    }

    #[test]
    fn work_counter() {
        let c = WorkCounter::new();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
    }
}
