//! Zero-alloc scratch arenas for the serving hot path (DESIGN.md §perf).
//!
//! Every batch used to pay a stack of `vec![0.0; …]` allocations on its
//! way through `Engine::forward_batch` → `im2col` → `Bcm::{mmm, mmm_fft}`
//! → `ChipSim::forward_signed`.  The sizes recur exactly (they are
//! functions of the layer shapes and the batch width), so a per-worker
//! arena of checked-out buffers keyed by power-of-two size class turns
//! that churn into pointer swaps after the first batch warms the pools.
//!
//! The arena is **thread-local**: each serving worker (and the trainer,
//! and a bench's driver thread) owns its own pools, so checkout needs no
//! locking and buffers never migrate between threads.  Scoped kernel
//! threads ([`crate::util::threadpool::scoped_chunks`]) deliberately do
//! *not* use the arena — they are fresh threads each call, so their
//! thread-locals would never warm; their small per-chunk accumulators
//! stay plain `Vec`s.
//!
//! Contract: [`take`] returns a **zeroed** buffer of exactly the
//! requested length; [`put`] parks a buffer for reuse (any `Vec<f32>` is
//! accepted — returning a buffer that was not checked out is fine).  The
//! [`stats`] counters are the allocs-per-batch proxy the serving benches
//! report: once the pools are warm, `misses` stops moving.

use std::cell::RefCell;

/// Buffers parked per size class; beyond this, returns are dropped (keeps
/// a worker that briefly ran a huge batch from pinning memory forever).
const MAX_PER_CLASS: usize = 8;

/// Size classes cover lengths up to 2^32 floats (16 GiB — far beyond any
/// layer operand; larger requests just bypass pooling via the last class).
const CLASSES: usize = 33;

/// Cumulative checkout counters (per thread) — the allocs-per-batch proxy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Stats {
    /// buffers checked out via [`take`]
    pub takes: u64,
    /// checkouts that had to allocate because the class pool was empty
    pub misses: u64,
}

/// A pool of reusable `f32` buffers keyed by power-of-two size class.
pub struct Scratch {
    pools: Vec<Vec<Vec<f32>>>,
    stats: Stats,
}

impl Default for Scratch {
    fn default() -> Self {
        Scratch::new()
    }
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { pools: (0..CLASSES).map(|_| Vec::new()).collect(), stats: Stats::default() }
    }

    /// Class a request of `len` is served from: ceil(log₂ len), so every
    /// buffer parked there has capacity ≥ len.
    fn take_class(len: usize) -> usize {
        (len.max(1).next_power_of_two().trailing_zeros() as usize).min(CLASSES - 1)
    }

    /// Class a returned buffer parks in: floor(log₂ capacity), so its
    /// capacity covers every request served from that class.
    fn put_class(capacity: usize) -> usize {
        ((usize::BITS - 1 - capacity.leading_zeros()) as usize).min(CLASSES - 1)
    }

    /// Check out a zeroed buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        self.stats.takes += 1;
        match self.pools[Self::take_class(len)].pop() {
            Some(mut buf) => {
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => {
                self.stats.misses += 1;
                // capacity rounded up to the class size, so [`Scratch::put`]
                // parks this buffer back in the class it was served from
                // (exact-`len` capacity would land one class lower and the
                // pool would never warm for non-power-of-two sizes)
                let mut buf = Vec::with_capacity(len.max(1).next_power_of_two());
                buf.resize(len, 0.0);
                buf
            }
        }
    }

    /// Park a buffer for reuse.  Contents are irrelevant ([`Scratch::take`]
    /// re-zeroes); buffers beyond the per-class cap are dropped.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let class = Self::put_class(buf.capacity());
        let pool = &mut self.pools[class];
        if pool.len() < MAX_PER_CLASS {
            pool.push(buf);
        }
    }

    pub fn stats(&self) -> Stats {
        self.stats
    }
}

thread_local! {
    static ARENA: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Check out a zeroed buffer from this thread's arena.
pub fn take(len: usize) -> Vec<f32> {
    ARENA.with(|a| a.borrow_mut().take(len))
}

/// Return a buffer to this thread's arena.
pub fn put(buf: Vec<f32>) {
    ARENA.with(|a| a.borrow_mut().put(buf))
}

/// This thread's cumulative checkout counters.
pub fn stats() -> Stats {
    ARENA.with(|a| a.borrow().stats())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_sized() {
        let mut s = Scratch::new();
        let mut b = s.take(37);
        assert_eq!(b.len(), 37);
        assert!(b.iter().all(|v| *v == 0.0));
        b.iter_mut().for_each(|v| *v = 1.0);
        s.put(b);
        let b2 = s.take(37);
        assert_eq!(b2.len(), 37);
        assert!(b2.iter().all(|v| *v == 0.0), "recycled buffer must re-zero");
    }

    #[test]
    fn warm_pool_stops_missing() {
        let mut s = Scratch::new();
        let b = s.take(1000);
        s.put(b);
        assert_eq!(s.stats(), Stats { takes: 1, misses: 1 });
        // same class (513..=1024 all map to class 10) reuses the buffer
        for len in [1000usize, 513, 1024, 700] {
            let b = s.take(len);
            assert_eq!(b.len(), len);
            s.put(b);
        }
        assert_eq!(s.stats(), Stats { takes: 5, misses: 1 });
    }

    #[test]
    fn class_mapping_serves_capacity_covering_requests() {
        // a buffer parked at floor(log2 cap) must satisfy any take that
        // maps to the same class (ceil(log2 len))
        for cap in [1usize, 2, 3, 8, 1000, 1024, 1025] {
            let pc = Scratch::put_class(cap);
            assert!(cap >= 1 << pc);
        }
        for len in [1usize, 2, 3, 8, 1000, 1024, 1025] {
            let tc = Scratch::take_class(len);
            assert!(len <= 1 << tc);
        }
    }

    #[test]
    fn pool_is_bounded() {
        let mut s = Scratch::new();
        for _ in 0..3 * MAX_PER_CLASS {
            s.put(vec![0.0; 64]);
        }
        assert_eq!(s.pools[Scratch::put_class(64)].len(), MAX_PER_CLASS);
    }

    #[test]
    fn zero_len_take_is_safe() {
        let mut s = Scratch::new();
        let b = s.take(0);
        assert!(b.is_empty());
        s.put(b); // capacity 0: silently dropped
    }

    #[test]
    fn thread_local_front_compiles_and_counts() {
        let before = stats();
        let b = take(16);
        put(b);
        let after = stats();
        assert_eq!(after.takes, before.takes + 1);
    }
}
