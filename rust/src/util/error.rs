//! Hand-rolled error type with context chaining (anyhow is not in the
//! offline vendor set — same from-scratch philosophy as `util/json.rs`
//! and `util/rng.rs`).
//!
//! Mirrors exactly the slice of the `anyhow` API this crate uses: a
//! crate-wide [`Result`] alias, the [`crate::bail!`] macro, and a
//! [`Context`] extension trait for `Result` / `Option`.  Formatting
//! matches anyhow's conventions: `{e}` prints the outermost message,
//! `{e:#}` prints the whole cause chain separated by `": "` (the serving
//! logs and CLI fallbacks rely on the alternate form).

use std::fmt;

use crate::util::json::JsonError;

/// Crate-wide error: either a leaf (free-form message, I/O, JSON) or a
/// context frame wrapping a deeper cause.
#[derive(Debug)]
pub enum Error {
    /// Free-form message (`bail!`, `Error::msg`, `Option` context).
    Msg(String),
    /// An I/O failure, with the original error preserved as the source.
    Io(std::io::Error),
    /// A JSON parse failure from `util::json`.
    Json(JsonError),
    /// A higher-level context frame around a lower-level cause.
    Context { context: String, source: Box<Error> },
}

/// Crate-wide result alias (second parameter overridable, like anyhow's).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Leaf error from a message.
    pub fn msg(m: impl Into<String>) -> Error {
        Error::Msg(m.into())
    }

    /// Wrap `self` in a higher-level context frame.
    pub fn context(self, context: impl Into<String>) -> Error {
        Error::Context { context: context.into(), source: Box::new(self) }
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> Vec<String> {
        let mut out = vec![format!("{self}")];
        let mut cur: &(dyn std::error::Error) = self;
        while let Some(src) = cur.source() {
            out.push(format!("{src}"));
            cur = src;
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Msg(m) => f.write_str(m),
            Error::Io(e) => write!(f, "{e}"),
            Error::Json(e) => write!(f, "{e}"),
            Error::Context { context, source } => {
                f.write_str(context)?;
                if f.alternate() {
                    write!(f, ": {source:#}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Msg(_) => None,
            Error::Io(e) => Some(e),
            Error::Json(e) => Some(e),
            Error::Context { source, .. } => Some(source.as_ref()),
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

impl From<JsonError> for Error {
    fn from(e: JsonError) -> Error {
        Error::Json(e)
    }
}

impl From<String> for Error {
    fn from(m: String) -> Error {
        Error::Msg(m)
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Error {
        Error::Msg(m.to_string())
    }
}

impl From<std::string::FromUtf8Error> for Error {
    fn from(e: std::string::FromUtf8Error) -> Error {
        Error::Msg(format!("invalid utf-8: {e}"))
    }
}

impl From<crate::util::sync::mpsc::RecvError> for Error {
    fn from(_: crate::util::sync::mpsc::RecvError) -> Error {
        Error::Msg("reply channel closed (request failed on the worker)".into())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::Msg(format!("xla: {e}"))
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option` (the anyhow idiom the call sites were written
/// against).
pub trait Context<T> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T>;
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T> {
        self.ok_or_else(|| Error::Msg(msg.into()))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::Msg(f().into()))
    }
}

/// Early-return with a formatted [`Error::Msg`] (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf() -> Result<()> {
        Err(Error::msg("root cause"))
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let e = leaf().context("loading artifact").unwrap_err();
        assert_eq!(format!("{e}"), "loading artifact");
        assert_eq!(format!("{e:#}"), "loading artifact: root cause");
        assert_eq!(e.chain(), vec!["loading artifact", "root cause"]);
    }

    #[test]
    fn with_context_formats_lazily_built_message() {
        let e = leaf().with_context(|| format!("pass {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "pass 3: root cause");
    }

    #[test]
    fn bail_formats_arguments() {
        fn f(n: usize) -> Result<()> {
            crate::bail!("bad n {n}");
        }
        assert_eq!(format!("{}", f(3).unwrap_err()), "bad n 3");
    }

    #[test]
    fn option_context_is_a_leaf() {
        let v: Option<u32> = None;
        let e = v.context("tensor 'x' missing").unwrap_err();
        assert_eq!(format!("{e:#}"), "tensor 'x' missing");
    }

    #[test]
    fn io_source_preserved_through_context() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = Error::from(ioe).context("reading chip.json");
        assert!(format!("{e:#}").contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn json_error_converts() {
        let e: Error = crate::util::json::Json::parse("{").unwrap_err().into();
        assert!(format!("{e}").contains("json error"));
    }
}
