//! PJRT runtime: loads AOT HLO-text artifacts (produced once by
//! `python/compile/aot.py`) and executes them on the XLA CPU client.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialized protos use 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).  All artifacts are
//! lowered with `return_tuple=True`, so outputs unwrap with `to_tuple1`.
//!
//! Everything that touches PJRT lives in the `pjrt` submodule, compiled
//! only under the off-by-default `pjrt` cargo feature; the default build
//! is pure rust.  [`available_artifacts`] (plain directory inspection)
//! compiles in every configuration so the CLI and environment checks
//! work offline.

use std::path::Path;

use crate::util::error::{Context, Result};

/// Artifact names (`<name>.hlo.txt`) present under `dir`, sorted.
///
/// I/O failures (missing or unreadable directory) surface as errors
/// instead of an empty listing, so "no artifacts" always means the
/// directory was readable and genuinely empty.
pub fn available_artifacts(dir: &Path) -> Result<Vec<String>> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing artifacts dir {}", dir.display()))?;
    let mut names = Vec::new();
    for entry in entries {
        let entry = entry
            .with_context(|| format!("reading artifacts dir {}", dir.display()))?;
        if let Some(name) = entry
            .file_name()
            .to_str()
            .and_then(|n| n.strip_suffix(".hlo.txt"))
        {
            names.push(name.to_string());
        }
    }
    names.sort();
    Ok(names)
}

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};
