//! The PJRT-backed [`Runtime`] / [`Executable`] pair (pjrt feature only).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::tensor::Tensor;
use crate::util::error::{Context, Result};

/// A compiled, executable HLO module.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// The PJRT client plus a cache of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: BTreeMap<String, Executable>,
    pub artifacts_dir: PathBuf,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: BTreeMap::new(),
            artifacts_dir: artifacts_dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile `<name>.hlo.txt` from the artifacts dir (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                bail!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("utf-8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(
                name.to_string(),
                Executable { exe, name: name.to_string() },
            );
        }
        Ok(&self.cache[name])
    }

    /// Artifact names available on disk (I/O errors surface, they are not
    /// swallowed into an empty listing).
    pub fn available(&self) -> Result<Vec<String>> {
        super::available_artifacts(&self.artifacts_dir)
    }
}

impl Executable {
    /// Execute with f32 tensors; returns the elements of the 1-tuple output
    /// as a flat f32 vector (output shapes are fixed by the AOT signature,
    /// which the caller knows).
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<f32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> =
                    t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .context("literal reshape")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0].to_literal_sync()?;
        let tuple = lit.to_tuple1().context("unwrapping 1-tuple output")?;
        Ok(tuple.to_vec::<f32>()?)
    }
}
