//! Receive chain: photodiode → TIA → ADC (paper Fig. 1b output path).
//!
//! The PD sums all wavelengths on its column bus (WDM accumulation — the
//! "free" adds of the MVM); the TIA and ADC set the electrical power floor
//! that dominates total power at high rates (paper Fig. S16b/f).

/// Photodiode with responsivity, dark current and noise parameters.
#[derive(Clone, Copy, Debug)]
pub struct Photodiode {
    /// responsivity (A/W)
    pub responsivity: f64,
    /// dark current (A)
    pub dark_a: f64,
    /// electrical bandwidth (Hz)
    pub bandwidth_hz: f64,
}

impl Photodiode {
    pub fn typical() -> Photodiode {
        Photodiode { responsivity: 1.0, dark_a: 50e-9, bandwidth_hz: 30e9 }
    }

    /// Photocurrent (A) for incident optical power (W), including dark.
    pub fn current(&self, power_w: f64) -> f64 {
        self.responsivity * power_w + self.dark_a
    }

    /// Shot-noise RMS current (A): sqrt(2 q I B).
    pub fn shot_noise_a(&self, power_w: f64) -> f64 {
        const Q_E: f64 = 1.602e-19;
        (2.0 * Q_E * self.current(power_w) * self.bandwidth_hz).sqrt()
    }

    /// Minimum detectable optical power (W) for a target SNR (linear) given
    /// thermal-noise-equivalent current `i_th` (A RMS) — sets the laser
    /// budget floor (paper: "minimum required laser power must overcome the
    /// capacitance and shot noise of the photodetector").
    pub fn sensitivity_w(&self, snr: f64, i_th: f64) -> f64 {
        // solve R·P = snr · sqrt(shot² + th²); iterate twice (shot depends on P)
        let mut p = snr * i_th / self.responsivity;
        for _ in 0..20 {
            let noise = (self.shot_noise_a(p).powi(2) + i_th * i_th).sqrt();
            p = snr * noise / self.responsivity;
        }
        p
    }
}

/// Trans-impedance amplifier (off-chip in the prototype; paper cites
/// 0.65 pJ/bit for a 28-nm receiver front-end).
#[derive(Clone, Copy, Debug)]
pub struct Tia {
    pub energy_per_bit_j: f64,
    pub gain_ohm: f64,
}

impl Tia {
    pub fn paper() -> Tia {
        Tia { energy_per_bit_j: 0.65e-12, gain_ohm: 10e3 }
    }

    /// Output voltage for an input photocurrent.
    pub fn volts(&self, current_a: f64) -> f64 {
        current_a * self.gain_ohm
    }

    /// Power (W) at bit rate `bps`.
    pub fn power_w(&self, bps: f64) -> f64 {
        self.energy_per_bit_j * bps
    }
}

/// ADC power model (paper cites 39 mW @ 10 GHz, 194 mW @ 25 GHz).
/// Interpolate as a power law P = a·f^k through the two cited points.
#[derive(Clone, Copy, Debug)]
pub struct Adc {
    pub a: f64,
    pub k: f64,
}

impl Adc {
    pub fn paper() -> Adc {
        // fit through (10 GHz, 39 mW) and (25 GHz, 194 mW)
        let k = (194.0f64 / 39.0).ln() / (25.0f64 / 10.0).ln();
        let a = 39e-3 / (10e9f64).powf(k);
        Adc { a, k }
    }

    pub fn power_w(&self, f_hz: f64) -> f64 {
        self.a * f_hz.powf(self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pd_current_includes_dark() {
        let pd = Photodiode::typical();
        assert!((pd.current(1e-3) - (1e-3 + 50e-9)).abs() < 1e-12);
    }

    #[test]
    fn shot_noise_grows_with_power() {
        let pd = Photodiode::typical();
        assert!(pd.shot_noise_a(1e-3) > pd.shot_noise_a(1e-6));
    }

    #[test]
    fn sensitivity_converges_and_scales() {
        let pd = Photodiode::typical();
        let p1 = pd.sensitivity_w(10.0, 1e-6);
        let p2 = pd.sensitivity_w(100.0, 1e-6);
        assert!(p1.is_finite() && p1 > 0.0);
        assert!(p2 > p1, "higher SNR needs more power");
    }

    #[test]
    fn tia_power_paper_value() {
        // 0.65 pJ/bit at 10 Gb/s = 6.5 mW
        assert!((Tia::paper().power_w(10e9) - 6.5e-3).abs() < 1e-9);
    }

    #[test]
    fn adc_fits_both_paper_points() {
        let adc = Adc::paper();
        assert!((adc.power_w(10e9) - 39e-3).abs() < 1e-6);
        assert!((adc.power_w(25e9) - 194e-3).abs() < 1e-6);
    }

    #[test]
    fn adc_superlinear() {
        let adc = Adc::paper();
        assert!(adc.k > 1.0, "ADC power superlinear in rate, k={}", adc.k);
    }
}
