//! Waveguide / coupler loss bookkeeping — the insertion-loss budget along
//! the CirPTC critical path (paper Fig. S14: loss increases linearly with
//! matrix size; laser power therefore grows exponentially, Fig. S16e).

/// Per-element loss constants for the CirPTC critical path (dB).
#[derive(Clone, Copy, Debug)]
pub struct LossBudget {
    /// fiber-chip edge coupler (per facet)
    pub edge_coupler_db: f64,
    /// MZM insertion loss
    pub mzm_db: f64,
    /// weight-encoding MRR drop-path loss (per serial ring traversed)
    pub weight_ring_db: f64,
    /// crossbar switch ring through-port loss (per ring passed on the bus)
    pub switch_through_db: f64,
    /// crossbar switch ring drop-port loss (the one routing event)
    pub switch_drop_db: f64,
    /// waveguide propagation (dB/mm) and crossing loss
    pub propagation_db_per_mm: f64,
    pub crossing_db: f64,
}

impl LossBudget {
    /// Values representative of the AIM PDK devices the paper uses.
    pub fn paper() -> LossBudget {
        LossBudget {
            edge_coupler_db: 1.5,
            mzm_db: 2.5,
            weight_ring_db: 0.6,
            switch_through_db: 0.10,
            switch_drop_db: 1.2,
            propagation_db_per_mm: 0.2,
            crossing_db: 0.02,
        }
    }

    /// Worst-case (critical-path) insertion loss of an N×M CirPTC (dB).
    ///
    /// Path: edge coupler → MZM → N/l serial weight rings (one drop, rest
    /// through) → row bus across M switch through-ports → one switch drop →
    /// column bus down N through-ports → PD.  Linear in M and N, matching
    /// Fig. S14.
    pub fn cirptc_critical_path_db(&self, n: usize, m: usize, l: usize) -> f64 {
        let serial_rings = (n / l).max(1) as f64;
        let path_mm = 0.02 * (n + m) as f64 + 1.0; // geometric route length
        self.edge_coupler_db
            + self.mzm_db
            + self.weight_ring_db                     // the encoding drop
            + (serial_rings - 1.0) * self.switch_through_db
            + m as f64 * self.switch_through_db
            + self.switch_drop_db
            + n as f64 * self.switch_through_db
            + (n.saturating_sub(1)) as f64 * self.crossing_db
            + path_mm * self.propagation_db_per_mm
    }

    /// Uncompressed MRR-crossbar baseline: every cell is an *active*
    /// weighting ring whose partial drop leaves more loss in the bus, and
    /// there is no serial-rail sharing.
    pub fn uncompressed_critical_path_db(&self, n: usize, m: usize) -> f64 {
        let active_through_db = self.switch_through_db * 2.2; // active rings leak more
        let path_mm = 0.02 * (n + m) as f64 + 1.0;
        self.edge_coupler_db
            + self.mzm_db
            + m as f64 * active_through_db
            + self.switch_drop_db
            + n as f64 * active_through_db
            + (n.saturating_sub(1)) as f64 * self.crossing_db
            + path_mm * self.propagation_db_per_mm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_linear_in_size() {
        let b = LossBudget::paper();
        let l8 = b.cirptc_critical_path_db(8, 8, 4);
        let l16 = b.cirptc_critical_path_db(16, 16, 4);
        let l32 = b.cirptc_critical_path_db(32, 32, 4);
        // linearity: equal increments for equal size steps (Fig. S14)
        let d1 = l16 - l8;
        let d2 = l32 - l16;
        assert!((d2 / d1 - 2.0).abs() < 0.15, "d1={d1} d2={d2}");
    }

    #[test]
    fn reasonable_absolute_values() {
        let b = LossBudget::paper();
        let l = b.cirptc_critical_path_db(48, 48, 4);
        assert!(l > 5.0 && l < 25.0, "48x48 IL = {l} dB");
    }

    #[test]
    fn uncompressed_lossier_than_cirptc() {
        let b = LossBudget::paper();
        for s in [16usize, 48, 64] {
            assert!(
                b.uncompressed_critical_path_db(s, s)
                    > b.cirptc_critical_path_db(s, s, 4)
            );
        }
    }

    #[test]
    fn grows_with_each_dim() {
        let b = LossBudget::paper();
        let base = b.cirptc_critical_path_db(16, 16, 4);
        assert!(b.cirptc_critical_path_db(32, 16, 4) > base);
        assert!(b.cirptc_critical_path_db(16, 32, 4) > base);
    }
}
