//! Add-drop microring resonator (MRR) model.
//!
//! The CirPTC uses MRRs in two roles (paper Fig. 2):
//!   * serial **weight-encoding** rings — thermally detuned off resonance to
//!     set a drop-port amplitude in [0, peak] (Fig. 2f, one branch of the
//!     Lorentzian to avoid spectral overlap);
//!   * crossbar **switch** rings — statically calibrated onto one WDM
//!     channel, redirecting that wavelength to a column bus.
//!
//! The drop-port intensity response near resonance is Lorentzian:
//!     T(δ) = peak / (1 + (2 δ / FWHM)²),  FWHM = λ / Q.

#[derive(Clone, Copy, Debug)]
pub struct Mrr {
    /// loaded quality factor
    pub q: f64,
    /// resonance wavelength (nm)
    pub lambda_nm: f64,
    /// peak drop-port transmission (≤ 1; asymmetric lossy coupling in the
    /// paper gives < 1, producing the "forbidden zone" of Fig. 2f)
    pub peak: f64,
    /// through-port insertion loss at far detuning (dB, positive number)
    pub through_loss_db: f64,
}

impl Mrr {
    pub fn new(q: f64, lambda_nm: f64) -> Mrr {
        Mrr { q, lambda_nm, peak: 0.95, through_loss_db: 0.01 }
    }

    /// Full-width half-maximum linewidth (nm).
    pub fn fwhm_nm(&self) -> f64 {
        self.lambda_nm / self.q
    }

    /// Drop-port transmission at detuning `delta_nm` from resonance.
    pub fn drop_transmission(&self, delta_nm: f64) -> f64 {
        let x = 2.0 * delta_nm / self.fwhm_nm();
        self.peak / (1.0 + x * x)
    }

    /// Drop-port *amplitude* (field) transmission — sqrt of intensity.
    pub fn drop_amplitude(&self, delta_nm: f64) -> f64 {
        self.drop_transmission(delta_nm).sqrt()
    }

    /// Detuning (nm, ≤ 0: left branch as in Fig. 2f) that realises a target
    /// drop transmission `t` in (0, peak].
    pub fn detuning_for(&self, t: f64) -> f64 {
        let t = t.clamp(1e-9, self.peak);
        -0.5 * self.fwhm_nm() * (self.peak / t - 1.0).sqrt()
    }

    /// Free spectral range (nm) for a ring of radius `radius_um` with group
    /// index `ng`: FSR = λ² / (2π R n_g).
    pub fn fsr_nm(radius_um: f64, ng: f64, lambda_nm: f64) -> f64 {
        let lambda_m = lambda_nm * 1e-9;
        let circumference = 2.0 * std::f64::consts::PI * radius_um * 1e-6;
        lambda_m * lambda_m / (circumference * ng) * 1e9
    }

    /// Thermal tuning power (mW) to shift resonance by `delta_nm`, given a
    /// tuning efficiency in nm/mW (typ. ~0.25 nm/mW for foundry heaters).
    pub fn tuning_power_mw(delta_nm: f64, nm_per_mw: f64) -> f64 {
        delta_nm.abs() / nm_per_mw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring() -> Mrr {
        Mrr::new(1.0e4, 1550.0)
    }

    #[test]
    fn peak_at_resonance() {
        let m = ring();
        assert!((m.drop_transmission(0.0) - m.peak).abs() < 1e-12);
    }

    #[test]
    fn half_power_at_half_fwhm() {
        let m = ring();
        let t = m.drop_transmission(m.fwhm_nm() / 2.0);
        assert!((t - m.peak / 2.0).abs() < 1e-9);
    }

    #[test]
    fn detuning_roundtrip() {
        let m = ring();
        for target in [0.05, 0.3, 0.6, 0.9] {
            let d = m.detuning_for(target);
            assert!(d <= 0.0, "left branch");
            assert!((m.drop_transmission(d) - target).abs() < 1e-9);
        }
    }

    #[test]
    fn monotone_on_branch() {
        let m = ring();
        let mut last = f64::INFINITY;
        for i in 0..100 {
            let d = -(i as f64) * m.fwhm_nm() / 20.0;
            let t = m.drop_transmission(d);
            assert!(t <= last);
            last = t;
        }
    }

    #[test]
    fn higher_q_narrower_line() {
        let lo = Mrr::new(1e4, 1550.0);
        let hi = Mrr::new(1e5, 1550.0);
        assert!(hi.fwhm_nm() < lo.fwhm_nm());
        // at the same absolute detuning the high-Q ring leaks less
        assert!(hi.drop_transmission(0.1) < lo.drop_transmission(0.1));
    }

    #[test]
    fn fsr_physical_range() {
        // 5 µm ring, ng 4.2: FSR ≈ 18 nm (silicon photonics textbook value)
        let fsr = Mrr::fsr_nm(5.0, 4.2, 1550.0);
        assert!(fsr > 15.0 && fsr < 22.0, "fsr={fsr}");
    }

    #[test]
    fn tuning_power_linear() {
        assert!((Mrr::tuning_power_mw(0.5, 0.25) - 2.0).abs() < 1e-12);
        assert!((Mrr::tuning_power_mw(-0.5, 0.25) - 2.0).abs() < 1e-12);
    }
}
