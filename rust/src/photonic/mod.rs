//! Photonic device physics — the substrate the paper's testbed provides in
//! silicon (paper Fig. 2 d-f fits these same models to chip measurements).
//!
//! Everything is deterministic, unit-tested math; stochastic behaviour
//! (noise, fabrication variance) lives in [`crate::simulator`].

pub mod detector;
pub mod mrr;
pub mod mzm;
pub mod waveguide;

pub use detector::{Adc, Photodiode, Tia};
pub use mrr::Mrr;
pub use mzm::Mzm;

/// Speed of light (m/s) — used for FSR/group-delay conversions.
pub const C_M_S: f64 = 2.998e8;

/// Default operating wavelength (nm), C-band as in the paper (1545–1563 nm).
pub const LAMBDA_NM: f64 = 1550.0;

/// Convert a dB value to a linear power ratio.
#[inline]
pub fn db_to_lin(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Convert a linear power ratio to dB.
#[inline]
pub fn lin_to_db(lin: f64) -> f64 {
    10.0 * lin.log10()
}

/// dBm to milliwatts.
#[inline]
pub fn dbm_to_mw(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0)
}

/// Milliwatts to dBm.
#[inline]
pub fn mw_to_dbm(mw: f64) -> f64 {
    10.0 * mw.log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_roundtrip() {
        for db in [-30.0, -3.0, 0.0, 3.0, 10.0] {
            assert!((lin_to_db(db_to_lin(db)) - db).abs() < 1e-9);
        }
    }

    #[test]
    fn db3_is_half() {
        assert!((db_to_lin(-3.0103) - 0.5).abs() < 1e-4);
    }

    #[test]
    fn dbm_zero_is_1mw() {
        assert!((dbm_to_mw(0.0) - 1.0).abs() < 1e-12);
        assert!((mw_to_dbm(1.0)).abs() < 1e-12);
    }
}
