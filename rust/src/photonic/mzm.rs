//! Mach–Zehnder modulator (MZM) model — the broadband input encoder.
//!
//! The paper encodes the input vector x with MZMs because their
//! interference-based transfer is wavelength-flat across the four WDM
//! channels (unlike a ring), letting one device modulate all wavelengths of
//! one crossbar row simultaneously (Fig. 2e).  Push-pull, biased at null:
//!
//! ```text
//! T(v) = sin^2(pi * v / (2 * V_pi))
//! ```
//!
//! with extinction limited by imbalance (finite ER).

#[derive(Clone, Copy, Debug)]
pub struct Mzm {
    /// half-wave voltage (V)
    pub v_pi: f64,
    /// extinction ratio (dB) — floor of the off state
    pub er_db: f64,
    /// energy per programmed symbol (J); thermo-optic in the prototype,
    /// 0.35 pJ for the MOSCAP projection (paper Discussion)
    pub energy_per_symbol_j: f64,
}

impl Mzm {
    /// The thermo-optic PDK device used in the fabricated prototype
    /// (tens-of-kHz tuning, paper "tuning speed of tens of KHz").
    pub fn thermo_optic() -> Mzm {
        Mzm { v_pi: 1.0, er_db: 25.0, energy_per_symbol_j: 12e-12 }
    }

    /// Carrier-accumulation MOSCAP projection (paper: 0.35 pJ/symbol).
    pub fn moscap() -> Mzm {
        Mzm { v_pi: 1.0, er_db: 22.0, energy_per_symbol_j: 0.35e-12 }
    }

    /// Intensity transfer at drive voltage v.
    pub fn transmission(&self, v: f64) -> f64 {
        let ideal = (std::f64::consts::PI * v / (2.0 * self.v_pi)).sin().powi(2);
        let floor = 10f64.powf(-self.er_db / 10.0);
        floor + (1.0 - floor) * ideal
    }

    /// Drive voltage realising intensity x ∈ [0, 1] (inverse transfer,
    /// ignoring the extinction floor — the calibration LUT absorbs it).
    pub fn drive_for(&self, x: f64) -> f64 {
        let x = x.clamp(0.0, 1.0);
        2.0 * self.v_pi / std::f64::consts::PI * x.sqrt().asin()
    }

    /// Encoding power (W) at symbol rate `f_sym` Hz.
    pub fn encode_power_w(&self, f_sym: f64) -> f64 {
        self.energy_per_symbol_j * f_sym
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_and_peak() {
        let m = Mzm::moscap();
        assert!(m.transmission(0.0) < 0.01); // extinction floor
        assert!((m.transmission(m.v_pi) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn drive_roundtrip() {
        let m = Mzm::moscap();
        for x in [0.1, 0.25, 0.5, 0.75, 0.99] {
            let v = m.drive_for(x);
            // roundtrip error bounded by extinction floor
            assert!((m.transmission(v) - x).abs() < 0.01, "x={x}");
        }
    }

    #[test]
    fn monotone_drive_range() {
        let m = Mzm::thermo_optic();
        let mut last = -1.0;
        for i in 0..=100 {
            let t = m.transmission(m.v_pi * i as f64 / 100.0);
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn moscap_energy_matches_paper() {
        // paper Discussion: "each MOSCAP MZM consumes 0.35 pJ per symbol"
        let m = Mzm::moscap();
        assert!((m.encode_power_w(10e9) - 3.5e-3).abs() < 1e-9); // 3.5 mW @10 GHz
    }

    #[test]
    fn extinction_floor_positive() {
        let m = Mzm::thermo_optic();
        assert!(m.transmission(0.0) > 0.0);
        assert!(m.transmission(0.0) < 10f64.powf(-2.0)); // better than 20 dB
    }
}
