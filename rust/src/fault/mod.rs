//! Fault injection + self-healing supervision (DESIGN.md §fault).
//!
//! Two halves, both deterministic:
//!
//! * [`FaultPlan`] — a seeded schedule of abrupt-fault episodes injected
//!   into [`crate::simulator::ChipSim`] on the same pass-count clock the
//!   drift model uses ([`crate::drift::DriftModel::on_pass`]).  Every
//!   episode is `(start_pass, duration, kind)`, so a chaos run replays
//!   exactly from its seed + plan.
//! * [`ChipSupervisor`] — the probe-driven health authority that closes
//!   the ROADMAP loop ("probe-driven automatic fail()/restore()"):
//!   consecutive bad probes drive an automatic `Fail` verdict, a
//!   probation state demands N clean probes off the serving path before
//!   `Restore`, and M failed probations latch `Quarantine` for operator
//!   escalation.
//!
//! The farm applies supervisor verdicts to [`crate::farm::ChipStatus`];
//! the router + pipeline add bounded retry, per-pass deadlines and
//! degradation to the digital reference backend (see
//! [`crate::coordinator::pipeline`] and [`crate::farm::router`]).

use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// fault plan
// ---------------------------------------------------------------------------

/// One abrupt-fault failure mode.  The taxonomy follows the
/// photonic-accelerator nonideality surveys cited in ISSUE/PAPERS:
/// whole-die loss, localized stuck hardware, transient readout garbage,
/// non-finite readout, and a bounded excess-noise episode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Total die loss: every readout is zero.  Silent — only a
    /// calibration probe notices (huge residual).
    DeadChip,
    /// The first `rows` output rows are stuck at the dark level
    /// (e.g. a dead detector bank).  Silent, probe-detected.
    StuckTiles { rows: usize },
    /// With probability `p` per pass the whole readout is replaced by
    /// garbage, and the pass reports a detectable readout error (models
    /// a CRC/parity trip on the ADC link).
    TransientPassError { p: f32 },
    /// Readout returns NaN and reports a detectable error.
    NaNReadout,
    /// Additive Gaussian excess noise of `gain` for up to `ticks`
    /// passes inside the episode.  Silent, degrades accuracy.
    NoiseBurst { gain: f32, ticks: u64 },
}

impl FaultKind {
    /// Stable tag used in the JSON plan format.
    pub fn tag(&self) -> &'static str {
        match self {
            FaultKind::DeadChip => "dead_chip",
            FaultKind::StuckTiles { .. } => "stuck_tiles",
            FaultKind::TransientPassError { .. } => "transient_pass_error",
            FaultKind::NaNReadout => "nan_readout",
            FaultKind::NoiseBurst { .. } => "noise_burst",
        }
    }
}

/// One scheduled fault: `kind` is active for passes in
/// `[start_pass, start_pass + duration)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Episode {
    pub start_pass: u64,
    pub duration: u64,
    pub kind: FaultKind,
}

impl Episode {
    fn active_at(&self, pass: u64) -> bool {
        pass >= self.start_pass
            && pass - self.start_pass < self.duration
    }
}

/// A deterministic, replayable schedule of fault episodes for one chip.
/// Lives inside [`crate::simulator::ChipSim`] and is advanced once per
/// crossbar pass, mirroring the drift clock.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    episodes: Vec<Episode>,
    rng: Rng,
    passes: u64,
    injected: u64,
}

impl FaultPlan {
    pub fn new(seed: u64, episodes: Vec<Episode>) -> FaultPlan {
        FaultPlan {
            seed,
            episodes,
            rng: Rng::new(seed ^ 0xFA_17_FA_17),
            passes: 0,
            injected: 0,
        }
    }

    /// A small randomized chaos plan: one hard-loss episode (DeadChip or
    /// NaNReadout), one transient episode, and one noise burst, with
    /// seeded starts/durations.  `cirptc chaos --seed S` prints this.
    pub fn generate(seed: u64) -> FaultPlan {
        let mut r = Rng::new(seed ^ 0xC4_A0_5C_4A);
        let hard = if r.f32() < 0.5 {
            FaultKind::DeadChip
        } else {
            FaultKind::NaNReadout
        };
        let episodes = vec![
            Episode {
                start_pass: 20 + r.below(40) as u64,
                duration: 20 + r.below(40) as u64,
                kind: hard,
            },
            Episode {
                start_pass: 10 + r.below(30) as u64,
                duration: 30 + r.below(60) as u64,
                kind: FaultKind::TransientPassError {
                    p: 0.1 + 0.3 * r.f32(),
                },
            },
            Episode {
                start_pass: 40 + r.below(80) as u64,
                duration: 10 + r.below(30) as u64,
                kind: FaultKind::NoiseBurst {
                    gain: 0.05 + 0.1 * r.f32(),
                    ticks: 8 + r.below(16) as u64,
                },
            },
        ];
        FaultPlan::new(seed, episodes)
    }

    /// The plan's base RNG seed (member farms derive per-chip streams
    /// by XOR-ing the member index in).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn episodes(&self) -> &[Episode] {
        &self.episodes
    }

    /// Passes observed so far (the plan's clock position).
    pub fn passes(&self) -> u64 {
        self.passes
    }

    /// Total passes whose readout this plan corrupted.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Latest pass at which any episode is still active; after this the
    /// plan is inert and the chip can recover.
    pub fn last_active_pass(&self) -> u64 {
        self.episodes
            .iter()
            .map(|e| e.start_pass + e.duration)
            .max()
            .unwrap_or(0)
    }

    /// Advance the fault clock by one crossbar pass and corrupt `ybuf`
    /// (row-major `[rows, cols]` readout, `cols` batch columns) in
    /// place according to the active episodes.  Returns the event tag
    /// when the fault is *detectable at the readout interface* (CRC
    /// trip / non-finite check); silent faults return `None` and are
    /// left for calibration probes to catch.
    pub fn on_pass(
        &mut self,
        ybuf: &mut [f32],
        cols: usize,
        dark: f32,
    ) -> Option<&'static str> {
        let pass = self.passes;
        self.passes += 1;
        let mut event = None;
        let mut hit = false;
        for i in 0..self.episodes.len() {
            let ep = self.episodes[i];
            if !ep.active_at(pass) {
                continue;
            }
            match ep.kind {
                FaultKind::DeadChip => {
                    ybuf.fill(0.0);
                    hit = true;
                }
                FaultKind::StuckTiles { rows } => {
                    let n = (rows * cols.max(1)).min(ybuf.len());
                    ybuf[..n].fill(dark);
                    hit = n > 0;
                }
                FaultKind::TransientPassError { p } => {
                    // one seeded draw per active pass keeps the plan
                    // replayable regardless of batch shape
                    let u = self.rng.f32();
                    if u < p {
                        for v in ybuf.iter_mut() {
                            *v = (self.rng.f32() - 0.5) * 1e3;
                        }
                        hit = true;
                        event = Some("transient_pass_error");
                    }
                }
                FaultKind::NaNReadout => {
                    ybuf.fill(f32::NAN);
                    hit = true;
                    event = Some("nan_readout");
                }
                FaultKind::NoiseBurst { gain, ticks } => {
                    if pass - ep.start_pass < ticks {
                        for v in ybuf.iter_mut() {
                            *v += gain * self.rng.normal() as f32;
                        }
                        hit = true;
                    }
                }
            }
        }
        if hit {
            self.injected += 1;
        }
        event
    }

    // -- JSON plan format ---------------------------------------------------

    /// Serialize the plan *spec* (seed + episodes).  The runtime clock
    /// and RNG position are not part of the spec: parsing the dump
    /// yields a fresh plan that replays identically from pass 0.
    pub fn to_json(&self) -> Json {
        let eps: Vec<Json> = self
            .episodes
            .iter()
            .map(|e| {
                let mut pairs = vec![
                    ("start_pass", Json::Num(e.start_pass as f64)),
                    ("duration", Json::Num(e.duration as f64)),
                    ("kind", Json::Str(e.kind.tag().to_string())),
                ];
                match e.kind {
                    FaultKind::StuckTiles { rows } => {
                        pairs.push(("rows", Json::Num(rows as f64)));
                    }
                    FaultKind::TransientPassError { p } => {
                        pairs.push(("p", Json::Num(p as f64)));
                    }
                    FaultKind::NoiseBurst { gain, ticks } => {
                        pairs.push(("gain", Json::Num(gain as f64)));
                        pairs.push(("ticks", Json::Num(ticks as f64)));
                    }
                    FaultKind::DeadChip | FaultKind::NaNReadout => {}
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("episodes", Json::Arr(eps)),
        ])
    }

    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        let seed = j
            .get("seed")
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::msg("fault plan: missing numeric `seed`"))?
            as u64;
        let eps = j
            .get("episodes")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::msg("fault plan: missing `episodes` array"))?;
        let mut episodes = Vec::with_capacity(eps.len());
        for (i, e) in eps.iter().enumerate() {
            let field = |k: &str| -> Result<f64> {
                e.get(k).and_then(Json::as_f64).ok_or_else(|| {
                    Error::msg(format!(
                        "fault plan episode {i}: missing numeric `{k}`"
                    ))
                })
            };
            let tag = e.get("kind").and_then(Json::as_str).ok_or_else(|| {
                Error::msg(format!("fault plan episode {i}: missing `kind`"))
            })?;
            let kind = match tag {
                "dead_chip" => FaultKind::DeadChip,
                "stuck_tiles" => {
                    FaultKind::StuckTiles { rows: field("rows")? as usize }
                }
                "transient_pass_error" => {
                    FaultKind::TransientPassError { p: field("p")? as f32 }
                }
                "nan_readout" => FaultKind::NaNReadout,
                "noise_burst" => FaultKind::NoiseBurst {
                    gain: field("gain")? as f32,
                    ticks: field("ticks")? as u64,
                },
                other => {
                    return Err(Error::msg(format!(
                        "fault plan episode {i}: unknown kind `{other}`"
                    )))
                }
            };
            episodes.push(Episode {
                start_pass: field("start_pass")? as u64,
                duration: field("duration")? as u64,
                kind,
            });
        }
        Ok(FaultPlan::new(seed, episodes))
    }

    pub fn parse(text: &str) -> Result<FaultPlan> {
        let j = Json::parse(text)
            .map_err(|e| Error::msg(format!("fault plan: {e}")))?;
        FaultPlan::from_json(&j)
    }
}

// ---------------------------------------------------------------------------
// supervisor
// ---------------------------------------------------------------------------

/// Policy knobs for [`ChipSupervisor`].
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// A probe residual at or above this (or any non-finite residual)
    /// counts as a failed probe.  This is the *hard* ceiling — well
    /// above the drift monitor's recalibration trigger.
    pub residual_ceiling: f32,
    /// Consecutive failed probes while serving before the automatic
    /// `Fail` verdict.
    pub consecutive_failures: u32,
    /// Clean probes required, off the serving path, before the
    /// automatic `Restore` verdict.
    pub probation_probes: u32,
    /// Failed probation attempts before the latched `Quarantine`
    /// verdict escalates to the operator.
    pub max_probations: u32,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            residual_ceiling: 0.05,
            consecutive_failures: 2,
            probation_probes: 3,
            max_probations: 3,
        }
    }
}

/// Supervisor position in the self-healing state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SupervisorState {
    /// Member serves traffic; probes ride the serving cadence.
    Serving,
    /// Member is failed out of routing; idle-path probes decide whether
    /// it comes back.
    Probation,
    /// Latched: automatic recovery gave up after `max_probations`
    /// failed attempts.  Only an operator `restore()` clears it.
    Quarantined,
}

/// Action the farm must apply to the member's [`crate::farm::ChipStatus`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Take the member out of routing (`ChipStatus::fail`).
    Fail,
    /// Probation passed: return the member to service
    /// (`ChipStatus::restore`).
    Restore,
    /// Escalate: automatic recovery exhausted
    /// (`ChipStatus::quarantine`).
    Quarantine,
}

/// Probe-driven health authority for one farm member.  Pure state
/// machine: callers feed probe residuals (and detected pass faults) in,
/// verdicts come out; applying them to routing is the farm's job.
#[derive(Clone, Debug)]
pub struct ChipSupervisor {
    cfg: SupervisorConfig,
    state: SupervisorState,
    bad_streak: u32,
    clean_streak: u32,
    probations: u32,
}

impl ChipSupervisor {
    pub fn new(cfg: SupervisorConfig) -> ChipSupervisor {
        ChipSupervisor {
            cfg,
            state: SupervisorState::Serving,
            bad_streak: 0,
            clean_streak: 0,
            probations: 0,
        }
    }

    pub fn state(&self) -> SupervisorState {
        self.state
    }

    pub fn is_quarantined(&self) -> bool {
        self.state == SupervisorState::Quarantined
    }

    /// Feed one probe residual; returns the verdict the farm must apply,
    /// if any.  Non-finite residuals are failed probes by definition.
    pub fn observe(&mut self, residual: f32) -> Option<Verdict> {
        let bad = !residual.is_finite()
            || residual >= self.cfg.residual_ceiling;
        match self.state {
            SupervisorState::Quarantined => None,
            SupervisorState::Serving => {
                if bad {
                    self.bad_streak += 1;
                    if self.bad_streak >= self.cfg.consecutive_failures {
                        self.state = SupervisorState::Probation;
                        self.bad_streak = 0;
                        self.clean_streak = 0;
                        return Some(Verdict::Fail);
                    }
                } else {
                    self.bad_streak = 0;
                }
                None
            }
            SupervisorState::Probation => {
                if bad {
                    self.clean_streak = 0;
                    self.probations += 1;
                    if self.probations >= self.cfg.max_probations {
                        self.state = SupervisorState::Quarantined;
                        return Some(Verdict::Quarantine);
                    }
                    None
                } else {
                    self.clean_streak += 1;
                    if self.clean_streak >= self.cfg.probation_probes {
                        self.state = SupervisorState::Serving;
                        self.bad_streak = 0;
                        self.clean_streak = 0;
                        self.probations = 0;
                        return Some(Verdict::Restore);
                    }
                    None
                }
            }
        }
    }

    /// A fault detected outside the probe path (readout error, pass
    /// deadline): equivalent to the worst possible probe.
    pub fn note_fault(&mut self) -> Option<Verdict> {
        self.observe(f32::INFINITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SupervisorConfig {
        SupervisorConfig {
            residual_ceiling: 0.1,
            consecutive_failures: 2,
            probation_probes: 2,
            max_probations: 2,
        }
    }

    #[test]
    fn supervisor_fails_after_consecutive_bad_probes_only() {
        let mut s = ChipSupervisor::new(cfg());
        // a single bad probe is not enough; a clean one resets the streak
        assert_eq!(s.observe(0.5), None);
        assert_eq!(s.observe(0.01), None);
        assert_eq!(s.observe(0.5), None);
        assert_eq!(s.observe(0.5), Some(Verdict::Fail));
        assert_eq!(s.state(), SupervisorState::Probation);
    }

    #[test]
    fn supervisor_restores_after_clean_probation() {
        let mut s = ChipSupervisor::new(cfg());
        s.observe(f32::NAN);
        assert_eq!(s.observe(f32::NAN), Some(Verdict::Fail));
        // one clean probe is not enough to restore
        assert_eq!(s.observe(0.0), None);
        assert_eq!(s.observe(0.0), Some(Verdict::Restore));
        assert_eq!(s.state(), SupervisorState::Serving);
        // fully reset: the next failure needs a fresh streak
        assert_eq!(s.observe(0.5), None);
        assert_eq!(s.observe(0.5), Some(Verdict::Fail));
    }

    #[test]
    fn supervisor_quarantines_after_failed_probations_and_latches() {
        let mut s = ChipSupervisor::new(cfg());
        s.observe(0.5);
        assert_eq!(s.observe(0.5), Some(Verdict::Fail));
        // probation attempt 1 fails (bad probe mid-probation)
        assert_eq!(s.observe(0.0), None);
        assert_eq!(s.observe(0.5), None);
        // probation attempt 2 fails => latched quarantine
        assert_eq!(s.observe(0.5), Some(Verdict::Quarantine));
        assert!(s.is_quarantined());
        // latched: even perfect probes produce no further verdicts
        for _ in 0..10 {
            assert_eq!(s.observe(0.0), None);
        }
        assert!(s.is_quarantined());
    }

    #[test]
    fn note_fault_counts_as_bad_probe() {
        let mut s = ChipSupervisor::new(cfg());
        assert_eq!(s.note_fault(), None);
        assert_eq!(s.note_fault(), Some(Verdict::Fail));
    }

    #[test]
    fn plan_is_deterministic_and_episode_scoped() {
        let eps = vec![
            Episode {
                start_pass: 2,
                duration: 3,
                kind: FaultKind::TransientPassError { p: 1.0 },
            },
        ];
        let mut a = FaultPlan::new(7, eps.clone());
        let mut b = FaultPlan::new(7, eps);
        for pass in 0..8u64 {
            let mut ya = vec![1.0f32; 12];
            let mut yb = vec![1.0f32; 12];
            let ea = a.on_pass(&mut ya, 4, 0.0);
            let eb = b.on_pass(&mut yb, 4, 0.0);
            assert_eq!(ea, eb, "pass {pass}");
            assert_eq!(ya, yb, "pass {pass}");
            let in_episode = (2..5).contains(&pass);
            assert_eq!(ea.is_some(), in_episode, "pass {pass}");
            assert_eq!(ya != vec![1.0f32; 12], in_episode, "pass {pass}");
        }
        assert_eq!(a.injected(), 3);
        assert_eq!(a.passes(), 8);
    }

    #[test]
    fn dead_chip_zeros_and_stuck_tiles_clamp_rows() {
        let mut p = FaultPlan::new(
            1,
            vec![Episode {
                start_pass: 0,
                duration: 1,
                kind: FaultKind::DeadChip,
            }],
        );
        let mut y = vec![3.0f32; 6];
        assert_eq!(p.on_pass(&mut y, 3, 0.5), None, "dead chip is silent");
        assert!(y.iter().all(|&v| v == 0.0));

        let mut p = FaultPlan::new(
            1,
            vec![Episode {
                start_pass: 0,
                duration: 1,
                kind: FaultKind::StuckTiles { rows: 1 },
            }],
        );
        // 2 rows x 3 cols: only row 0 sticks at dark
        let mut y = vec![3.0f32; 6];
        assert_eq!(p.on_pass(&mut y, 3, 0.5), None);
        assert_eq!(&y[..3], &[0.5, 0.5, 0.5]);
        assert_eq!(&y[3..], &[3.0, 3.0, 3.0]);
    }

    #[test]
    fn nan_readout_is_detectable() {
        let mut p = FaultPlan::new(
            1,
            vec![Episode {
                start_pass: 1,
                duration: 2,
                kind: FaultKind::NaNReadout,
            }],
        );
        let mut y = vec![1.0f32; 4];
        assert_eq!(p.on_pass(&mut y, 2, 0.0), None);
        assert_eq!(p.on_pass(&mut y, 2, 0.0), Some("nan_readout"));
        assert!(y.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn plan_json_round_trips() {
        let plan = FaultPlan::generate(0xBEEF);
        let text = plan.dump();
        let back = FaultPlan::parse(&text).unwrap();
        assert_eq!(back.episodes(), plan.episodes());
        // and the reparsed plan replays identically
        let mut a = FaultPlan::parse(&text).unwrap();
        let mut b = FaultPlan::parse(&text).unwrap();
        for _ in 0..200 {
            let mut ya = vec![0.25f32; 8];
            let mut yb = vec![0.25f32; 8];
            assert_eq!(
                a.on_pass(&mut ya, 2, 0.01),
                b.on_pass(&mut yb, 2, 0.01)
            );
            assert_eq!(ya, yb);
        }
    }

    #[test]
    fn parse_rejects_unknown_kind_and_missing_fields() {
        assert!(FaultPlan::parse("{\"seed\":1}").is_err());
        assert!(FaultPlan::parse(
            "{\"seed\":1,\"episodes\":[{\"start_pass\":0,\"duration\":1,\
             \"kind\":\"meteor_strike\"}]}"
        )
        .is_err());
        assert!(FaultPlan::parse(
            "{\"seed\":1,\"episodes\":[{\"start_pass\":0,\"duration\":1,\
             \"kind\":\"stuck_tiles\"}]}"
        )
        .is_err(), "stuck_tiles requires rows");
    }
}
