//! Functional CirPTC chip simulator — the request-path twin of the python
//! chip model (`python/compile/chip.py`).
//!
//! The simulator is constructed from `artifacts/chip.json` (the chip
//! description exported at build time, holding the *as-fabricated* hidden
//! parameters: true crosstalk operator Γ, per-wavelength responsivity,
//! dark current, noise magnitudes and DAC resolutions) and executes BCM
//! tiles exactly as the chip would in lookup mode:
//!
//!   quantize(w, 6b) ∘ resp  →  Γ · quantize(x, 4b)  →  crossbar matmul
//!   → + dark  → + noise(σ_rel·|y| + σ_abs)
//!
//! The deterministic part is cross-validated against golden vectors from
//! the python side (`artifacts/goldens.cpt`) in `rust/tests/`.

use std::collections::HashMap;
use std::path::Path;
use crate::util::sync::Arc;

use crate::bail;
use crate::circulant::{Bcm, SignSplit};
use crate::drift::DriftModel;
use crate::fault::FaultPlan;
use crate::quant::Quantizer;
use crate::tensor::Tensor;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::scratch;

/// As-fabricated chip description (see `PhotonicChip.export_dict`).
#[derive(Clone, Debug)]
pub struct ChipDescription {
    pub l: usize,
    pub gamma: Vec<f32>, // (l, l) row-major true crosstalk operator
    pub resp: Vec<f32>,  // (l,) per-wavelength responsivity
    pub dark: f32,
    pub sigma_rel: f32,
    pub sigma_abs: f32,
    pub w_bits: u32,
    pub x_bits: u32,
    pub seed: u64,
    /// MRR-bank capacity: how many l×l tiles this chip can hold resident
    /// across all weight-stationary layers.  `0` means unlimited (the
    /// pre-farm single-chip assumption, and the default when absent from
    /// `chip.json`).  A model whose total circ tile count exceeds this is
    /// partitioned across chips by [`crate::farm::PartitionPlan`].
    pub mrr_capacity: usize,
}

impl ChipDescription {
    /// An ideal chip: identity Γ, flat response, no noise or quantization.
    pub fn ideal(l: usize) -> ChipDescription {
        let mut gamma = vec![0.0f32; l * l];
        for i in 0..l {
            gamma[i * l + i] = 1.0;
        }
        ChipDescription {
            l,
            gamma,
            resp: vec![1.0; l],
            dark: 0.0,
            sigma_rel: 0.0,
            sigma_abs: 0.0,
            w_bits: 0,
            x_bits: 0,
            seed: 0,
            mrr_capacity: 0,
        }
    }

    pub fn from_json(j: &Json) -> Result<ChipDescription> {
        let l = j.get("l").and_then(Json::as_usize).context("chip.l")?;
        let gamma = j.get("gamma_true").context("gamma_true")?.as_f32_flat();
        let resp = j.get("resp").context("resp")?.as_f32_flat();
        if gamma.len() != l * l || resp.len() != l {
            bail!("chip.json shape mismatch: l={l} gamma={} resp={}",
                  gamma.len(), resp.len());
        }
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        Ok(ChipDescription {
            l,
            gamma,
            resp,
            dark: f("dark") as f32,
            sigma_rel: f("sigma_rel") as f32,
            sigma_abs: f("sigma_abs") as f32,
            w_bits: f("w_bits") as u32,
            x_bits: f("x_bits") as u32,
            seed: f("seed") as u64,
            mrr_capacity: f("mrr_capacity") as usize,
        })
    }

    /// Load a chip description, attributing every failure (I/O, JSON,
    /// shape mismatch) to the file it came from — drift snapshots are
    /// loaded back through this path, so an unattributed "shape mismatch"
    /// would be undebuggable.
    pub fn load(path: &Path) -> Result<ChipDescription> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        ChipDescription::from_json(&j)
            .with_context(|| format!("loading chip description {}", path.display()))
    }

    /// Serialize to the `chip.json` layout [`ChipDescription::from_json`]
    /// parses (writer ↔ parser symmetry, like [`crate::onn::Manifest`]).
    /// Used to snapshot drifted operating points for attribution.
    pub fn to_json(&self) -> String {
        let rows: Vec<Json> = self
            .gamma
            .chunks(self.l)
            .map(|r| {
                Json::arr_f64(&r.iter().map(|&v| v as f64).collect::<Vec<_>>())
            })
            .collect();
        Json::obj(vec![
            ("l", Json::Num(self.l as f64)),
            ("gamma_true", Json::Arr(rows)),
            (
                "resp",
                Json::arr_f64(
                    &self.resp.iter().map(|&v| v as f64).collect::<Vec<_>>(),
                ),
            ),
            ("dark", Json::Num(self.dark as f64)),
            ("sigma_rel", Json::Num(self.sigma_rel as f64)),
            ("sigma_abs", Json::Num(self.sigma_abs as f64)),
            ("w_bits", Json::Num(self.w_bits as f64)),
            ("x_bits", Json::Num(self.x_bits as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("mrr_capacity", Json::Num(self.mrr_capacity as f64)),
        ])
        .dump()
    }

    /// Write the description to disk (creating parent directories).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

/// The executable simulator.
#[derive(Debug)]
pub struct ChipSim {
    pub desc: ChipDescription,
    wq: Quantizer,
    xq: Quantizer,
    rng: Rng,
    /// stochastic noise enabled (lookup-mode realism) or not (deterministic
    /// cross-validation)
    pub noisy: bool,
    /// block-tile × column MVM operations executed: each crossbar pass over
    /// a (P, Q, l) BCM with a B-column operand counts P·Q·B tiles, so the
    /// utilization accounting scales with the batch width streamed through
    /// one programming pass
    pub tiles_executed: u64,
    /// worker threads for the crossbar matmul (1 = serial; results are
    /// bit-identical for any value — see [`Bcm::mmm`])
    pub threads: usize,
    /// crossbar passes: one per [`ChipSim::forward`] call regardless of
    /// batch width (two per signed matmul, `fold` per folded execution)
    passes_done: u64,
    /// post-deployment drift process over `desc`, advanced one step per
    /// pass ([`DriftModel::on_pass`]).  `None` (the default) leaves every
    /// code path bit-identical to the drift-free simulator.
    drift: Option<DriftModel>,
    /// device-domain weight encodes performed (quantize ∘ responsivity):
    /// the planned path's cache-hit observable — flat per layer while the
    /// chip holds still, re-encoding only after a drift tick or hot swap
    pub encodes_done: u64,
    /// encode-cache generation: bumped whenever `desc` mutates under the
    /// planned path's feet (a drift tick, [`ChipSim::set_drift`], or an
    /// explicit [`ChipSim::invalidate_encodings`])
    enc_generation: u64,
    enc_cache: EncodeCache,
    /// pipelined-path observability: passes that accepted a pre-encoded
    /// operand ([`EncodedOperand`]) because its generation still matched
    pub pre_hits: u64,
    /// passes handed a pre-encoded operand that had gone stale (drift
    /// tick or invalidation since the snapshot) and re-encoded in line
    pub pre_stale: u64,
    /// seeded abrupt-fault schedule ([`FaultPlan`]), advanced on the same
    /// pass-count clock as drift.  `None` (the default) leaves every code
    /// path bit-identical to the fault-free simulator.
    fault: Option<FaultPlan>,
    /// latched detectable readout event from the most recent faulted
    /// pass; drained by [`ChipSim::take_fault_event`]
    pending_fault: Option<&'static str>,
    /// detectable fault events observed at the readout interface (CRC
    /// trips, non-finite readouts, external deadline verdicts)
    fault_events: u64,
}

/// Pre-encoded weight tiles keyed by `(owner, layer slot, sign half)`.
/// `owner` is a [`crate::onn::plan::next_tile_owner`] id — every engine
/// instance gets a fresh one, so an [`crate::drift::EngineSlot`] hot swap
/// makes every old key miss and the new weights re-encode.
#[derive(Debug, Default)]
struct EncodeCache {
    /// the [`ChipSim::enc_generation`] these tiles were encoded under
    generation: u64,
    tiles: HashMap<(u64, usize, bool), Arc<Bcm>>,
}

/// Hard cap on parked tiles: swaps retire owners faster than drift
/// retires generations, so bound the map instead of tracking liveness.
const ENC_CACHE_CAP: usize = 256;

/// Drift-generation-stamped snapshot of the operand-encode parameters
/// (input quantizer + crosstalk operator Γ).  The pipelined serving path
/// ([`crate::coordinator::pipeline`]) hands one to its *pre* stage so
/// batch *i+1*'s operand can be Γ-mixed on an electronic thread while
/// batch *i* streams through the crossbar.  The stamp is what keeps the
/// overlap bit-identical: a [`ChipSim`] only accepts a pre-encoded
/// operand whose generation still matches its own (checked per pass —
/// a drift tick between the two sign-split passes retires the snapshot
/// mid-pair), falling back to the exact in-line encode otherwise.
#[derive(Clone, Debug)]
pub struct EncodeSnapshot {
    xq: Quantizer,
    gamma: Vec<f32>,
    l: usize,
    generation: u64,
}

impl EncodeSnapshot {
    /// The [`ChipSim`] encode generation this snapshot was taken under.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Encode an operand off-thread: quantize + Γ-mix exactly as the
    /// chip's in-line path would (same kernel, same thread split rules,
    /// bit-identical for any `threads`).  Draws from the *calling*
    /// thread's scratch arena.
    pub fn encode_operand(&self, x: &Tensor, threads: usize) -> EncodedOperand {
        let xenc = encode_operand(&self.xq, &self.gamma, self.l, x, threads, true);
        EncodedOperand {
            xenc: Tensor::new(&[x.shape[0], x.shape[1]], xenc),
            generation: self.generation,
        }
    }
}

/// An operand already quantized + Γ-mixed against a specific encode
/// generation (see [`EncodeSnapshot`]).  Reused for *both* sign-split
/// passes of a layer — the in-line encode is deterministic, so encoding
/// once is bit-identical to the sequential path's encode-per-pass.
#[derive(Debug)]
pub struct EncodedOperand {
    xenc: Tensor,
    generation: u64,
}

impl EncodedOperand {
    /// The encode generation this operand was Γ-mixed under.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Return the encoded buffer to the calling thread's scratch arena.
    pub fn recycle(self) {
        scratch::put(self.xenc.data);
    }
}

/// Operand (input) encoding: quantize then Γ mixing within each l-block.
/// Depends only on (`x`, Γ, the input quantizer) — independent of the
/// weights, the RNG stream and every per-pass counter, which is what
/// lets a pipelined pre stage compute it off-thread ([`EncodeSnapshot`])
/// bit-identically to the in-line path.
///
/// Row-contiguous SAXPY form (EXPERIMENTS.md §Perf): quantize each
/// input row once, then accumulate Γ-weighted rows — batch-stride-1
/// throughout instead of the naive per-(col, channel) gather.
/// For very wide batches the destination rows are distributed
/// across scoped workers ([`crate::util::threadpool::scoped_chunks`],
/// like the crossbar matmul): each row (qb·l + i) is filled by
/// exactly one thread in the same j-order as the serial loop, so
/// any thread count is bit-identical; below the madd threshold the
/// single-thread fallback runs the identical serial path.
fn encode_operand(
    xq: &Quantizer,
    gamma: &[f32],
    l: usize,
    x: &Tensor,
    threads: usize,
    pooled: bool,
) -> Vec<f32> {
    let b = x.shape[1];
    let mut xqbuf = if pooled {
        let mut buf = scratch::take(x.data.len());
        buf.copy_from_slice(&x.data);
        buf
    } else {
        x.data.clone()
    };
    xq.q_slice(&mut xqbuf);
    let mut xenc = if pooled {
        scratch::take(x.data.len())
    } else {
        vec![0.0f32; x.data.len()]
    };
    let q_blocks = x.shape[0] / l;
    if b > 0 {
        let enc_madds = q_blocks * l * l * b;
        let enc_threads = if q_blocks >= 2 && enc_madds >= (1 << 19) {
            threads.min(q_blocks * l)
        } else {
            1
        };
        crate::util::threadpool::scoped_chunks(
            enc_threads,
            &mut xenc,
            b,
            |row, dst| {
                let i = row % l;
                let base = row - i;
                for j in 0..l {
                    let g = gamma[i * l + j];
                    if g == 0.0 {
                        continue;
                    }
                    let src = &xqbuf[(base + j) * b..(base + j + 1) * b];
                    for (d, &s) in dst.iter_mut().zip(src) {
                        *d += g * s;
                    }
                }
            },
        );
    }
    if pooled {
        scratch::put(xqbuf);
    }
    xenc
}

impl ChipSim {
    pub fn new(desc: ChipDescription) -> ChipSim {
        ChipSim {
            wq: Quantizer::new(desc.w_bits),
            xq: Quantizer::new(desc.x_bits),
            rng: Rng::new(desc.seed ^ 0xC19_97C),
            noisy: true,
            desc,
            tiles_executed: 0,
            threads: 1,
            passes_done: 0,
            drift: None,
            encodes_done: 0,
            enc_generation: 0,
            enc_cache: EncodeCache::default(),
            pre_hits: 0,
            pre_stale: 0,
            fault: None,
            pending_fault: None,
            fault_events: 0,
        }
    }

    pub fn deterministic(desc: ChipDescription) -> ChipSim {
        let mut s = ChipSim::new(desc);
        s.noisy = false;
        s
    }

    /// Program + run one BCM tile: w (P,Q,l) in [0,1], x (N,B) in [0,1].
    /// Returns the (M,B) photocurrent tensor.
    ///
    /// Reference path: the weight tile is re-encoded on every call.  The
    /// serving engine's planned path goes through
    /// [`ChipSim::forward_planned`], which caches the encoded tile — the
    /// two are bit-identical (`rust/tests/planned_path.rs`).
    pub fn forward(&mut self, w: &Bcm, x: &Tensor) -> Tensor {
        assert_eq!(w.l, self.desc.l, "block order mismatch with chip");
        let wenc = self.encode_weights(w);
        self.forward_encoded(&wenc, x, false)
    }

    /// Device-domain weight encoding: quantize then responsivity tilt.
    /// Depends only on (`w`, `desc.resp`, `w_bits`) — static between
    /// drift ticks, which is what makes the encoded tiles cacheable.
    fn encode_weights(&mut self, w: &Bcm) -> Bcm {
        self.encodes_done += 1;
        let l = self.desc.l;
        let mut wenc = w.clone();
        for (i, v) in wenc.w.iter_mut().enumerate() {
            *v = self.wq.q(*v) * self.desc.resp[i % l];
        }
        wenc
    }

    /// One crossbar pass over an already-encoded tile.  `pooled` draws
    /// the operand-encode and photocurrent buffers from the thread-local
    /// scratch arena (the planned path); `false` allocates fresh (the
    /// reference path).  Identical arithmetic either way.
    fn forward_encoded(&mut self, wenc: &Bcm, x: &Tensor, pooled: bool) -> Tensor {
        assert_eq!(wenc.l, self.desc.l, "block order mismatch with chip");
        assert_eq!(x.shape[0], wenc.n());
        let xenc = encode_operand(
            &self.xq,
            &self.desc.gamma,
            self.desc.l,
            x,
            self.threads,
            pooled,
        );
        let xenc = Tensor::new(&[wenc.n(), x.shape[1]], xenc);
        let y = self.crossbar_pass(wenc, &xenc, pooled);
        if pooled {
            scratch::put(xenc.data);
        }
        y
    }

    /// One detection event over an already-encoded weight tile and an
    /// already-encoded operand: crossbar matmul + dark + noise + the
    /// pass/tile/drift bookkeeping.  Everything that must serialize on
    /// the chip (RNG draws, the pass-count drift clock) lives here, so
    /// the pipelined path can move the operand encode off-thread while
    /// this stays the single ordered "chip time" step.
    fn crossbar_pass(&mut self, wenc: &Bcm, xenc: &Tensor, pooled: bool) -> Tensor {
        let b = xenc.shape[1];
        let mut ybuf = if pooled {
            scratch::take(wenc.m() * b)
        } else {
            vec![0.0f32; wenc.m() * b]
        };
        wenc.mmm_into(xenc, self.threads, &mut ybuf);
        let (dark, srel, sabs) =
            (self.desc.dark, self.desc.sigma_rel, self.desc.sigma_abs);
        for v in ybuf.iter_mut() {
            *v += dark;
        }
        if self.noisy && (srel > 0.0 || sabs > 0.0) {
            for v in ybuf.iter_mut() {
                let n = v.abs() * srel * self.rng.normal() as f32
                    + sabs * self.rng.normal() as f32;
                *v += n;
            }
        }
        self.passes_done += 1;
        self.tiles_executed += (wenc.p * wenc.q * b) as u64;
        // the pass that just ran saw the pre-tick parameters; an attached
        // drift model advances the pass-count clock afterwards, so drift
        // takes effect from the *next* pass on.  A tick mutates Γ /
        // responsivity / dark under the encode cache's feet, so it also
        // retires the current encode generation.
        if let Some(drift) = self.drift.as_mut() {
            let ticks_before = drift.ticks();
            drift.on_pass(&mut self.desc);
            if drift.ticks() != ticks_before {
                self.enc_generation = self.enc_generation.wrapping_add(1);
            }
        }
        // fault injection corrupts the detected photocurrents *after*
        // dark/noise (it models the readout interface, not the optics);
        // detectable events latch until the serving path drains them
        if let Some(fault) = self.fault.as_mut() {
            if let Some(event) = fault.on_pass(&mut ybuf, b, dark) {
                self.pending_fault = Some(event);
                self.fault_events += 1;
            }
        }
        Tensor::new(&[wenc.m(), b], ybuf)
    }

    /// Full-range matmul via the paper's sign-split time multiplexing:
    /// two positive-only passes, post-processing subtraction (cancels the
    /// dark offset exactly), rescale.
    pub fn forward_signed(&mut self, w: &Bcm, x: &Tensor) -> Tensor {
        let (wp, wn, scale) = w.split_signed();
        let yp = self.forward(&wp, x);
        let yn = self.forward(&wn, x);
        yp.sub(&yn).scale(scale)
    }

    /// Planned pass: like [`ChipSim::forward`], but the device-domain
    /// weight encode of `(owner, slot, negative)` is served from the
    /// pre-encoded tile cache while the chip's encode generation holds
    /// (i.e. until drift mutates `desc`, or a new owner — a hot-swapped
    /// engine — retires the old keys).  Bit-identical to `forward`:
    /// a cached tile holds exactly the values `encode_weights` would
    /// recompute, and the invalidation rules re-encode precisely when
    /// those values would change.
    pub fn forward_planned(
        &mut self,
        owner: u64,
        slot: usize,
        negative: bool,
        w: &Bcm,
        x: &Tensor,
    ) -> Tensor {
        self.forward_planned_enc(owner, slot, negative, w, x, None)
    }

    /// Planned pass that can additionally consume a pre-encoded operand
    /// from a pipelined pre stage.  The snapshot generation is checked
    /// *per pass*: a pre-encode is only trusted while the chip's Γ /
    /// quantizer state is exactly what [`ChipSim::encode_snapshot`]
    /// captured (a drift tick between the two sign-split passes retires
    /// it mid-pair); anything stale falls back to the in-line encode, so
    /// every path stays bit-identical to [`ChipSim::forward`].
    pub fn forward_planned_enc(
        &mut self,
        owner: u64,
        slot: usize,
        negative: bool,
        w: &Bcm,
        x: &Tensor,
        pre: Option<&EncodedOperand>,
    ) -> Tensor {
        assert_eq!(w.l, self.desc.l, "block order mismatch with chip");
        if self.enc_cache.generation != self.enc_generation {
            self.enc_cache.tiles.clear();
            self.enc_cache.generation = self.enc_generation;
        }
        if self.enc_cache.tiles.len() >= ENC_CACHE_CAP {
            self.enc_cache.tiles.clear();
        }
        let key = (owner, slot, negative);
        let cached = self.enc_cache.tiles.get(&key).cloned();
        let wenc = match cached {
            Some(tile) => tile,
            None => {
                let tile = Arc::new(self.encode_weights(w));
                self.enc_cache.tiles.insert(key, Arc::clone(&tile));
                tile
            }
        };
        match pre {
            Some(p)
                if p.generation == self.enc_generation
                    && p.xenc.shape[0] == wenc.n()
                    && p.xenc.shape[1] == x.shape[1] =>
            {
                self.pre_hits += 1;
                self.crossbar_pass(&wenc, &p.xenc, true)
            }
            Some(_) => {
                self.pre_stale += 1;
                self.forward_encoded(&wenc, x, true)
            }
            None => self.forward_encoded(&wenc, x, true),
        }
    }

    /// Planned sign-split matmul over a pre-split layer
    /// ([`SignSplit`], computed once per layer by `onn::plan`): two
    /// cached-tile passes, fused subtract + rescale.  Bit-identical to
    /// [`ChipSim::forward_signed`] on the same weights.
    pub fn forward_signed_planned(
        &mut self,
        owner: u64,
        slot: usize,
        sign: &SignSplit,
        x: &Tensor,
    ) -> Tensor {
        self.forward_signed_planned_enc(owner, slot, sign, x, None)
    }

    /// Sign-split planned matmul with an optional pre-encoded operand.
    /// The *same* pre-encode serves both halves — the in-line operand
    /// encode is deterministic, so encoding once off-thread is
    /// bit-identical to the sequential encode-per-pass (each pass still
    /// re-validates the generation; see [`ChipSim::forward_planned_enc`]).
    pub fn forward_signed_planned_enc(
        &mut self,
        owner: u64,
        slot: usize,
        sign: &SignSplit,
        x: &Tensor,
        pre: Option<&EncodedOperand>,
    ) -> Tensor {
        let mut y = self.forward_planned_enc(owner, slot, false, &sign.pos, x, pre);
        let yn = self.forward_planned_enc(owner, slot, true, &sign.neg, x, pre);
        for (a, b) in y.data.iter_mut().zip(&yn.data) {
            *a = (*a - *b) * sign.scale;
        }
        scratch::put(yn.data);
        y
    }

    /// Snapshot the operand-encode parameters at the current encode
    /// generation, for a pipelined pre stage ([`EncodeSnapshot`]).
    pub fn encode_snapshot(&self) -> EncodeSnapshot {
        EncodeSnapshot {
            xq: self.xq,
            gamma: self.desc.gamma.clone(),
            l: self.desc.l,
            generation: self.enc_generation,
        }
    }

    /// Retire every cached pre-encoded tile.  Call after mutating
    /// [`ChipSim::desc`] directly (the drift clock and hot swaps handle
    /// their own invalidation).
    pub fn invalidate_encodings(&mut self) {
        self.enc_generation = self.enc_generation.wrapping_add(1);
    }

    /// Pre-encoded tiles currently parked (test/observability hook).
    pub fn cached_tiles(&self) -> usize {
        self.enc_cache.tiles.len()
    }

    /// Spectral-folded execution (paper Fig. S18): an M×(r·N_phys) BCM run
    /// on an N_phys-row physical crossbar by launching `fold` input groups
    /// in adjacent FSRs.  All folds sum *simultaneously* at each column PD
    /// (one detection event: one dark offset, one noise draw), but each
    /// FSR replica sees a slightly different PD responsivity — the
    /// "wavelength-dependent response of PDs" the paper flags as folding's
    /// calibration cost, modelled as a per-fold gain slope of
    /// `fold_resp_slope` per FSR.
    pub fn forward_folded(&mut self, w: &Bcm, x: &Tensor, fold: usize,
                          fold_resp_slope: f32) -> Tensor {
        assert!(fold >= 1 && w.q % fold == 0,
                "logical width must split into {fold} folds");
        let q_phys = w.q / fold;
        let n_phys = q_phys * w.l;
        let b = x.shape[1];
        let mut acc = Tensor::zeros(&[w.m(), b]);
        // accumulate the folds optically (no per-fold dark/noise).  The
        // dark level is tracked explicitly so that drift creep applied by
        // an attached model *during* the fold group (it ticks on the
        // temporarily-zeroed field) is carried into the single detection
        // event instead of being lost by the snapshot restore.
        let (dark, srel, sabs) =
            (self.desc.dark, self.desc.sigma_rel, self.desc.sigma_abs);
        let mut dark_level = dark;
        for r in 0..fold {
            // sub-BCM of this fold: block-columns [r*q_phys, (r+1)*q_phys)
            let mut wsub = Bcm::zeros(w.p, q_phys, w.l);
            for bp in 0..w.p {
                for bq in 0..q_phys {
                    let src = (bp * w.q + r * q_phys + bq) * w.l;
                    let dst = (bp * q_phys + bq) * w.l;
                    wsub.w[dst..dst + w.l]
                        .copy_from_slice(&w.w[src..src + w.l]);
                }
            }
            let xsub = Tensor::new(&[n_phys, b],
                x.data[r * n_phys * b..(r + 1) * n_phys * b].to_vec());
            // suppress per-pass dark/noise: folds are one detection event
            self.desc.dark = 0.0;
            self.desc.sigma_rel = 0.0;
            self.desc.sigma_abs = 0.0;
            let y = self.forward(&wsub, &xsub);
            // whatever now sits in the zeroed field is drift creep from
            // this pass's tick — fold it into the running dark level
            dark_level += self.desc.dark;
            self.desc.dark = dark_level;
            self.desc.sigma_rel = srel;
            self.desc.sigma_abs = sabs;
            let gain = 1.0 + fold_resp_slope * r as f32;
            for (a, v) in acc.data.iter_mut().zip(&y.data) {
                *a += gain * v;
            }
        }
        // single PD detection: dark + one noise draw
        for v in acc.data.iter_mut() {
            *v += dark_level;
        }
        if self.noisy && (srel > 0.0 || sabs > 0.0) {
            for v in acc.data.iter_mut() {
                *v += v.abs() * srel * self.rng.normal() as f32
                    + sabs * self.rng.normal() as f32;
            }
        }
        acc
    }

    /// Chip passes consumed so far: one per `forward` call whatever the
    /// batch width (two per signed matmul) — batching a layer's whole
    /// operand block into one call is what keeps this flat per layer.
    pub fn passes(&self) -> u64 {
        self.passes_done
    }

    /// Attach a post-deployment drift process: from now on `desc` evolves
    /// on the pass-count clock (one [`DriftModel::on_pass`] per crossbar
    /// pass).  [`ChipSim::forward_folded`] counts one pass per fold; dark
    /// creep ticked inside a fold group is accumulated into that group's
    /// single detection event.
    pub fn set_drift(&mut self, model: DriftModel) {
        self.drift = Some(model);
        // the chip is about to walk: don't trust tiles encoded before
        self.invalidate_encodings();
    }

    /// The attached drift process, if any.
    pub fn drift(&self) -> Option<&DriftModel> {
        self.drift.as_ref()
    }

    /// Attach a seeded abrupt-fault schedule: from now on every crossbar
    /// pass advances the plan's clock and may corrupt the readout.  Like
    /// [`ChipSim::set_drift`], attaching retires pre-encoded tiles (a
    /// chaos run should not trust state staged before the faults began).
    pub fn set_fault(&mut self, plan: FaultPlan) {
        self.fault = Some(plan);
        self.invalidate_encodings();
    }

    /// The attached fault plan, if any.
    pub fn fault(&self) -> Option<&FaultPlan> {
        self.fault.as_ref()
    }

    /// Drain the latched detectable readout event from the most recent
    /// faulted pass, if any.  The pipelined chip lane checks this after
    /// every batch and converts it into a retry + supervisor verdict.
    pub fn take_fault_event(&mut self) -> Option<&'static str> {
        self.pending_fault.take()
    }

    /// Record an externally detected fault verdict (e.g. a pass-deadline
    /// overrun in the serving pipeline) against this chip's counters.
    pub fn note_fault(&mut self) {
        self.fault_events += 1;
    }

    /// Detectable fault events seen at the readout interface so far.
    pub fn fault_events(&self) -> u64 {
        self.fault_events
    }

    /// Total passes the attached plan corrupted (silent or detectable);
    /// 0 when no plan is attached.
    pub fn faults_injected(&self) -> u64 {
        self.fault.as_ref().map_or(0, |f| f.injected())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::assert_close;

    fn rand_bcm(p: usize, q: usize, l: usize, seed: u64) -> Bcm {
        let mut r = Rng::new(seed);
        let mut w = vec![0.0f32; p * q * l];
        r.fill_uniform(&mut w);
        Bcm::new(p, q, l, w)
    }

    fn rand_x(n: usize, b: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut x = vec![0.0f32; n * b];
        r.fill_uniform(&mut x);
        Tensor::new(&[n, b], x)
    }

    #[test]
    fn ideal_chip_is_exact_bcm() {
        let mut sim = ChipSim::deterministic(ChipDescription::ideal(4));
        let w = rand_bcm(2, 3, 4, 1);
        let x = rand_x(12, 5, 2);
        let got = sim.forward(&w, &x);
        let want = w.matmul(&x);
        assert_close(&got.data, &want.data, 1e-5).unwrap();
    }

    #[test]
    fn deterministic_repeatable() {
        let mut d = ChipDescription::ideal(4);
        d.w_bits = 6;
        d.x_bits = 4;
        d.dark = 0.015;
        let mut sim = ChipSim::deterministic(d);
        let w = rand_bcm(2, 2, 4, 3);
        let x = rand_x(8, 4, 4);
        let y1 = sim.forward(&w, &x);
        let y2 = sim.forward(&w, &x);
        assert_close(&y1.data, &y2.data, 0.0).unwrap();
    }

    #[test]
    fn noise_perturbs() {
        let mut d = ChipDescription::ideal(4);
        d.sigma_abs = 0.01;
        let mut sim = ChipSim::new(d);
        let w = rand_bcm(2, 2, 4, 5);
        let x = rand_x(8, 4, 6);
        let y1 = sim.forward(&w, &x);
        let y2 = sim.forward(&w, &x);
        assert!(y1.max_abs_diff(&y2) > 0.0);
    }

    #[test]
    fn signed_cancels_dark() {
        let mut d = ChipDescription::ideal(4);
        d.dark = 0.4;
        let mut sim = ChipSim::deterministic(d);
        // full-range weights
        let mut w = rand_bcm(2, 2, 4, 7);
        for (i, v) in w.w.iter_mut().enumerate() {
            if i % 2 == 0 {
                *v = -*v;
            }
        }
        let x = rand_x(8, 3, 8);
        let got = sim.forward_signed(&w, &x);
        let want = w.matmul(&x);
        assert_close(&got.data, &want.data, 1e-4).unwrap();
        assert_eq!(sim.passes(), 2);
    }

    #[test]
    fn pass_and_tile_accounting_scale_with_columns() {
        let mut sim = ChipSim::deterministic(ChipDescription::ideal(4));
        let w = rand_bcm(2, 3, 4, 31);
        let x = rand_x(12, 5, 32);
        sim.forward(&w, &x);
        // one programming pass streams all 5 columns; tiles = P·Q·B
        assert_eq!(sim.passes(), 1);
        assert_eq!(sim.tiles_executed, 2 * 3 * 5);
        sim.forward_signed(&w, &x);
        assert_eq!(sim.passes(), 3);
        assert_eq!(sim.tiles_executed, 3 * (2 * 3 * 5));
        // a wider batch costs more tiles but no extra passes per call
        let x16 = rand_x(12, 16, 33);
        sim.forward(&w, &x16);
        assert_eq!(sim.passes(), 4);
        assert_eq!(sim.tiles_executed, 3 * (2 * 3 * 5) + 2 * 3 * 16);
    }

    #[test]
    fn threaded_sim_matches_serial() {
        let mut d = ChipDescription::ideal(4);
        d.w_bits = 6;
        d.x_bits = 4;
        d.dark = 0.01;
        let w = rand_bcm(4, 4, 4, 34);
        let x = rand_x(16, 8, 35);
        let mut s1 = ChipSim::deterministic(d.clone());
        let mut s8 = ChipSim::deterministic(d);
        s8.threads = 8;
        let y1 = s1.forward_signed(&w, &x);
        let y8 = s8.forward_signed(&w, &x);
        assert_eq!(y1.data, y8.data, "threaded crossbar must be bit-identical");
    }

    #[test]
    fn threaded_gamma_encode_matches_serial_above_threshold() {
        // q_blocks·l·l·b = 16·16·2048 = 512k madds clears the 1<<19
        // threading threshold of the Γ-mixing encode loop; a non-trivial
        // Γ exercises the accumulation order
        let mut d = ChipDescription::ideal(4);
        d.gamma = vec![
            0.90, 0.05, 0.03, 0.02, //
            0.04, 0.91, 0.03, 0.02, //
            0.02, 0.04, 0.92, 0.02, //
            0.01, 0.03, 0.04, 0.92,
        ];
        d.x_bits = 4;
        let w = rand_bcm(2, 16, 4, 41);
        let x = rand_x(64, 2048, 42);
        let mut s1 = ChipSim::deterministic(d.clone());
        let mut s8 = ChipSim::deterministic(d);
        s8.threads = 8;
        let y1 = s1.forward(&w, &x);
        let y8 = s8.forward(&w, &x);
        assert_eq!(y1.data, y8.data, "threaded Γ encode must be bit-identical");
    }

    #[test]
    fn quantization_bounds_error() {
        let mut d = ChipDescription::ideal(4);
        d.w_bits = 6;
        d.x_bits = 4;
        let mut sim = ChipSim::deterministic(d);
        let w = rand_bcm(2, 3, 4, 9);
        let x = rand_x(12, 4, 10);
        let got = sim.forward(&w, &x);
        let want = w.matmul(&x);
        // error bounded by N * (w_lsb + x_lsb) roughly
        let bound = 12.0 * (0.5 / 63.0 + 0.5 / 15.0) * 1.5;
        assert!(got.max_abs_diff(&want) < bound);
    }

    #[test]
    fn gamma_mixing_applied() {
        let mut d = ChipDescription::ideal(2);
        // swap-ish mixing
        d.gamma = vec![0.8, 0.2, 0.2, 0.8];
        let mut sim = ChipSim::deterministic(d);
        let w = Bcm::new(1, 1, 2, vec![1.0, 0.0]); // identity block
        let x = Tensor::new(&[2, 1], vec![1.0, 0.0]);
        let y = sim.forward(&w, &x);
        assert!((y.data[0] - 0.8).abs() < 1e-6);
        assert!((y.data[1] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn folded_equals_unfolded_on_ideal_chip() {
        // with flat PD response across FSRs, folding is numerically
        // identical to the unfolded wide BCM (paper Fig. S18 identity)
        let mut d = ChipDescription::ideal(4);
        d.dark = 0.02;
        let w = rand_bcm(2, 8, 4, 21);     // logical 8x32
        let x = rand_x(32, 3, 22);
        let mut sim = ChipSim::deterministic(d.clone());
        let y_wide = sim.forward(&w, &x);
        let mut sim2 = ChipSim::deterministic(d);
        let y_fold = sim2.forward_folded(&w, &x, 4, 0.0);
        assert_close(&y_wide.data, &y_fold.data, 1e-4).unwrap();
    }

    #[test]
    fn fold_response_slope_biases_later_folds() {
        let d = ChipDescription::ideal(4);
        let w = rand_bcm(1, 4, 4, 23);
        let x = rand_x(16, 1, 24);
        let mut sim = ChipSim::deterministic(d.clone());
        let y0 = sim.forward_folded(&w, &x, 4, 0.0);
        let mut sim2 = ChipSim::deterministic(d);
        let y1 = sim2.forward_folded(&w, &x, 4, 0.05);
        // positive slope adds energy from folds 1..3
        assert!(y1.data[0] > y0.data[0]);
    }

    #[test]
    fn folded_single_dark_offset() {
        let mut d = ChipDescription::ideal(4);
        d.dark = 0.5;
        let w = Bcm::zeros(1, 4, 4);           // zero weights: output = dark
        let x = rand_x(16, 1, 25);
        let mut sim = ChipSim::deterministic(d);
        let y = sim.forward_folded(&w, &x, 4, 0.0);
        // one detection event => exactly one dark, not r darks
        assert!((y.data[0] - 0.5).abs() < 1e-6, "got {}", y.data[0]);
    }

    fn accel_drift(seed: u64) -> crate::drift::DriftConfig {
        crate::drift::DriftConfig {
            seed,
            passes_per_tick: 1,
            gamma_walk: 2e-3,
            resp_tilt: 4e-3,
            dark_creep: 1e-4,
            max_ticks: 0,
        }
    }

    #[test]
    fn drift_disabled_is_the_default_and_desc_is_static() {
        let mut sim = ChipSim::deterministic(ChipDescription::ideal(4));
        assert!(sim.drift().is_none());
        let w = rand_bcm(2, 2, 4, 51);
        let x = rand_x(8, 4, 52);
        for _ in 0..10 {
            sim.forward(&w, &x);
        }
        assert_eq!(sim.desc.resp, vec![1.0; 4]);
        assert_eq!(sim.desc.dark, 0.0);
    }

    #[test]
    fn drift_enabled_is_deterministic_and_diverges_from_static_chip() {
        let d = ChipDescription::ideal(4);
        let w = rand_bcm(2, 2, 4, 53);
        let x = rand_x(8, 4, 54);
        let run = || {
            let mut sim = ChipSim::deterministic(d.clone());
            sim.set_drift(crate::drift::DriftModel::new(accel_drift(9)));
            (0..20).map(|_| sim.forward(&w, &x).data).collect::<Vec<_>>()
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "drifting sim must be seed-deterministic");
        // a static sim agrees on the first pass (drift applies after it)
        // and disagrees once the walk has accumulated
        let mut sim = ChipSim::deterministic(d);
        let y0 = sim.forward(&w, &x);
        assert_eq!(a[0], y0.data, "first pass sees the calibration point");
        let y19 = {
            let mut s = ChipSim::deterministic(ChipDescription::ideal(4));
            for _ in 0..19 {
                s.forward(&w, &x);
            }
            s.forward(&w, &x)
        };
        assert_ne!(a[19], y19.data, "drift must perturb later passes");
    }

    #[test]
    fn folded_carries_drift_dark_creep_into_detection_event() {
        let mut d = ChipDescription::ideal(4);
        d.dark = 0.1;
        let w = Bcm::zeros(1, 4, 4); // zero weights: output = dark level
        let x = rand_x(16, 1, 26);
        let mut sim = ChipSim::deterministic(d);
        sim.set_drift(crate::drift::DriftModel::new(
            crate::drift::DriftConfig {
                seed: 13,
                passes_per_tick: 1,
                gamma_walk: 0.0,
                resp_tilt: 0.0,
                dark_creep: 0.01,
                max_ticks: 0,
            },
        ));
        let y = sim.forward_folded(&w, &x, 4, 0.0);
        // 4 fold passes tick 0.01 creep each; the snapshot restore must
        // carry the creep into the single detection event, not erase it
        assert!(
            (y.data[0] - 0.14).abs() < 1e-6,
            "dark level must accumulate fold-group creep: {}",
            y.data[0]
        );
        assert!((sim.desc.dark - 0.14).abs() < 1e-6);
    }

    #[test]
    fn chip_description_json_roundtrip_and_load_attribution() {
        let mut d = ChipDescription::ideal(4);
        d.gamma[1] = 0.031_25; // exactly representable: survives f32↔f64
        d.resp = vec![1.0, 0.5, 1.25, 0.75];
        d.dark = 0.25;
        d.w_bits = 6;
        d.x_bits = 4;
        d.seed = 7;
        d.mrr_capacity = 48;
        let dir = std::env::temp_dir().join("cirptc_chipdesc_rt");
        let path = dir.join("drift_snapshot.json");
        d.save(&path).unwrap();
        let back = ChipDescription::load(&path).unwrap();
        assert_eq!(back.l, 4);
        assert_eq!(back.gamma, d.gamma);
        assert_eq!(back.resp, d.resp);
        assert_eq!(back.dark, d.dark);
        assert_eq!((back.w_bits, back.x_bits, back.seed), (6, 4, 7));
        assert_eq!(back.mrr_capacity, 48);
        // pre-farm chip.json files omit mrr_capacity → unlimited
        let legacy = r#"{"l": 2,
            "gamma_true": [[1.0, 0.0], [0.0, 1.0]], "resp": [1.0, 1.0]}"#;
        std::fs::write(&path, legacy).unwrap();
        assert_eq!(ChipDescription::load(&path).unwrap().mrr_capacity, 0);
        // a corrupt snapshot names the file in the error chain
        std::fs::write(&path, "{\"l\": 4}").unwrap();
        let err = ChipDescription::load(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("drift_snapshot.json"),
            "error must carry the path: {err:#}"
        );
    }

    #[test]
    #[should_panic(expected = "block order mismatch")]
    fn rejects_wrong_order() {
        let mut sim = ChipSim::new(ChipDescription::ideal(4));
        let w = rand_bcm(1, 1, 8, 11);
        let x = rand_x(8, 1, 12);
        sim.forward(&w, &x);
    }

    fn nonideal_chip() -> ChipDescription {
        let mut d = ChipDescription::ideal(4);
        d.gamma = vec![
            0.90, 0.05, 0.03, 0.02, //
            0.04, 0.91, 0.03, 0.02, //
            0.02, 0.04, 0.92, 0.02, //
            0.01, 0.03, 0.04, 0.92,
        ];
        d.resp = vec![1.0, 0.9, 1.1, 0.95];
        d.w_bits = 6;
        d.x_bits = 4;
        d.dark = 0.02;
        d
    }

    #[test]
    fn planned_signed_is_bit_identical_and_caches_encodes() {
        let d = nonideal_chip();
        let w = rand_bcm(2, 3, 4, 61);
        let sign = SignSplit::of(&w);
        let mut plain = ChipSim::deterministic(d.clone());
        let mut planned = ChipSim::deterministic(d);
        for seed in 0..6u64 {
            let x = rand_x(12, 5, 100 + seed);
            let y0 = plain.forward_signed(&w, &x);
            let y1 = planned.forward_signed_planned(7, 0, &sign, &x);
            assert_eq!(y0.data, y1.data, "planned pass must be bit-identical");
        }
        // reference re-encodes both halves every call, planned only once
        assert_eq!(plain.encodes_done, 12);
        assert_eq!(planned.encodes_done, 2, "static chip: encode once per half");
        assert_eq!(planned.cached_tiles(), 2);
        assert_eq!(plain.passes(), planned.passes());
        assert_eq!(plain.tiles_executed, planned.tiles_executed);
    }

    #[test]
    fn planned_noisy_consumes_the_same_rng_stream() {
        let mut d = nonideal_chip();
        d.sigma_rel = 0.01;
        d.sigma_abs = 0.005;
        d.seed = 99;
        let w = rand_bcm(2, 2, 4, 62);
        let sign = SignSplit::of(&w);
        let x = rand_x(8, 3, 63);
        let mut plain = ChipSim::new(d.clone());
        let mut planned = ChipSim::new(d);
        for _ in 0..4 {
            let y0 = plain.forward_signed(&w, &x);
            let y1 = planned.forward_signed_planned(8, 0, &sign, &x);
            assert_eq!(y0.data, y1.data, "same seed, same noise draws");
        }
    }

    #[test]
    fn planned_stays_bit_identical_across_drift_ticks() {
        // the stale-cache accuracy bug would be silent: a cached tile
        // encoded under the old responsivity keeps "working", just wrong.
        // Drive identical drift episodes through the planned and
        // reference sims — any missed invalidation diverges the outputs.
        let d = nonideal_chip();
        let w = rand_bcm(2, 3, 4, 64);
        let sign = SignSplit::of(&w);
        let x = rand_x(12, 4, 65);
        let run_drift = |planned: bool| -> Vec<Vec<f32>> {
            let mut sim = ChipSim::deterministic(d.clone());
            sim.set_drift(DriftModel::new(accel_drift(17)));
            (0..10)
                .map(|_| {
                    if planned {
                        sim.forward_signed_planned(9, 0, &sign, &x).data
                    } else {
                        sim.forward_signed(&w, &x).data
                    }
                })
                .collect()
        };
        assert_eq!(run_drift(false), run_drift(true));
    }

    #[test]
    fn first_drift_tick_invalidates_the_encoded_tiles() {
        let d = nonideal_chip();
        let w = rand_bcm(1, 2, 4, 66);
        let sign = SignSplit::of(&w);
        let x = rand_x(8, 2, 67);
        let mut sim = ChipSim::deterministic(d);
        sim.set_drift(DriftModel::new(accel_drift(18)));
        sim.forward_signed_planned(10, 0, &sign, &x);
        assert_eq!(sim.encodes_done, 2);
        // the two passes above ticked drift twice (resp walked) — the
        // next pass pair must re-encode, not serve the stale tiles
        sim.forward_signed_planned(10, 0, &sign, &x);
        assert_eq!(
            sim.encodes_done, 4,
            "drift tick must retire the encode generation"
        );
    }

    #[test]
    fn new_owner_retires_old_tiles_without_desc_change() {
        // hot swap: a fresh engine gets a fresh owner id; the cache must
        // miss for its keys even though the chip never moved
        let d = nonideal_chip();
        let w = rand_bcm(1, 2, 4, 68);
        let sign = SignSplit::of(&w);
        let x = rand_x(8, 2, 69);
        let mut sim = ChipSim::deterministic(d);
        sim.forward_signed_planned(11, 0, &sign, &x);
        sim.forward_signed_planned(11, 0, &sign, &x);
        assert_eq!(sim.encodes_done, 2);
        sim.forward_signed_planned(12, 0, &sign, &x);
        assert_eq!(sim.encodes_done, 4, "new owner must re-encode");
        assert_eq!(sim.cached_tiles(), 4, "old + new owner tiles parked");
    }

    #[test]
    fn pre_encoded_operand_is_bit_identical_and_counted() {
        let d = nonideal_chip();
        let w = rand_bcm(2, 3, 4, 81);
        let sign = SignSplit::of(&w);
        let x = rand_x(12, 5, 82);
        let mut seq = ChipSim::deterministic(d.clone());
        let mut pip = ChipSim::deterministic(d);
        let y0 = seq.forward_signed_planned(21, 0, &sign, &x);
        let snap = pip.encode_snapshot();
        let pre = snap.encode_operand(&x, 1);
        let y1 = pip.forward_signed_planned_enc(21, 0, &sign, &x, Some(&pre));
        pre.recycle();
        assert_eq!(y0.data, y1.data, "pre-encoded pass must be bit-identical");
        assert_eq!(pip.pre_hits, 2, "both sign passes reuse the pre-encode");
        assert_eq!(pip.pre_stale, 0);
        assert_eq!(seq.passes(), pip.passes());
        assert_eq!(seq.tiles_executed, pip.tiles_executed);
    }

    #[test]
    fn stale_pre_encode_falls_back_to_inline_reencode() {
        let d = nonideal_chip();
        let w = rand_bcm(2, 2, 4, 83);
        let sign = SignSplit::of(&w);
        let x = rand_x(8, 3, 84);
        let mut sim = ChipSim::deterministic(d.clone());
        let snap = sim.encode_snapshot();
        let pre = snap.encode_operand(&x, 1);
        sim.desc.resp[2] = 0.7; // chip moved between snapshot and use
        sim.invalidate_encodings();
        let y = sim.forward_signed_planned_enc(25, 0, &sign, &x, Some(&pre));
        pre.recycle();
        assert_eq!(sim.pre_hits, 0);
        assert_eq!(sim.pre_stale, 2, "both passes must reject the stale operand");
        let mut twin = ChipSim::deterministic({
            let mut d2 = d;
            d2.resp[2] = 0.7;
            d2
        });
        let want = twin.forward_signed(&w, &x);
        assert_eq!(y.data, want.data, "fallback must see the post-move chip");
    }

    #[test]
    fn drift_tick_between_sign_passes_retires_pre_encode_mid_pair() {
        // passes_per_tick = 1: the positive pass ticks drift, so the
        // negative pass must re-encode against the walked Γ instead of
        // trusting the snapshot — exactly what the sequential path does.
        let d = nonideal_chip();
        let w = rand_bcm(2, 2, 4, 85);
        let sign = SignSplit::of(&w);
        let x = rand_x(8, 3, 86);
        let want = {
            let mut s = ChipSim::deterministic(d.clone());
            s.set_drift(DriftModel::new(accel_drift(29)));
            s.forward_signed_planned(26, 0, &sign, &x).data
        };
        let mut sim = ChipSim::deterministic(d);
        sim.set_drift(DriftModel::new(accel_drift(29)));
        let snap = sim.encode_snapshot();
        let pre = snap.encode_operand(&x, 1);
        let y = sim.forward_signed_planned_enc(26, 0, &sign, &x, Some(&pre));
        pre.recycle();
        assert_eq!(y.data, want, "mid-pair drift tick must force a re-encode");
        assert_eq!(sim.pre_hits, 1, "positive pass ran at the snapshot generation");
        assert_eq!(sim.pre_stale, 1, "negative pass saw the post-tick Γ");
    }

    #[test]
    fn noisy_pre_encode_consumes_the_same_rng_stream() {
        let mut d = nonideal_chip();
        d.sigma_rel = 0.01;
        d.sigma_abs = 0.005;
        d.seed = 123;
        let w = rand_bcm(2, 2, 4, 87);
        let sign = SignSplit::of(&w);
        let x = rand_x(8, 3, 88);
        let mut seq = ChipSim::new(d.clone());
        let mut pip = ChipSim::new(d);
        for _ in 0..3 {
            let y0 = seq.forward_signed_planned(27, 0, &sign, &x);
            let snap = pip.encode_snapshot();
            let pre = snap.encode_operand(&x, 4);
            let y1 = pip.forward_signed_planned_enc(27, 0, &sign, &x, Some(&pre));
            pre.recycle();
            assert_eq!(y0.data, y1.data, "operand encode must not draw RNG");
        }
    }

    #[test]
    fn invalidate_encodings_forces_reencode() {
        let d = nonideal_chip();
        let w = rand_bcm(1, 2, 4, 70);
        let sign = SignSplit::of(&w);
        let x = rand_x(8, 2, 71);
        let mut sim = ChipSim::deterministic(d);
        sim.forward_signed_planned(13, 0, &sign, &x);
        assert_eq!(sim.encodes_done, 2);
        sim.desc.resp[1] = 0.5; // external mutation: caller's contract
        sim.invalidate_encodings();
        let y = sim.forward_signed_planned(13, 0, &sign, &x);
        assert_eq!(sim.encodes_done, 4);
        // and the re-encoded tiles actually see the new responsivity
        let mut twin = ChipSim::deterministic({
            let mut d2 = nonideal_chip();
            d2.resp[1] = 0.5;
            d2
        });
        let want = twin.forward_signed(&w, &x);
        assert_eq!(y.data, want.data);
    }

    #[test]
    fn fault_detached_is_bit_identical_and_plan_rides_the_pass_clock() {
        use crate::fault::{Episode, FaultKind};
        let w = rand_bcm(2, 2, 4, 90);
        let x = rand_x(8, 3, 91);
        let mut clean = ChipSim::deterministic(nonideal_chip());
        let mut faulted = ChipSim::deterministic(nonideal_chip());
        // episode covers passes [1, 3): pass 0 is untouched
        faulted.set_fault(FaultPlan::new(
            5,
            vec![Episode {
                start_pass: 1,
                duration: 2,
                kind: FaultKind::DeadChip,
            }],
        ));
        let y0c = clean.forward(&w, &x);
        let y0f = faulted.forward(&w, &x);
        assert_eq!(y0c.data, y0f.data, "pre-episode pass is bit-identical");
        assert_eq!(faulted.take_fault_event(), None);
        let y1 = faulted.forward(&w, &x);
        assert!(y1.data.iter().all(|&v| v == 0.0), "dead chip reads zero");
        // silent fault: counted as injected, not as a detectable event
        assert_eq!(faulted.faults_injected(), 1);
        assert_eq!(faulted.fault_events(), 0);
        assert_eq!(faulted.fault().map(|f| f.passes()), Some(2));
    }

    #[test]
    fn detectable_fault_latches_until_drained() {
        use crate::fault::{Episode, FaultKind};
        let w = rand_bcm(1, 2, 4, 92);
        let x = rand_x(8, 2, 93);
        let mut sim = ChipSim::deterministic(nonideal_chip());
        sim.set_fault(FaultPlan::new(
            6,
            vec![Episode {
                start_pass: 0,
                duration: 1,
                kind: FaultKind::NaNReadout,
            }],
        ));
        let y = sim.forward(&w, &x);
        assert!(y.data.iter().all(|v| v.is_nan()));
        assert_eq!(sim.fault_events(), 1);
        assert_eq!(sim.take_fault_event(), Some("nan_readout"));
        assert_eq!(sim.take_fault_event(), None, "drained");
        sim.note_fault(); // external deadline verdict
        assert_eq!(sim.fault_events(), 2);
    }
}
