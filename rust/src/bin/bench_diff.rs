//! Compare `BENCH_*.json` files against committed baselines with a
//! generous regression tolerance (DESIGN.md §perf).
//!
//! ```text
//! bench_diff [--baseline <dir>] [--tolerance <x>] <current.json> ...
//! ```
//!
//! For every current file, the baseline of the same basename is read from
//! `--baseline` (default `benches/baselines`).  Two checks run:
//!
//! * **timing regressions** — every `results.<name>.mean_ns` present in
//!   both files with a positive baseline must not exceed
//!   `tolerance × baseline` (default 2.0: only gross slowdowns fail —
//!   shared CI runners are noisy, and the point is catching a planned
//!   path that quietly fell back to per-call rebuilds, not a 10% wobble);
//! * **metric floors** — a baseline may declare `"floors": {"metric":
//!   min}`; the current file's `metrics.<metric>` must reach the floor
//!   (this is how the planned-vs-unplanned speedup acceptance is pinned
//!   without pinning machine-dependent absolute timings).
//!
//! Baselines with empty `results` skip the timing check (the committed
//! seeds carry only floors until a CI artifact refreshes them).  Exit
//! code 1 on any violation.

use std::path::Path;
use std::process::ExitCode;

use cirptc::util::cli::Args;
use cirptc::util::json::Json;

/// Violations found comparing one current report against its baseline.
fn compare(base: &Json, cur: &Json, tolerance: f64) -> Vec<String> {
    let mut bad = Vec::new();
    if let (Some(Json::Obj(b)), Some(Json::Obj(c))) =
        (base.get("results"), cur.get("results"))
    {
        for (name, bentry) in b {
            let (Some(bm), Some(cm)) = (
                bentry.get("mean_ns").and_then(Json::as_f64),
                c.get(name)
                    .and_then(|e| e.get("mean_ns"))
                    .and_then(Json::as_f64),
            ) else {
                continue;
            };
            if bm > 0.0 && cm > tolerance * bm {
                bad.push(format!(
                    "{name}: mean {cm:.0} ns vs baseline {bm:.0} ns \
                     (> {tolerance:.1}x slowdown)"
                ));
            }
        }
    }
    if let Some(Json::Obj(floors)) = base.get("floors") {
        for (name, floor) in floors {
            let Some(floor) = floor.as_f64() else { continue };
            match cur
                .get("metrics")
                .and_then(|m| m.get(name))
                .and_then(Json::as_f64)
            {
                Some(v) if v >= floor => {}
                Some(v) => bad.push(format!(
                    "{name}: {v:.3} below the baseline floor {floor:.3}"
                )),
                None => bad.push(format!(
                    "{name}: floor {floor:.3} declared but metric missing"
                )),
            }
        }
    }
    bad
}

fn main() -> ExitCode {
    let args = Args::parse();
    let dir = args.str_or("baseline", "benches/baselines");
    let tolerance = args.f64_or("tolerance", 2.0);
    let mut failed = false;
    if args.positional().is_empty() {
        eprintln!("bench_diff: no bench files given");
        return ExitCode::FAILURE;
    }
    for file in args.positional() {
        let cur_path = Path::new(file);
        let name = match cur_path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => {
                eprintln!("bench_diff: bad path {file}");
                failed = true;
                continue;
            }
        };
        let base_path = Path::new(&dir).join(name);
        let cur = match std::fs::read_to_string(cur_path) {
            Ok(text) => text,
            Err(e) => {
                eprintln!("bench_diff: {file}: {e} (did the bench run?)");
                failed = true;
                continue;
            }
        };
        let base = match std::fs::read_to_string(&base_path) {
            Ok(text) => text,
            Err(_) => {
                println!("bench_diff: {name}: no committed baseline, skipping");
                continue;
            }
        };
        let (cur, base) = match (Json::parse(&cur), Json::parse(&base)) {
            (Ok(c), Ok(b)) => (c, b),
            (c, b) => {
                eprintln!(
                    "bench_diff: {name}: parse failure (current ok: {}, \
                     baseline ok: {})",
                    c.is_ok(),
                    b.is_ok()
                );
                failed = true;
                continue;
            }
        };
        let bad = compare(&base, &cur, tolerance);
        if bad.is_empty() {
            println!("bench_diff: {name}: OK (tolerance {tolerance:.1}x)");
        } else {
            failed = true;
            eprintln!("bench_diff: {name}: REGRESSION");
            for line in bad {
                eprintln!("  {line}");
            }
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(entries: &[(&str, f64)], metrics: &[(&str, f64)]) -> Json {
        let results = entries
            .iter()
            .map(|(k, v)| (*k, Json::obj(vec![("mean_ns", Json::Num(*v))])))
            .collect::<Vec<_>>();
        let metrics = metrics
            .iter()
            .map(|(k, v)| (*k, Json::Num(*v)))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("results", Json::obj(results)),
            ("metrics", Json::obj(metrics)),
        ])
    }

    #[test]
    fn within_tolerance_passes() {
        let base = report(&[("k", 100.0)], &[]);
        let cur = report(&[("k", 180.0)], &[]);
        assert!(compare(&base, &cur, 2.0).is_empty());
    }

    #[test]
    fn gross_slowdown_fails() {
        let base = report(&[("k", 100.0)], &[]);
        let cur = report(&[("k", 250.0)], &[]);
        assert_eq!(compare(&base, &cur, 2.0).len(), 1);
    }

    #[test]
    fn missing_and_zero_baseline_entries_are_skipped() {
        let base = report(&[("gone", 100.0), ("unseeded", 0.0)], &[]);
        let cur = report(&[("new", 1e9), ("unseeded", 5e9)], &[]);
        assert!(compare(&base, &cur, 2.0).is_empty());
    }

    #[test]
    fn floors_enforced() {
        let mut base = report(&[], &[]);
        if let Json::Obj(m) = &mut base {
            m.insert(
                "floors".into(),
                Json::obj(vec![("speedup", Json::Num(1.5))]),
            );
        }
        let ok = report(&[], &[("speedup", 1.7)]);
        assert!(compare(&base, &ok, 2.0).is_empty());
        let low = report(&[], &[("speedup", 1.2)]);
        assert_eq!(compare(&base, &low, 2.0).len(), 1);
        let missing = report(&[], &[]);
        assert_eq!(compare(&base, &missing, 2.0).len(), 1);
    }
}
