//! `trace_check` — CI validator for the Chrome trace-event files
//! `cirptc serve --trace` writes (the second half of `make trace-smoke`).
//!
//! Checks, failing loudly on any miss:
//!   * the file parses as a top-level JSON array of event objects;
//!   * every event carries `name` / `cat` / `ph` / `ts` / `pid` / `tid`,
//!     with `ph == "X"` (complete) events also carrying `dur`;
//!   * all four span families the serving stack records are present
//!     (`request`, `stage`, `farm`, `drift` categories — DESIGN.md §obs),
//!     including a farm `shard_pass` span and a drift `recalibrate` span.

use std::collections::BTreeMap;
use std::process::ExitCode;

use cirptc::util::json::Json;

fn run(path: &str) -> Result<(), String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
    let events = j.as_arr().ok_or("top level must be a JSON array")?;
    if events.is_empty() {
        return Err("trace holds no events".into());
    }
    let mut by_cat: BTreeMap<String, usize> = BTreeMap::new();
    let mut names: BTreeMap<String, usize> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing name"))?;
        let cat = e
            .get("cat")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing cat"))?;
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        for key in ["ts", "pid", "tid"] {
            if e.get(key).and_then(Json::as_f64).is_none() {
                return Err(format!("event {i} ({name}): missing {key}"));
            }
        }
        match ph {
            "X" => {
                if e.get("dur").and_then(Json::as_f64).is_none() {
                    return Err(format!(
                        "event {i} ({name}): complete span without dur"
                    ));
                }
            }
            "i" => {}
            other => {
                return Err(format!("event {i} ({name}): unknown ph {other:?}"))
            }
        }
        *by_cat.entry(cat.to_string()).or_insert(0) += 1;
        *names.entry(name.to_string()).or_insert(0) += 1;
    }
    for cat in ["request", "stage", "farm", "drift"] {
        if !by_cat.contains_key(cat) {
            return Err(format!(
                "no {cat:?} spans (categories present: {:?})",
                by_cat.keys().collect::<Vec<_>>()
            ));
        }
    }
    for name in ["shard_pass", "recalibrate"] {
        if !names.contains_key(name) {
            return Err(format!(
                "no {name:?} span (names present: {:?})",
                names.keys().collect::<Vec<_>>()
            ));
        }
    }
    println!("trace OK: {} events", events.len());
    for (cat, n) in &by_cat {
        println!("  cat {cat:<8} {n}");
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(path) = args.first() else {
        eprintln!("usage: trace_check TRACE.json");
        return ExitCode::FAILURE;
    };
    match run(path) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("trace_check: {path}: {e}");
            ExitCode::FAILURE
        }
    }
}
