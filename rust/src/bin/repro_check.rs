//! Minimal environment check: PJRT client comes up, artifacts dir visible.
fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    let rt = cirptc::runtime::Runtime::new(&dir)?;
    println!("platform={} artifacts={}", rt.platform(), rt.available().len());
    Ok(())
}
