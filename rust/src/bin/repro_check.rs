//! Minimal environment check: artifacts dir visible; with `--features
//! pjrt` the PJRT client must come up too.
use cirptc::util::error::Result;

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    #[cfg(feature = "pjrt")]
    {
        let rt = cirptc::runtime::Runtime::new(&dir)?;
        println!(
            "platform={} artifacts={}",
            rt.platform(),
            rt.available()?.len()
        );
    }
    #[cfg(not(feature = "pjrt"))]
    match cirptc::runtime::available_artifacts(&dir) {
        Ok(names) => println!("platform=rust-native artifacts={}", names.len()),
        // diagnosable, but not fatal: the pure-rust build serves without
        // AOT artifacts
        Err(e) => println!("platform=rust-native artifacts=unavailable ({e:#})"),
    }
    Ok(())
}
