//! `validate` — standalone front door to the static artifact validator
//! ([`cirptc::verify`]).  Loads a manifest + CPT1 bundle (and optionally
//! a chip description), runs the full pass pipeline and reports every
//! attributed diagnostic.  Exit status is the contract CI scripts key on:
//!
//! * `0` — verdict matches the expectation (valid by default, invalid
//!   with `--expect-invalid`)
//! * `1` — verdict contradicts the expectation, or the files themselves
//!   could not be loaded when they were expected to be valid
//!
//! A file that fails to parse counts as *invalid* (corrupt artifacts
//! often fail at the parse layer before the validator sees them), so
//! `--expect-invalid` fixtures may be broken at either level.

use std::path::PathBuf;
use std::process::ExitCode;

use cirptc::data::Bundle;
use cirptc::onn::Manifest;
use cirptc::simulator::ChipDescription;
use cirptc::util::cli::Args;
use cirptc::verify::{validate_artifacts, Report};

fn run(args: &Args) -> (bool, String) {
    let manifest_path = PathBuf::from(args.str_or("manifest", "artifacts/model.json"));
    let bundle_path = PathBuf::from(args.str_or("bundle", "artifacts/model.cpt"));
    let manifest = match Manifest::load(&manifest_path) {
        Ok(m) => m,
        Err(e) => return (false, format!("manifest {}: {e:#}", manifest_path.display())),
    };
    let bundle = match Bundle::load(&bundle_path) {
        Ok(b) => b,
        Err(e) => return (false, format!("bundle {}: {e:#}", bundle_path.display())),
    };
    let chip = match args.get("chip") {
        Some(p) => match ChipDescription::load(&PathBuf::from(p)) {
            Ok(c) => Some(c),
            Err(e) => return (false, format!("chip {p}: {e:#}")),
        },
        None => None,
    };
    let report = validate_artifacts(&manifest, &bundle, chip.as_ref());
    let ok = report.is_ok();
    (ok, render(&report, args.has("json")))
}

fn render(report: &Report, json: bool) -> String {
    if json {
        return report.json_dump();
    }
    if report.is_ok() {
        return "ok: artifacts are structurally sound".to_string();
    }
    let mut s = format!("{} validation error(s):\n", report.diagnostics.len());
    for d in &report.diagnostics {
        s.push_str("  ");
        s.push_str(&d.render());
        s.push('\n');
    }
    s.pop();
    s
}

fn main() -> ExitCode {
    let mut args = Args::parse();
    args.describe("manifest", "model manifest JSON (default artifacts/model.json)")
        .describe("bundle", "CPT1 weight bundle (default artifacts/model.cpt)")
        .describe("chip", "optional chip.json to check capability against")
        .describe("json", "emit the machine-readable diagnostic dump")
        .describe("expect-invalid", "exit 0 iff the artifacts are rejected")
        .describe("help", "print this help");
    if args.has("help") {
        println!("{}", args.usage());
        return ExitCode::SUCCESS;
    }
    let (valid, output) = run(&args);
    println!("{output}");
    let expected_valid = !args.has("expect-invalid");
    if valid == expected_valid {
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "validate: artifacts are {}, expected {}",
            if valid { "valid" } else { "invalid" },
            if expected_valid { "valid" } else { "invalid" },
        );
        ExitCode::FAILURE
    }
}
