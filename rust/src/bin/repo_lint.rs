//! `repo_lint` — dependency-free source lint for the invariants this
//! crate cares about but `clippy` cannot see.  Walks `rust/src` (or
//! `src` when run from inside `rust/`) and enforces three rules:
//!
//! * **hot-path-unwrap** — no `.unwrap()` / `.expect(` / `panic!(` in
//!   the request-path modules (`coordinator/`, `onn/`, `simulator/`,
//!   `circulant/`, `farm/`).  A panic there poisons locks shared with
//!   sibling workers and takes down the serving stack; errors must
//!   travel as `Result` or be recovered (`PoisonError::into_inner`).
//! * **std-sync** — no direct `std::sync` paths outside the
//!   `util/sync/` shim (and `bin/`, which never runs under the model
//!   checker).  Everything that synchronises must import through the
//!   shim so `--cfg loom` can swap in the instrumented types.
//! * **scratch-alloc** — the planned-path kernels that advertise
//!   zero-alloc steady state (`bcm_mmm_fft_planned`, `bcm_mvm_fft`,
//!   `column_spectra`, `pad_rows_pooled`, `multiply`) must not call
//!   `vec![` / `Vec::with_capacity` / `Vec::new` / `.to_vec(` — they
//!   draw from the thread-local scratch arena instead.
//! * **stage-buffer-bounded** — the stage-pipeline executor
//!   (`coordinator/pipeline.rs`) and the farm's failover router
//!   (`farm/router.rs`) must not create unbounded `mpsc::channel`
//!   inter-stage buffers: stage and member hand-offs go through
//!   `mpsc::sync_channel` so a slow stage (or wedged chip) exerts
//!   backpressure instead of queueing batches (and their scratch
//!   buffers) without bound.
//! * **obs-record-alloc** — the tracing record path (`obs/trace.rs`:
//!   `push` / `record_instant` / `record_complete` / `begin` / `end` /
//!   `instant`) must not allocate.  These run inline on the serving
//!   hot path; when tracing is disabled they must reduce to one atomic
//!   load, and when enabled they write into the pre-sized ring only.
//! * **obs-bounded-channel** — no unbounded `mpsc::channel` anywhere
//!   under `obs/`: the sampler's control channel and any future obs
//!   plumbing stay bounded so observability can never buffer without
//!   limit while the thing it observes is wedged.
//! * **obs-named-listener** — obs threads must be identifiable in a
//!   hung-process dump: no anonymous `thread::spawn(` under `obs/`,
//!   and the `/metrics` accept loop (`obs/prom.rs`, the file holding
//!   the `TcpListener`) must go through `spawn_scoped_named`.
//! * **retry-budget** — every retry redispatch site must be bounded:
//!   a non-test line that both mentions `retry` and performs a
//!   `.send(` is only legal in a file whose non-test code references
//!   a `RETRY_BUDGET` constant somewhere.  An unbounded retry loop
//!   (requeue on every failure with no attempt ceiling) turns one
//!   poisoned batch into an infinite hot loop that starves the farm;
//!   tying the send site to a named budget constant keeps the bound
//!   greppable and reviewable.
//!
//! Escapes: a `// lint:allow(<rule>): <reason>` comment suppresses the
//! rule on the next non-comment line (or on its own line when it
//! trails code).  An allow without a reason is itself a finding.
//! Test code (everything from the first `#[cfg(test)]` to end of file)
//! is exempt.  Exit status 1 when any finding survives.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const KNOWN_RULES: &[&str] = &[
    "hot-path-unwrap",
    "std-sync",
    "scratch-alloc",
    "stage-buffer-bounded",
    "obs-record-alloc",
    "obs-bounded-channel",
    "obs-named-listener",
    "retry-budget",
];
const UNWRAP_NEEDLES: &[&str] = &[".unwrap()", ".expect(", "panic!("];
const ALLOC_NEEDLES: &[&str] = &["vec![", "Vec::with_capacity", "Vec::new", ".to_vec("];
const HOT_DIRS: &[&str] =
    &["coordinator/", "onn/", "simulator/", "circulant/", "farm/"];

/// Files whose non-test code must only use bounded (`sync_channel`)
/// stage buffers.  `mpsc::sync_channel` does not contain the needle, so
/// matching the bare path is safe (and catches turbofish call sites).
const BOUNDED_CHANNEL_FILES: &[&str] =
    &["coordinator/pipeline.rs", "farm/router.rs"];
const UNBOUNDED_CHANNEL_NEEDLE: &str = "mpsc::channel";

/// (file relative to src/, function name) pairs held to the
/// scratch-arena-only allocation discipline.
const SCRATCH_FNS: &[(&str, &str)] = &[
    ("circulant/fft.rs", "bcm_mmm_fft_planned"),
    ("circulant/fft.rs", "bcm_mvm_fft"),
    ("circulant/fft.rs", "column_spectra"),
    ("onn/engine.rs", "pad_rows_pooled"),
    ("onn/plan.rs", "multiply"),
];

/// Directory prefix the obs-specific rules apply to.
const OBS_DIR: &str = "obs/";
/// The file holding the `/metrics` accept loop.
const OBS_LISTENER_FILE: &str = "obs/prom.rs";
/// Functions on the tracing record path (all in `obs/trace.rs`) held to
/// the no-allocation discipline — same `fn_span` mechanism as
/// `SCRATCH_FNS`.  `new` / `snapshot` / the Chrome exporter are
/// deliberately absent: they run at setup / export time, not per event.
const OBS_RECORD_FNS: &[&str] =
    &["push", "record_instant", "record_complete", "begin", "end", "instant"];
const ANON_SPAWN_NEEDLE: &str = "thread::spawn(";

/// A retry redispatch site: a line mentioning `retry` that also calls
/// `.send(` (covers `retry_tx.send(` and `retry_tx.try_send(`).  Any
/// file containing one must also reference a `RETRY_BUDGET` constant in
/// its non-test code — the greppable evidence that the retry loop is
/// bounded by an attempt ceiling.  `RETRY_BUDGET` is case-distinct from
/// the lowercase `retry` needle, so the constant's own definition line
/// never counts as a send site.
const RETRY_SEND_NEEDLES: (&str, &str) = ("retry", ".send(");
const RETRY_BUDGET_NEEDLE: &str = "RETRY_BUDGET";

#[derive(Debug)]
struct Finding {
    file: String,
    line: usize, // 1-based
    rule: &'static str,
    excerpt: String,
}

impl Finding {
    fn render(&self) -> String {
        format!("src/{}:{}: [{}] {}", self.file, self.line, self.rule, self.excerpt)
    }
}

/// Remove string-literal contents and line comments so needles inside
/// `"..."` or `// ...` never match.  Naive by design: no raw-string or
/// block-comment awareness (the codebase uses neither in lint scope),
/// but escape-aware inside strings so `"\""` does not derail it.
fn strip_code(line: &str) -> String {
    let mut out = String::with_capacity(line.len());
    let bytes = line.as_bytes();
    let mut i = 0;
    let mut in_str = false;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if in_str {
            if c == '\\' {
                i += 2;
                continue;
            }
            if c == '"' {
                in_str = false;
                out.push('"');
            }
            i += 1;
            continue;
        }
        if c == '"' {
            in_str = true;
            out.push('"');
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < bytes.len() && bytes[i + 1] as char == '/' {
            break; // line comment: drop the rest
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Parsed `lint:allow` escape comment.
struct Allow {
    rule: String,
    has_reason: bool,
    /// true when the comment trails code on the same line
    trailing: bool,
}

fn parse_allow(raw: &str) -> Option<Allow> {
    let pos = raw.find("// lint:allow(")?;
    let rest = &raw[pos + "// lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let after = &rest[close + 1..];
    let has_reason = after
        .strip_prefix(':')
        .map(|r| !r.trim().is_empty())
        .unwrap_or(false);
    let trailing = !strip_code(&raw[..pos]).trim().is_empty();
    Some(Allow { rule, has_reason, trailing })
}

/// Line span (0-based, inclusive) of `fn <name>(` bodies found in the
/// stripped lines, tracked by brace depth from the first `{` onward.
fn fn_span(stripped: &[String], name: &str) -> Option<(usize, usize)> {
    let needle = format!("fn {name}(");
    let start = stripped.iter().position(|l| l.contains(&needle))?;
    let mut depth = 0i32;
    let mut opened = false;
    for (i, line) in stripped.iter().enumerate().skip(start) {
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some((start, i));
        }
    }
    Some((start, stripped.len().saturating_sub(1)))
}

struct FileReport {
    findings: Vec<Finding>,
    allows: usize,
}

fn analyze_file(rel: &str, content: &str) -> FileReport {
    let raw: Vec<&str> = content.lines().collect();
    let stripped: Vec<String> = raw.iter().map(|l| strip_code(l)).collect();
    let test_start = raw
        .iter()
        .position(|l| l.trim_start().starts_with("#[cfg(test)]"))
        .unwrap_or(raw.len());

    let mut findings = Vec::new();
    let mut allows = 0usize;
    // line index -> rules allowed on that line
    let mut allowed: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    for (i, line) in raw.iter().enumerate() {
        let Some(allow) = parse_allow(line) else { continue };
        if i >= test_start || !KNOWN_RULES.contains(&allow.rule.as_str()) {
            continue;
        }
        allows += 1;
        if !allow.has_reason {
            findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "lint-allow",
                excerpt: format!("lint:allow({}) without a justification", allow.rule),
            });
            continue;
        }
        let target = if allow.trailing {
            Some(i)
        } else {
            // a standalone allow covers the next line that is actual
            // code — comment continuation lines strip to empty
            (i + 1..raw.len()).find(|&j| !stripped[j].trim().is_empty())
        };
        if let Some(j) = target {
            allowed.entry(j).or_default().push(allow.rule);
        }
    }

    let is_allowed =
        |i: usize, rule: &str| allowed.get(&i).is_some_and(|rs| rs.iter().any(|r| r == rule));

    let hot_path = HOT_DIRS.iter().any(|d| rel.starts_with(d));
    let sync_scoped = !rel.starts_with("util/sync/") && !rel.starts_with("bin/");
    let bounded_channels = BOUNDED_CHANNEL_FILES.contains(&rel);
    let scratch_spans: Vec<(usize, usize)> = SCRATCH_FNS
        .iter()
        .filter(|(f, _)| *f == rel)
        .filter_map(|(_, name)| fn_span(&stripped, name))
        .collect();
    let obs_file = rel.starts_with(OBS_DIR);
    let obs_record_spans: Vec<(usize, usize)> = if rel == "obs/trace.rs" {
        OBS_RECORD_FNS
            .iter()
            .filter_map(|name| fn_span(&stripped, name))
            .collect()
    } else {
        Vec::new()
    };

    for (i, code) in stripped.iter().enumerate().take(test_start) {
        if hot_path && !is_allowed(i, "hot-path-unwrap") {
            if let Some(n) = UNWRAP_NEEDLES.iter().find(|n| code.contains(*n)) {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "hot-path-unwrap",
                    excerpt: format!("`{n}` on the request path: {}", raw[i].trim()),
                });
            }
        }
        if sync_scoped && code.contains("std::sync") && !is_allowed(i, "std-sync") {
            findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "std-sync",
                excerpt: format!(
                    "direct std::sync path (import via util::sync shim): {}",
                    raw[i].trim()
                ),
            });
        }
        if bounded_channels
            && code.contains(UNBOUNDED_CHANNEL_NEEDLE)
            && !is_allowed(i, "stage-buffer-bounded")
        {
            findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "stage-buffer-bounded",
                excerpt: format!(
                    "unbounded mpsc::channel in the stage pipeline (use \
                     sync_channel for backpressure): {}",
                    raw[i].trim()
                ),
            });
        }
        if scratch_spans.iter().any(|&(a, b)| i >= a && i <= b)
            && !is_allowed(i, "scratch-alloc")
        {
            if let Some(n) = ALLOC_NEEDLES.iter().find(|n| code.contains(*n)) {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "scratch-alloc",
                    excerpt: format!("`{n}` in a zero-alloc kernel: {}", raw[i].trim()),
                });
            }
        }
        if obs_record_spans.iter().any(|&(a, b)| i >= a && i <= b)
            && !is_allowed(i, "obs-record-alloc")
        {
            if let Some(n) = ALLOC_NEEDLES.iter().find(|n| code.contains(*n)) {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "obs-record-alloc",
                    excerpt: format!(
                        "`{n}` on the tracing record path: {}",
                        raw[i].trim()
                    ),
                });
            }
        }
        if obs_file
            && code.contains(UNBOUNDED_CHANNEL_NEEDLE)
            && !is_allowed(i, "obs-bounded-channel")
        {
            findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "obs-bounded-channel",
                excerpt: format!(
                    "unbounded mpsc::channel in obs (sampler/control channels \
                     must be sync_channel): {}",
                    raw[i].trim()
                ),
            });
        }
        if obs_file
            && code.contains(ANON_SPAWN_NEEDLE)
            && !is_allowed(i, "obs-named-listener")
        {
            findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "obs-named-listener",
                excerpt: format!(
                    "anonymous thread::spawn in obs (use spawn_scoped_named / \
                     spawn_named so dumps are attributable): {}",
                    raw[i].trim()
                ),
            });
        }
    }

    // Whole-file check: the `/metrics` accept loop must run on a named
    // scoped thread.  Flagged at the first `TcpListener` mention when
    // `spawn_scoped_named` is absent from the non-test code.
    if rel == OBS_LISTENER_FILE {
        let non_test = &stripped[..test_start];
        let listener = non_test.iter().position(|l| l.contains("TcpListener"));
        let named = non_test.iter().any(|l| l.contains("spawn_scoped_named"));
        if let Some(i) = listener {
            if !named && !is_allowed(i, "obs-named-listener") {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "obs-named-listener",
                    excerpt: "TcpListener accept loop without a \
                              spawn_scoped_named thread"
                        .to_string(),
                });
            }
        }
    }

    // Whole-file check: retry redispatch sends are only legal when the
    // file's non-test code names a `RETRY_BUDGET` constant — the
    // evidence that the retry loop has an attempt ceiling.  Flagged at
    // every send site so each one is individually allowable.
    {
        let non_test = &stripped[..test_start];
        let budgeted =
            non_test.iter().any(|l| l.contains(RETRY_BUDGET_NEEDLE));
        if !budgeted {
            let (a, b) = RETRY_SEND_NEEDLES;
            for (i, code) in non_test.iter().enumerate() {
                if code.contains(a)
                    && code.contains(b)
                    && !is_allowed(i, "retry-budget")
                {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "retry-budget",
                        excerpt: format!(
                            "retry send without a RETRY_BUDGET bound in \
                             this file: {}",
                            raw[i].trim()
                        ),
                    });
                }
            }
        }
    }

    FileReport { findings, allows }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn main() -> ExitCode {
    let root = ["rust/src", "src"]
        .iter()
        .map(Path::new)
        .find(|p| p.is_dir());
    let Some(root) = root else {
        eprintln!("repo_lint: neither rust/src nor src found; run from the repo root");
        return ExitCode::FAILURE;
    };

    let mut files = Vec::new();
    collect_rs(root, &mut files);

    let mut findings = Vec::new();
    let mut allows = 0usize;
    for path in &files {
        let Ok(content) = fs::read_to_string(path) else {
            eprintln!("repo_lint: unreadable file {}", path.display());
            return ExitCode::FAILURE;
        };
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let report = analyze_file(&rel, &content);
        findings.extend(report.findings);
        allows += report.allows;
    }

    for f in &findings {
        println!("{}", f.render());
    }
    println!(
        "repo_lint: {} files scanned, {} finding(s), {} allow(s)",
        files.len(),
        findings.len(),
        allows
    );
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_strings_and_comments() {
        assert_eq!(strip_code(r#"let s = ".unwrap()"; // .expect("#), r#"let s = ""; "#);
        assert_eq!(strip_code("x(); // panic!("), "x(); ");
        assert_eq!(strip_code(r#"let q = "a\"b.unwrap()";"#), r#"let q = "";"#);
    }

    #[test]
    fn hot_path_needles_fire_and_test_mod_is_exempt() {
        let src = "fn f() {\n    x.unwrap();\n}\n#[cfg(test)]\nmod t {\n    \
                   fn g() { y.unwrap(); }\n}\n";
        let r = analyze_file("coordinator/worker.rs", src);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].line, 2);
        assert_eq!(r.findings[0].rule, "hot-path-unwrap");
        // same content outside the hot dirs: clean
        assert!(analyze_file("util/cli.rs", src).findings.is_empty());
    }

    #[test]
    fn needles_inside_strings_and_comments_do_not_fire() {
        let src = "fn f() {\n    let m = \"call .unwrap() later\";\n    \
                   // .expect( is discussed here\n}\n";
        assert!(analyze_file("onn/engine.rs", src).findings.is_empty());
    }

    #[test]
    fn standalone_allow_skips_comment_continuations() {
        let src = "fn f() {\n    // lint:allow(hot-path-unwrap): startup only,\n    \
                   // continuation of the justification\n    x.expect(\"boom\");\n}\n";
        let r = analyze_file("coordinator/worker.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.allows, 1);
    }

    #[test]
    fn trailing_allow_covers_its_own_line() {
        let src = "fn f() {\n    x.unwrap(); \
                   // lint:allow(hot-path-unwrap): infallible by construction\n}\n";
        assert!(analyze_file("simulator/mod.rs", src).findings.is_empty());
    }

    #[test]
    fn allow_without_reason_is_a_finding() {
        let src = "fn f() {\n    // lint:allow(hot-path-unwrap)\n    x.unwrap();\n}\n";
        let r = analyze_file("coordinator/worker.rs", src);
        // the bare allow is flagged AND does not suppress the unwrap
        assert_eq!(r.findings.len(), 2, "{:?}", r.findings);
        assert!(r.findings.iter().any(|f| f.rule == "lint-allow"));
        assert!(r.findings.iter().any(|f| f.rule == "hot-path-unwrap"));
    }

    #[test]
    fn allow_for_wrong_rule_does_not_suppress() {
        let src = "fn f() {\n    // lint:allow(scratch-alloc): wrong rule\n    x.unwrap();\n}\n";
        let r = analyze_file("circulant/fft.rs", src);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "hot-path-unwrap");
    }

    #[test]
    fn std_sync_rule_scoping() {
        let src = "use std::sync::Mutex;\n";
        assert_eq!(analyze_file("coordinator/mod.rs", src).findings.len(), 1);
        assert!(analyze_file("util/sync/mod.rs", src).findings.is_empty());
        assert!(analyze_file("bin/validate.rs", src).findings.is_empty());
    }

    #[test]
    fn scratch_alloc_only_inside_configured_fns() {
        let src = "pub fn bcm_mvm_fft(x: &[f32]) {\n    let v = vec![0.0; 4];\n}\n\n\
                   pub fn other() {\n    let w = Vec::with_capacity(9);\n}\n";
        let r = analyze_file("circulant/fft.rs", src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].line, 2);
        assert_eq!(r.findings[0].rule, "scratch-alloc");
    }

    #[test]
    fn stage_buffer_rule_flags_unbounded_channels_in_pipeline_only() {
        let src = "fn wire() {\n    let (tx, rx) = mpsc::channel::<Batch>();\n}\n";
        let r = analyze_file("coordinator/pipeline.rs", src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, "stage-buffer-bounded");
        // bounded buffers are the sanctioned hand-off
        let ok = "fn wire() {\n    let (tx, rx) = mpsc::sync_channel::<Batch>(2);\n}\n";
        assert!(analyze_file("coordinator/pipeline.rs", ok).findings.is_empty());
        // the reply channels elsewhere in the coordinator stay legal
        assert!(analyze_file("coordinator/mod.rs", src).findings.is_empty());
    }

    #[test]
    fn farm_dir_is_hot_and_its_router_buffers_are_bounded() {
        let hot = "fn f() {\n    x.unwrap();\n}\n";
        let r = analyze_file("farm/mod.rs", hot);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "hot-path-unwrap");
        let unbounded = "fn wire() {\n    let (tx, rx) = mpsc::channel::<Batch>();\n}\n";
        let r = analyze_file("farm/router.rs", unbounded);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, "stage-buffer-bounded");
        // the farm's intake/reply channels outside the router stay legal
        assert!(analyze_file("farm/mod.rs", unbounded).findings.is_empty());
    }

    #[test]
    fn fn_span_tracks_nested_braces() {
        let src = "pub fn multiply(a: u32) -> u32 {\n    let f = |x: u32| { x + 1 };\n    \
                   f(a)\n}\nfn after() { let v = vec![1]; }\n";
        let stripped: Vec<String> = src.lines().map(strip_code).collect();
        assert_eq!(fn_span(&stripped, "multiply"), Some((0, 3)));
        // the vec! in `after` is outside the multiply span
        let r = analyze_file("onn/plan.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn obs_record_alloc_fires_inside_record_fns_only() {
        let src = "pub fn record_instant(&self, n: u32) {\n    \
                   let v = vec![0u64; 4];\n}\n\n\
                   pub fn snapshot(&self) -> Vec<u64> {\n    \
                   Vec::with_capacity(8)\n}\n";
        let r = analyze_file("obs/trace.rs", src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].line, 2);
        assert_eq!(r.findings[0].rule, "obs-record-alloc");
        // the same record fn in an unrelated file is out of scope
        assert!(analyze_file("obs/sampler.rs", src).findings.is_empty());
    }

    #[test]
    fn obs_channels_must_be_bounded() {
        let src = "fn wire() {\n    let (tx, rx) = mpsc::channel::<()>();\n}\n";
        let r = analyze_file("obs/sampler.rs", src);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, "obs-bounded-channel");
        // sync_channel is the sanctioned hand-off
        let ok = "fn wire() {\n    let (tx, rx) = mpsc::sync_channel::<()>(1);\n}\n";
        assert!(analyze_file("obs/sampler.rs", ok).findings.is_empty());
        // outside obs/, this stays the stage-buffer rule's business
        assert!(analyze_file("util/metrics.rs", src).findings.is_empty());
    }

    #[test]
    fn retry_sends_require_a_budget_constant() {
        // a retry send with no RETRY_BUDGET anywhere: finding
        let bad = "fn requeue() {\n    let _ = link.retry_tx.send((m, batch));\n}\n";
        let r = analyze_file("coordinator/pipeline.rs", bad);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, "retry-budget");
        assert_eq!(r.findings[0].line, 2);
        // naming the budget constant anywhere in non-test code clears it
        let ok = "pub const FARM_RETRY_BUDGET: u32 = 3;\n\
                  fn requeue() {\n    let _ = link.retry_tx.send((m, batch));\n}\n";
        assert!(analyze_file("coordinator/pipeline.rs", ok).findings.is_empty());
        // a budget constant only inside #[cfg(test)] does NOT count
        let test_only = "fn requeue() {\n    let _ = retry_tx.send(b);\n}\n\
                         #[cfg(test)]\nmod t {\n    const RETRY_BUDGET: u32 = 1;\n}\n";
        let r = analyze_file("farm/mod.rs", test_only);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, "retry-budget");
        // sends that do not mention retry are out of scope
        let unrelated = "fn go() {\n    tx.send(batch);\n    retry_count += 1;\n}\n";
        assert!(analyze_file("farm/router.rs", unrelated).findings.is_empty());
        // an explicit allow with a reason suppresses the site
        let allowed = "fn requeue() {\n    \
                       // lint:allow(retry-budget): bounded by caller's attempt check\n    \
                       let _ = retry_tx.send(b);\n}\n";
        assert!(analyze_file("farm/mod.rs", allowed).findings.is_empty());
    }

    #[test]
    fn metrics_listener_thread_must_be_named() {
        // TcpListener without spawn_scoped_named: whole-file finding
        let bad = "fn serve() {\n    let l = TcpListener::bind(\"x\");\n}\n";
        let r = analyze_file("obs/prom.rs", bad);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, "obs-named-listener");
        assert_eq!(r.findings[0].line, 2);
        // named scoped accept loop passes
        let ok = "fn serve() {\n    let l = TcpListener::bind(\"x\");\n    \
                  spawn_scoped_named(scope, \"cirptc-metrics\", move || accept(l));\n}\n";
        assert!(analyze_file("obs/prom.rs", ok).findings.is_empty());
        // anonymous spawns anywhere under obs/ are flagged line-by-line
        let anon = "fn go() {\n    std::thread::spawn(move || {});\n}\n";
        let r = analyze_file("obs/sampler.rs", anon);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, "obs-named-listener");
    }
}
