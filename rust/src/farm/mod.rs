//! Multi-chip serving farm: partitioned engines, per-chip drift
//! compensation, and health-state failover (DESIGN.md §farm).
//!
//! The [`crate::drift`] subsystem assumes one shared engine slot and one
//! chip per worker; a *farm* generalizes that to N chips that fail and
//! drift **independently**:
//!
//! * [`partition`] — shard a manifest's circulant block-rows across
//!   chips whose [`crate::simulator::ChipDescription::mrr_capacity`]
//!   cannot hold the whole model, [`PartitionedEngine`] ([`engine`])
//!   executes the shards bit-identically to the single-chip engine;
//! * [`FarmMember`] (here) — one chip's full serving stack: its own
//!   engine copy in its own [`crate::drift::DriftShared`], its own
//!   (differently seeded) drifting sim, its own
//!   [`crate::drift::DriftMonitor`] and recalibration channel.  Nothing
//!   is shared between members except the metrics sink, so one chip
//!   recalibrating never blocks or rebases a sibling;
//! * [`ChipStatus`] (here) — the per-chip health machine
//!   `Healthy → Drifting → Recalibrating → (Healthy | Failed)`, derived
//!   *live* from the member's drift state (never latched, so a chip
//!   that recovers is immediately routable again) plus a sticky
//!   operator kill switch ([`ChipStatus::fail`]);
//! * [`router`] — the failover stage between the dynamic batcher and
//!   the per-chip pipelines: round-robin over serving-capable members,
//!   reroute around `Recalibrating`/`Failed` chips, absorb into
//!   whatever still lives when nothing healthy remains.
//!
//! [`Farm::start`] wires intake → batcher → router → N single-member
//! pipelined workers ([`crate::coordinator::pipeline`]) behind the
//! ordinary [`Coordinator`] submit/shed front end, so admission control,
//! metrics and the zero-drop drain guarantee carry over unchanged
//! (`rust/tests/farm_e2e.rs` pins all of it).

pub mod engine;
pub mod partition;
mod router;

pub use engine::PartitionedEngine;
pub use partition::{circ_grids, tile_demand, LayerGrid, LayerShard, PartitionPlan};

use std::time::Duration;

use crate::util::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use crate::util::sync::{mpsc, Arc, Mutex, PoisonError};

use crate::coordinator::{
    batcher, pipeline, worker, Batch, BatcherConfig, Coordinator,
    EngineSource, InferenceBackend, Metrics, PipelineConfig, Request, Staged,
};
use crate::drift::{DriftMonitor, DriftShared, RecalRequest};
use crate::fault::{ChipSupervisor, Verdict};
use crate::obs::trace;
use crate::onn::{Backend, Engine};
use crate::simulator::ChipSim;
use crate::tensor::Tensor;
use crate::util::error::Result;

/// Residual (ppm of the probe reference range) at which a member counts
/// as [`ChipHealth::Drifting`]: degraded-but-serving, deprioritized by
/// the router.  One fifth of the default recalibration trigger
/// ([`crate::drift::MonitorConfig::residual_trigger`] = 0.05), so the
/// state machine visibly passes through Drifting before a
/// recalibration fires.
pub const DEFAULT_DRIFTING_PPM: i64 = 10_000;

/// One chip's health state, most healthy first.  `Drifting` still
/// serves (the router only deprioritizes it); `Recalibrating` serves on
/// the pre-swap engine but is routed around; `Failed` is the sticky
/// operator kill switch and never serves while any sibling lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChipHealth {
    Healthy,
    Drifting,
    Recalibrating,
    Failed,
}

impl ChipHealth {
    /// Serving-capable at full trust: the router's first-choice pool.
    pub fn serves(self) -> bool {
        matches!(self, ChipHealth::Healthy | ChipHealth::Drifting)
    }

    /// Stable lowercase name, used by the telemetry exporters
    /// ([`crate::obs`]) as the label value in Prometheus text and the
    /// JSONL sampler stream.
    pub fn name(self) -> &'static str {
        match self {
            ChipHealth::Healthy => "healthy",
            ChipHealth::Drifting => "drifting",
            ChipHealth::Recalibrating => "recalibrating",
            ChipHealth::Failed => "failed",
        }
    }

    /// Numeric code for gauge export, most healthy first (0 = Healthy …
    /// 3 = Failed) so dashboards can alert on `health > 1`.
    pub fn code(self) -> i64 {
        match self {
            ChipHealth::Healthy => 0,
            ChipHealth::Drifting => 1,
            ChipHealth::Recalibrating => 2,
            ChipHealth::Failed => 3,
        }
    }
}

/// Live health handle for one farm member.  The state is **derived** on
/// every read — `Recalibrating` from the member's single-flight recal
/// gate, `Drifting` from its last probe residual — so recovery needs no
/// acknowledgment protocol: the moment the recalibrator finishes and a
/// probe comes back clean, the member reads `Healthy` again.  Only
/// `Failed` is latched ([`ChipStatus::fail`] / [`ChipStatus::restore`]).
pub struct ChipStatus {
    failed: AtomicBool,
    /// escalation latch: set by the supervisor after repeated failed
    /// probations.  Implies `failed`; only [`ChipStatus::restore`] (an
    /// operator action) clears it.
    quarantined: AtomicBool,
    /// last probe residual in ppm, published by the member's chip hook
    residual_ppm: AtomicI64,
    /// at or above this residual the member reads `Drifting`
    drifting_ppm: i64,
    /// the member's drift state; `None` for members without drift
    /// machinery (digital fallback), which only toggle Healthy/Failed
    shared: Option<Arc<DriftShared>>,
}

impl ChipStatus {
    pub fn new(
        shared: Option<Arc<DriftShared>>,
        drifting_ppm: i64,
    ) -> Arc<ChipStatus> {
        Arc::new(ChipStatus {
            failed: AtomicBool::new(false),
            quarantined: AtomicBool::new(false),
            residual_ppm: AtomicI64::new(0),
            drifting_ppm: drifting_ppm.max(1),
            shared,
        })
    }

    /// Derive the current health state (see the type docs for priority).
    pub fn health(&self) -> ChipHealth {
        if self.failed.load(Ordering::Relaxed) {
            return ChipHealth::Failed;
        }
        if let Some(s) = &self.shared {
            if s.recal_in_flight.in_flight() {
                return ChipHealth::Recalibrating;
            }
        }
        if self.residual_ppm.load(Ordering::Relaxed) >= self.drifting_ppm {
            ChipHealth::Drifting
        } else {
            ChipHealth::Healthy
        }
    }

    /// Sticky kill switch — thrown by an operator or by the member's
    /// [`ChipSupervisor`]: the member stops receiving traffic (unless
    /// every sibling is also down) until [`ChipStatus::restore`].
    pub fn fail(&self) {
        self.failed.store(true, Ordering::Relaxed);
    }

    /// Escalation latch: like [`ChipStatus::fail`], but also marks the
    /// member [`ChipStatus::is_quarantined`] so dashboards and the
    /// sampler can tell "down, supervisor gave up" from a plain failure.
    pub fn quarantine(&self) {
        self.quarantined.store(true, Ordering::Relaxed);
        self.failed.store(true, Ordering::Relaxed);
    }

    /// Whether the supervisor escalated this member to `Quarantined`.
    pub fn is_quarantined(&self) -> bool {
        self.quarantined.load(Ordering::Relaxed)
    }

    /// Clear the kill switch (and any quarantine latch); health
    /// derivation resumes normally.  The stale residual from before the
    /// failure is also dropped — the member was failed precisely because
    /// its last probes were bad, and leaving them published would make a
    /// restored member immediately re-read as `Drifting` until the next
    /// probe lands.
    pub fn restore(&self) {
        self.failed.store(false, Ordering::Relaxed);
        self.quarantined.store(false, Ordering::Relaxed);
        self.residual_ppm.store(0, Ordering::Relaxed);
    }

    /// Last published probe residual, ppm.
    pub fn residual_ppm(&self) -> i64 {
        self.residual_ppm.load(Ordering::Relaxed)
    }

    pub(crate) fn set_residual_ppm(&self, ppm: i64) {
        self.residual_ppm.store(ppm, Ordering::Relaxed);
    }
}

/// Farm-wide tuning.
#[derive(Clone, Debug)]
pub struct FarmConfig {
    pub batcher: BatcherConfig,
    pub pipeline: PipelineConfig,
    /// bounded routing queue per member (batches a member may run
    /// behind the router before backpressure reaches admission control)
    pub member_queue: usize,
    /// chip-stage deadline per batch: a member whose pass stream exceeds
    /// it is treated as wedged — the batch is redispatched and the event
    /// counts as a fault toward the member's supervisor.  `None` (the
    /// default) disables the check.
    pub pass_deadline: Option<Duration>,
}

impl Default for FarmConfig {
    fn default() -> FarmConfig {
        FarmConfig {
            batcher: BatcherConfig::default(),
            pipeline: PipelineConfig::default(),
            member_queue: 2,
            pass_deadline: None,
        }
    }
}

/// One chip's serving stack, ready to be wired into a [`Farm`].
pub struct FarmMember {
    /// live health handle (also returned in [`Farm::status`])
    pub status: Arc<ChipStatus>,
    /// the member's drift state, for attaching a
    /// [`crate::drift::Recalibrator`]; `None` for fixed members
    pub shared: Option<Arc<DriftShared>>,
    source: EngineSource,
    backend: Backend,
    hook: Option<pipeline::ChipHook>,
    /// idle-interval hook ([`crate::coordinator::Staged`]): how a
    /// failed (traffic-less) member still runs probation probes
    idle: Option<(Duration, pipeline::ChipHook)>,
}

/// Everything the supervised member's two hooks (serving + idle) share.
/// Both hooks run on the member's single chip-lane thread, so the mutex
/// is uncontended — it only satisfies the `Send` bound on the closures.
struct SupervisorInner {
    monitor: DriftMonitor,
    supervisor: ChipSupervisor,
    batches: u64,
    /// detectable fault events already fed to the supervisor
    faults_seen: u64,
    /// plan-injected corruptions already surfaced in the metrics
    injected_seen: u64,
}

/// Apply a supervisor verdict to the member's health handle: this is the
/// probe-driven automatic `fail()` / `restore()` loop — the operator
/// actions become outputs of the state machine.
fn apply_verdict(v: Verdict, status: &ChipStatus, metrics: &Metrics) {
    match v {
        Verdict::Fail => {
            status.fail();
            metrics.quarantines.add(1);
            trace::instant("quarantine", "fault", trace::arg1("latched", 0));
        }
        Verdict::Restore => {
            status.restore();
            trace::instant("restore", "fault", trace::arg1("latched", 0));
        }
        Verdict::Quarantine => {
            status.quarantine();
            metrics.quarantines.add(1);
            trace::instant("quarantine", "fault", trace::arg1("latched", 1));
            eprintln!(
                "cirptc farm: member quarantined after repeated failed probations"
            );
        }
    }
}

impl FarmMember {
    /// Drift-compensated photonic member: its own engine copy behind its
    /// own hot-swap slot, its own chip (give each member's `sim` a
    /// differently seeded drift process), its own monitor.  Returns the
    /// recalibration-request receiver — hand it to a
    /// [`crate::drift::Recalibrator`] built over the member's `shared`,
    /// or drop it for a monitor-only member.
    pub fn monitored(
        engine: Engine,
        sim: ChipSim,
        monitor: DriftMonitor,
        drifting_ppm: i64,
        metrics: Arc<Metrics>,
    ) -> (FarmMember, mpsc::Receiver<RecalRequest>) {
        let shared = DriftShared::new(engine, metrics);
        let status = ChipStatus::new(Some(Arc::clone(&shared)), drifting_ppm);
        let (recal_tx, recal_rx) = mpsc::channel();
        let hook_shared = Arc::clone(&shared);
        let hook_status = Arc::clone(&status);
        let mut monitor = monitor;
        let mut batches = 0u64;
        let hook: pipeline::ChipHook = Box::new(move |backend: &mut Backend| {
            if let Backend::PhotonicSim(sim) = backend {
                batches += 1;
                // the probe residual only feeds a supervisor (see
                // [`FarmMember::supervised`]); a plain monitored member
                // classifies off the published ppm signal below
                let _ = monitor.after_batch(sim, batches, &hook_shared, &recal_tx);
                // publish the member-local drift signal the health
                // machine classifies on (the metrics gauge is shared
                // farm-wide and would mix the members together)
                hook_status.set_residual_ppm(
                    (monitor.last_residual() as f64 * 1e6) as i64,
                );
            }
        });
        (
            FarmMember {
                status,
                shared: Some(Arc::clone(&shared)),
                source: EngineSource::Shared(shared),
                backend: Backend::PhotonicSim(sim),
                hook: Some(hook),
                idle: None,
            },
            recal_rx,
        )
    }

    /// Self-healing photonic member: [`FarmMember::monitored`] plus a
    /// [`ChipSupervisor`] that turns probe residuals and detected fault
    /// events into automatic [`ChipStatus::fail`] / `restore` /
    /// `quarantine` verdicts.  While the member is failed (and therefore
    /// traffic-less) the idle hook keeps probing every `idle_every`, so
    /// probation runs off the serving path and a recovered chip restores
    /// itself without operator action.
    pub fn supervised(
        engine: Engine,
        sim: ChipSim,
        monitor: DriftMonitor,
        supervisor: ChipSupervisor,
        drifting_ppm: i64,
        idle_every: Duration,
        metrics: Arc<Metrics>,
    ) -> (FarmMember, mpsc::Receiver<RecalRequest>) {
        let shared = DriftShared::new(engine, Arc::clone(&metrics));
        let status = ChipStatus::new(Some(Arc::clone(&shared)), drifting_ppm);
        let (recal_tx, recal_rx) = mpsc::channel();
        let inner = Arc::new(Mutex::new(SupervisorInner {
            monitor,
            supervisor,
            batches: 0,
            faults_seen: 0,
            injected_seen: 0,
        }));
        let hook: pipeline::ChipHook = {
            let inner = Arc::clone(&inner);
            let shared = Arc::clone(&shared);
            let status = Arc::clone(&status);
            let metrics = Arc::clone(&metrics);
            Box::new(move |backend: &mut Backend| {
                if let Backend::PhotonicSim(sim) = backend {
                    let mut inner =
                        inner.lock().unwrap_or_else(PoisonError::into_inner);
                    inner.batches += 1;
                    let batches = inner.batches;
                    // surface plan-injected corruptions in the farm-wide
                    // counter, and feed each *detectable* event to the
                    // supervisor as a bad observation so a member fails
                    // even between probe cadences
                    let injected = sim.faults_injected();
                    if injected > inner.injected_seen {
                        metrics
                            .faults_injected
                            .add((injected - inner.injected_seen) as usize);
                        inner.injected_seen = injected;
                    }
                    let mut verdict = None;
                    let faults = sim.fault_events();
                    while inner.faults_seen < faults {
                        inner.faults_seen += 1;
                        if let Some(v) = inner.supervisor.note_fault() {
                            verdict = Some(v);
                        }
                    }
                    if let Some(res) =
                        inner.monitor.after_batch(sim, batches, &shared, &recal_tx)
                    {
                        if let Some(v) = inner.supervisor.observe(res) {
                            verdict = Some(v);
                        }
                    }
                    status.set_residual_ppm(
                        (inner.monitor.last_residual() as f64 * 1e6) as i64,
                    );
                    if let Some(v) = verdict {
                        apply_verdict(v, &status, &metrics);
                    }
                }
            })
        };
        let idle_hook: pipeline::ChipHook = {
            let inner = Arc::clone(&inner);
            let status = Arc::clone(&status);
            let metrics = Arc::clone(&metrics);
            Box::new(move |backend: &mut Backend| {
                if let Backend::PhotonicSim(sim) = backend {
                    let mut inner =
                        inner.lock().unwrap_or_else(PoisonError::into_inner);
                    // probation probe, off the serving path (the member
                    // sees no traffic while failed, so the serving hook
                    // never runs): same instrumentation as the monitor's
                    // in-band probes
                    let res = inner.monitor.probe(sim);
                    let ppm = (res as f64 * 1e6) as u64;
                    metrics.probes.add(1);
                    metrics.probe_residual_ppm.record(ppm.max(1));
                    metrics.last_probe_residual_ppm.set(ppm as i64);
                    status.set_residual_ppm(ppm as i64);
                    if let Some(v) = inner.supervisor.observe(res) {
                        apply_verdict(v, &status, &metrics);
                    }
                }
            })
        };
        (
            FarmMember {
                status,
                shared: Some(Arc::clone(&shared)),
                source: EngineSource::Shared(shared),
                backend: Backend::PhotonicSim(sim),
                hook: Some(hook),
                idle: Some((idle_every, idle_hook)),
            },
            recal_rx,
        )
    }

    /// Static member with no drift machinery: a digital fallback or a
    /// fixed photonic chip.  Health only toggles Healthy/Failed.
    pub fn fixed(engine: Arc<Engine>, backend: Backend) -> FarmMember {
        FarmMember {
            status: ChipStatus::new(None, i64::MAX),
            shared: None,
            source: EngineSource::Fixed(engine),
            backend,
            hook: None,
            idle: None,
        }
    }
}

/// The running farm: the ordinary coordinator front end (submit / shed /
/// classify_all / metrics) over batcher → health router → one pipelined
/// worker per member.  Dropping the farm drains everything in channel
/// order: intake, batcher, router, member pipelines.
pub struct Farm {
    pub coord: Coordinator,
    /// per-member health handles, in member order
    pub status: Vec<Arc<ChipStatus>>,
}

impl Farm {
    pub fn start(
        members: Vec<FarmMember>,
        cfg: FarmConfig,
        metrics: Arc<Metrics>,
    ) -> Farm {
        Farm::start_with_fallback(members, None, cfg, metrics)
    }

    /// [`Farm::start`] plus an optional *digital fallback lane*: a plain
    /// sequential worker ([`crate::coordinator::worker::run`]) over the
    /// given backend factory.  The router degrades to it when no chip
    /// member may take a batch — every member quarantined, or the batch
    /// over its [`pipeline::FARM_RETRY_BUDGET`] — so `completed ==
    /// submitted` holds even under total photonic loss.
    pub fn start_with_fallback(
        members: Vec<FarmMember>,
        fallback: Option<worker::BackendFactory>,
        cfg: FarmConfig,
        metrics: Arc<Metrics>,
    ) -> Farm {
        assert!(!members.is_empty(), "a farm needs at least one member");
        let (tx, rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batcher_handle = worker::spawn_named("cirptc-batcher", {
            let bcfg = cfg.batcher.clone();
            move || batcher::run(rx, batch_tx, bcfg)
        });
        let depth = cfg.member_queue.max(1);
        // the retry loop: member pipelines send failed batches back to
        // the router for redispatch.  Unbounded by design — a bounded
        // channel here could deadlock the router (blocking-send into a
        // full member queue while the member blocks sending a retry);
        // occupancy is still bounded by the farm's in-flight batches.
        let (retry_tx, retry_rx) = mpsc::channel::<(usize, Batch)>();
        let in_flight = Arc::new(AtomicI64::new(0));
        let mut targets = Vec::with_capacity(members.len());
        let mut status = Vec::with_capacity(members.len());
        let mut pipes = Vec::with_capacity(members.len());
        for (i, m) in members.into_iter().enumerate() {
            let FarmMember {
                status: st,
                shared: _,
                source,
                backend,
                hook,
                idle,
            } = m;
            let (mtx, mrx) = mpsc::sync_channel::<Batch>(depth);
            targets.push(router::RouteTarget {
                tx: mtx,
                status: Arc::clone(&st),
            });
            status.push(st);
            let mrx = Arc::new(Mutex::new(mrx));
            let metrics = Arc::clone(&metrics);
            let pcfg = cfg.pipeline.clone();
            let link = pipeline::FarmLink {
                member: i,
                retry_tx: retry_tx.clone(),
                in_flight: Arc::clone(&in_flight),
                deadline: cfg.pass_deadline,
            };
            pipes.push(worker::spawn_named(&format!("cirptc-farm-{i}"), move || {
                let mut staged = Staged::new(source, backend)
                    .with_depth(pcfg.depth)
                    .with_farm_link(link);
                if let Some(h) = hook {
                    staged = staged.with_hook(h);
                }
                if let Some((every, h)) = idle {
                    staged = staged.with_idle(every, h);
                }
                pipeline::run(staged, mrx, metrics);
            }));
        }
        // the member links hold the only retry senders: when the last
        // member pipeline exits, the router's retry receiver disconnects
        drop(retry_tx);
        let (fallback_tx, fallback_handle) = match fallback {
            Some(factory) => {
                let (ftx, frx) = mpsc::sync_channel::<Batch>(depth);
                let frx = Arc::new(Mutex::new(frx));
                let metrics = Arc::clone(&metrics);
                let h = worker::spawn_named("cirptc-farm-fallback", move || {
                    worker::run(factory(), frx, metrics)
                });
                (Some(ftx), Some(h))
            }
            None => (None, None),
        };
        let router_handle = worker::spawn_named("cirptc-farm-router", {
            let metrics = Arc::clone(&metrics);
            let in_flight = Arc::clone(&in_flight);
            move || {
                router::run(
                    batch_rx,
                    retry_rx,
                    targets,
                    fallback_tx,
                    in_flight,
                    metrics,
                )
            }
        });
        // join order must follow the channel cascade: batcher first
        // (drops the router's input), then the router (drops the member
        // queues and the fallback queue), then the member pipelines and
        // the fallback worker
        let mut workers = vec![router_handle];
        workers.extend(pipes);
        if let Some(h) = fallback_handle {
            workers.push(h);
        }
        let coord = Coordinator::assemble(
            tx,
            cfg.batcher.queue_cap,
            metrics,
            batcher_handle,
            workers,
        );
        Farm { coord, status }
    }
}

/// The partitioned engine as a serving backend: one worker drives all N
/// chips of a [`PartitionedEngine`] (the shard passes fan out inside
/// `forward_batch`).  This is how a model too large for one chip's MRR
/// bank serves through the ordinary coordinator or a farm member.
pub struct PartitionedBackend {
    pub part: Arc<PartitionedEngine>,
    pub chips: Vec<Backend>,
}

impl InferenceBackend for PartitionedBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        self.part.forward_batch(imgs, &mut self.chips)
    }

    fn name(&self) -> String {
        format!("farm/partitioned[{}]", self.part.plan.chips)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Bundle;
    use crate::drift::MonitorConfig;
    use crate::onn::Manifest;
    use crate::simulator::ChipDescription;
    use crate::util::rng::Rng;

    fn tiny_engine(seed: u64) -> Engine {
        let manifest = Manifest::parse(
            r#"{
              "dataset": "synth_cxr", "classes": 3,
              "layers": [
                {"kind": "conv", "cin": 1, "cout": 4, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "fc", "cin": 256, "cout": 3, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0}
              ]}"#,
        )
        .unwrap();
        let mut bundle = Bundle::default();
        let mut rng = Rng::new(seed);
        let mut w0 = vec![0.0f32; 3 * 4];
        rng.fill_uniform(&mut w0);
        bundle.insert_f32("layer0.w", &[1, 3, 4], w0);
        bundle.insert_f32("layer0.b", &[4], vec![0.1; 4]);
        let mut w3 = vec![0.0f32; 64 * 4];
        rng.fill_uniform(&mut w3);
        bundle.insert_f32("layer3.w", &[1, 64, 4], w3);
        bundle.insert_f32("layer3.b", &[3], vec![0.0; 3]);
        Engine::from_parts(manifest, &bundle).unwrap()
    }

    fn img(seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut d = vec![0.0f32; 64];
        r.fill_uniform(&mut d);
        Tensor::new(&[1, 8, 8], d)
    }

    #[test]
    fn health_priority_failed_over_recal_over_drifting() {
        let metrics = Arc::new(Metrics::default());
        let shared = DriftShared::new(tiny_engine(3), Arc::clone(&metrics));
        let st = ChipStatus::new(Some(Arc::clone(&shared)), 10_000);
        assert_eq!(st.health(), ChipHealth::Healthy);
        st.set_residual_ppm(10_000);
        assert_eq!(st.health(), ChipHealth::Drifting);
        assert!(st.health().serves());
        assert!(shared.recal_in_flight.try_begin());
        assert_eq!(st.health(), ChipHealth::Recalibrating);
        st.fail();
        assert_eq!(st.health(), ChipHealth::Failed);
        st.restore();
        assert_eq!(st.health(), ChipHealth::Recalibrating);
        // restore dropped the stale residual; a live monitor republishes
        st.set_residual_ppm(10_000);
        shared.recal_in_flight.finish();
        assert_eq!(st.health(), ChipHealth::Drifting);
        st.set_residual_ppm(0);
        assert_eq!(
            st.health(),
            ChipHealth::Healthy,
            "recovery must need no acknowledgment"
        );
    }

    #[test]
    fn restore_clears_stale_residual_and_quarantine_latch() {
        // the bug this pins: restore() used to clear only the kill
        // switch, so a restored member immediately re-read as Drifting
        // off the residual published just before it failed
        let st = ChipStatus::new(None, 10_000);
        st.set_residual_ppm(50_000);
        assert_eq!(st.health(), ChipHealth::Drifting);
        st.fail();
        assert_eq!(st.health(), ChipHealth::Failed);
        st.restore();
        assert_eq!(
            st.health(),
            ChipHealth::Healthy,
            "restored member must not linger in Drifting on a stale residual"
        );
        assert_eq!(st.residual_ppm(), 0);
        // the quarantine latch implies Failed and survives fail()-level
        // toggles, but restore() clears it too
        st.quarantine();
        assert!(st.is_quarantined());
        assert_eq!(st.health(), ChipHealth::Failed);
        st.restore();
        assert!(!st.is_quarantined());
        assert_eq!(st.health(), ChipHealth::Healthy);
    }

    #[test]
    fn fixed_member_health_only_toggles_failed() {
        let m = FarmMember::fixed(Arc::new(tiny_engine(4)), Backend::Digital);
        assert_eq!(m.status.health(), ChipHealth::Healthy);
        m.status.fail();
        assert_eq!(m.status.health(), ChipHealth::Failed);
        m.status.restore();
        assert_eq!(m.status.health(), ChipHealth::Healthy);
    }

    #[test]
    fn farm_of_fixed_members_serves_like_a_coordinator() {
        let oracle = Arc::new(tiny_engine(5));
        let members: Vec<FarmMember> = (0..3)
            .map(|_| FarmMember::fixed(Arc::clone(&oracle), Backend::Digital))
            .collect();
        let metrics = Arc::new(Metrics::default());
        let farm = Farm::start(
            members,
            FarmConfig {
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait_us: 300,
                    queue_cap: 0,
                },
                ..FarmConfig::default()
            },
            metrics,
        );
        let images: Vec<Tensor> = (0..24).map(img).collect();
        let responses = farm.coord.classify_all(&images).unwrap();
        assert_eq!(responses.len(), 24);
        for (im, r) in images.iter().zip(&responses) {
            let want = oracle.forward(im, &mut Backend::Digital).unwrap();
            assert_eq!(r.logits, want, "farm must serve the engine exactly");
        }
        let m = &farm.coord.metrics;
        assert_eq!(m.completed.get(), 24);
        assert_eq!(m.errors.get(), 0);
        assert_eq!(m.queue_depth.get(), 0);
        assert_eq!(m.farm_absorbed.get(), 0);
    }

    #[test]
    fn farm_reroutes_around_a_failed_member_with_zero_drops() {
        let oracle = Arc::new(tiny_engine(6));
        let members: Vec<FarmMember> = (0..3)
            .map(|_| FarmMember::fixed(Arc::clone(&oracle), Backend::Digital))
            .collect();
        let metrics = Arc::new(Metrics::default());
        let farm = Farm::start(
            members,
            FarmConfig {
                batcher: BatcherConfig {
                    max_batch: 2,
                    max_wait_us: 100,
                    queue_cap: 0,
                },
                ..FarmConfig::default()
            },
            metrics,
        );
        farm.status[1].fail();
        let images: Vec<Tensor> = (0..20).map(img).collect();
        let responses = farm.coord.classify_all(&images).unwrap();
        assert_eq!(responses.len(), 20, "no request may be dropped");
        let m = &farm.coord.metrics;
        assert_eq!(m.completed.get(), 20);
        assert_eq!(m.rejected.get(), 0);
        assert_eq!(m.errors.get(), 0);
        assert!(m.farm_rerouted.get() >= 1, "traffic rerouted around chip 1");
        assert!(m.farm_transitions.get() >= 1);
        let s = m.summary();
        assert!(s.contains("farm_rerouted="), "summary: {s}");
    }

    #[test]
    fn partitioned_backend_serves_through_a_coordinator() {
        let oracle = {
            // wide enough to shard: reuse the farm engine fixture shape
            let manifest = Manifest::parse(
                r#"{
                  "dataset": "synth_cxr", "classes": 8,
                  "layers": [
                    {"kind": "conv", "cin": 1, "cout": 16, "k": 3, "pool": 2,
                     "arch": "circ", "l": 4, "act_scale": 4.0},
                    {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                     "arch": "circ", "l": 4, "act_scale": 4.0},
                    {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                     "arch": "circ", "l": 4, "act_scale": 4.0},
                    {"kind": "fc", "cin": 1024, "cout": 8, "k": 3, "pool": 2,
                     "arch": "circ", "l": 4, "act_scale": 4.0}
                  ]}"#,
            )
            .unwrap();
            let mut bundle = Bundle::default();
            let mut rng = Rng::new(77);
            let mut w0 = vec![0.0f32; 4 * 3 * 4];
            rng.fill_uniform(&mut w0);
            bundle.insert_f32("layer0.w", &[4, 3, 4], w0);
            bundle.insert_f32("layer0.b", &[16], vec![0.01; 16]);
            let mut w3 = vec![0.0f32; 2 * 256 * 4];
            rng.fill_uniform(&mut w3);
            bundle.insert_f32("layer3.w", &[2, 256, 4], w3);
            bundle.insert_f32("layer3.b", &[8], vec![0.0; 8]);
            Arc::new(Engine::from_parts(manifest, &bundle).unwrap())
        };
        let plan = PartitionPlan::plan(&oracle.manifest, 2);
        let part =
            Arc::new(PartitionedEngine::new(Arc::clone(&oracle), plan).unwrap());
        let c = Coordinator::start(
            vec![Box::new(move || {
                Box::new(PartitionedBackend {
                    part,
                    chips: vec![Backend::Digital, Backend::Digital],
                }) as Box<dyn InferenceBackend>
            })],
            BatcherConfig { max_batch: 4, max_wait_us: 200, queue_cap: 0 },
        );
        let images: Vec<Tensor> = (0..8).map(img).collect();
        let responses = c.classify_all(&images).unwrap();
        for (im, r) in images.iter().zip(&responses) {
            let want = oracle.forward(im, &mut Backend::Digital).unwrap();
            assert_eq!(r.logits, want, "partitioned serving must be exact");
        }
    }

    #[test]
    fn monitored_member_probes_and_publishes_residual() {
        let metrics = Arc::new(Metrics::default());
        let desc = ChipDescription::ideal(4);
        let sim = ChipSim::deterministic(desc.clone());
        let monitor = DriftMonitor::new(
            MonitorConfig {
                probe_every: 1,
                residual_trigger: f32::INFINITY,
                cooldown_passes: 0,
                ..MonitorConfig::default()
            },
            &desc,
        );
        let (member, recal_rx) = FarmMember::monitored(
            tiny_engine(7),
            sim,
            monitor,
            DEFAULT_DRIFTING_PPM,
            Arc::clone(&metrics),
        );
        drop(recal_rx); // monitor-only member
        let status = Arc::clone(&member.status);
        let farm = Farm::start(
            vec![member],
            FarmConfig {
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait_us: 100,
                    queue_cap: 0,
                },
                ..FarmConfig::default()
            },
            Arc::clone(&metrics),
        );
        let images: Vec<Tensor> = (0..12).map(img).collect();
        farm.coord.classify_all(&images).unwrap();
        assert!(metrics.probes.get() >= 1, "hook must probe");
        assert_eq!(
            status.health(),
            ChipHealth::Healthy,
            "deterministic un-drifted chip stays healthy"
        );
        assert_eq!(metrics.errors.get(), 0);
    }
}
