//! Partition planner: shard a manifest's circulant block-rows across N
//! chips (DESIGN.md §farm).
//!
//! The unit of assignment is a whole **block-row** of a circ layer's
//! P×Q block grid: one block-row is `Q` l×l tiles programmed onto a
//! chip's MRR bank, and — because every BCM multiply path computes its
//! output rows independently per block-row — a chip holding block-rows
//! `[r0, r1)` produces exactly rows `[r0·l, r1·l)` of the layer output.
//! The electronic reduce step is therefore a plain row concatenation in
//! block-row order, which is what keeps an N-chip farm **bit-identical**
//! to the single-chip engine (pinned by `rust/tests/farm_e2e.rs`).
//!
//! Capacity model: [`crate::simulator::ChipDescription::mrr_capacity`]
//! declares how many l×l tiles a chip can hold resident across all
//! weight-stationary circ layers (`0` = unlimited).  A chip's load under
//! a plan is the sum of its shard tile counts over every layer; the
//! planner splits each layer's block-rows contiguously and near-evenly
//! (chip `k` takes rows `[⌊k·P/N⌋, ⌊(k+1)·P/N⌋)`), and
//! [`PartitionPlan::validate`] re-derives the grid from the manifest so
//! a stale or hand-edited plan with dangling block references is
//! refused with attributed diagnostics (the `partition` verify pass).

use crate::onn::{LayerKind, Manifest};
use crate::verify::Diagnostic;

/// The block grid of one circ linear layer, derived from the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerGrid {
    /// manifest layer index
    pub layer: usize,
    /// block-rows (P)
    pub p: usize,
    /// block-cols (Q) — every block-row is Q resident tiles
    pub q: usize,
    /// block order (l)
    pub l: usize,
}

impl LayerGrid {
    /// Total resident tiles of the full layer (P·Q).
    pub fn tiles(&self) -> usize {
        self.p * self.q
    }
}

/// One chip's slice of one layer: block-rows `[row0, row1)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerShard {
    /// manifest layer index (must name a circ linear layer)
    pub layer: usize,
    pub row0: usize,
    pub row1: usize,
    /// block-cols, copied from the grid so a shard is self-describing
    pub q: usize,
}

impl LayerShard {
    pub fn rows(&self) -> usize {
        self.row1 - self.row0
    }

    /// Resident tiles this shard pins on its chip.
    pub fn tiles(&self) -> usize {
        self.rows() * self.q
    }
}

/// A full farm partition: which block-rows of which layers live on which
/// chip.  `assignments[k]` lists chip `k`'s shards in layer order; a
/// chip may hold zero rows of a layer (narrow layers on wide farms).
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    pub chips: usize,
    /// the circ-layer grids the plan was derived from, in layer order
    pub grids: Vec<LayerGrid>,
    /// per-chip shard lists, `assignments.len() == chips`
    pub assignments: Vec<Vec<LayerShard>>,
}

/// The circ linear layers of a manifest as block grids, in layer order.
pub fn circ_grids(manifest: &Manifest) -> Vec<LayerGrid> {
    manifest
        .layers
        .iter()
        .enumerate()
        .filter(|(_, s)| {
            matches!(s.kind, LayerKind::Conv | LayerKind::Fc) && s.arch == "circ"
        })
        .map(|(i, s)| {
            let (p, q) = s.bcm_dims();
            LayerGrid { layer: i, p, q, l: s.l }
        })
        .collect()
}

/// Total resident tiles a single chip would need for the whole model.
pub fn tile_demand(manifest: &Manifest) -> usize {
    circ_grids(manifest).iter().map(LayerGrid::tiles).sum()
}

impl PartitionPlan {
    /// Balanced contiguous split: for every circ layer, chip `k` takes
    /// block-rows `[⌊k·P/N⌋, ⌊(k+1)·P/N⌋)`.  Deterministic, covers every
    /// row exactly once, and keeps each chip's shard contiguous so the
    /// reduce step is a straight row concatenation.
    pub fn plan(manifest: &Manifest, chips: usize) -> PartitionPlan {
        assert!(chips >= 1, "a farm has at least one chip");
        let grids = circ_grids(manifest);
        let assignments = (0..chips)
            .map(|k| {
                grids
                    .iter()
                    .map(|g| LayerShard {
                        layer: g.layer,
                        row0: k * g.p / chips,
                        row1: (k + 1) * g.p / chips,
                        q: g.q,
                    })
                    .collect()
            })
            .collect();
        PartitionPlan { chips, grids, assignments }
    }

    /// Resident tiles chip `k` holds under this plan.
    pub fn chip_tiles(&self, k: usize) -> usize {
        self.assignments[k].iter().map(LayerShard::tiles).sum()
    }

    /// The most-loaded chip's resident tile count.
    pub fn max_chip_tiles(&self) -> usize {
        (0..self.chips).map(|k| self.chip_tiles(k)).max().unwrap_or(0)
    }

    /// Does every chip fit a bank of `capacity` tiles (`0` = unlimited)?
    pub fn fits(&self, capacity: usize) -> bool {
        capacity == 0 || self.max_chip_tiles() <= capacity
    }

    /// Smallest farm width whose balanced plan fits `capacity`, or `None`
    /// when no block-row split can (some layer's single block-row — `Q`
    /// tiles — already exceeds the bank).  `capacity == 0` → 1 chip.
    pub fn required_chips(manifest: &Manifest, capacity: usize) -> Option<usize> {
        if capacity == 0 {
            return Some(1);
        }
        let grids = circ_grids(manifest);
        if grids.iter().any(|g| g.p >= 1 && g.q > capacity) {
            return None;
        }
        let total_rows: usize = grids.iter().map(|g| g.p).sum();
        for n in 1..=total_rows.max(1) {
            if PartitionPlan::plan(manifest, n).fits(capacity) {
                return Some(n);
            }
        }
        None
    }

    /// Structural validation against the manifest: the grids must match a
    /// fresh derivation (a stale plan is refused), every shard must
    /// reference an existing circ layer with in-range block-rows (no
    /// dangling block refs), and per layer the shards must tile `[0, P)`
    /// exactly — no gaps, no overlaps.  Returns attributed diagnostics
    /// under the `partition` pass; empty means sound.
    pub fn validate(&self, manifest: &Manifest) -> Vec<Diagnostic> {
        let mut out = Vec::new();
        let fresh = circ_grids(manifest);
        if self.grids != fresh {
            out.push(diag(
                None,
                "grids",
                format!("{} circ layer grids from the manifest", fresh.len()),
                format!("{} stored grids", self.grids.len()),
                "plan was derived from a different manifest",
            ));
            return out;
        }
        if self.assignments.len() != self.chips {
            out.push(diag(
                None,
                "assignments",
                format!("{} chip shard lists", self.chips),
                format!("{}", self.assignments.len()),
                "one shard list per chip",
            ));
            return out;
        }
        for g in &self.grids {
            // collect this layer's shards across chips, in row order
            let mut spans: Vec<(usize, usize)> = Vec::new();
            for shards in &self.assignments {
                for s in shards.iter().filter(|s| s.layer == g.layer) {
                    if s.row0 > s.row1 || s.row1 > g.p {
                        out.push(diag(
                            Some(g.layer),
                            "shard.rows",
                            format!("block-rows within [0, {}]", g.p),
                            format!("[{}, {})", s.row0, s.row1),
                            "dangling block-row reference",
                        ));
                        return out;
                    }
                    if s.q != g.q {
                        out.push(diag(
                            Some(g.layer),
                            "shard.q",
                            format!("{}", g.q),
                            format!("{}", s.q),
                            "shard width disagrees with the layer grid",
                        ));
                    }
                    if s.rows() > 0 {
                        spans.push((s.row0, s.row1));
                    }
                }
            }
            spans.sort_unstable();
            let mut next = 0usize;
            for (r0, r1) in &spans {
                if *r0 != next {
                    out.push(diag(
                        Some(g.layer),
                        "coverage",
                        format!("block-row {next} covered exactly once"),
                        if *r0 > next {
                            format!("gap [{next}, {r0})")
                        } else {
                            format!("overlap at {r0}")
                        },
                        "shards must tile [0, P) exactly",
                    ));
                    return out;
                }
                next = *r1;
            }
            if next != g.p {
                out.push(diag(
                    Some(g.layer),
                    "coverage",
                    format!("{} block-rows covered", g.p),
                    format!("{next}"),
                    "shards must tile [0, P) exactly",
                ));
            }
        }
        // a shard naming a layer with no grid is dangling
        for shards in &self.assignments {
            for s in shards {
                if !self.grids.iter().any(|g| g.layer == s.layer) {
                    out.push(diag(
                        Some(s.layer),
                        "shard.layer",
                        "a circ linear layer",
                        format!("layer {}", s.layer),
                        "dangling layer reference",
                    ));
                }
            }
        }
        out
    }

    /// Capacity validation: every chip's resident tiles must fit a bank
    /// of `capacity` tiles (`0` = unlimited → always empty).
    pub fn capacity_diags(&self, capacity: usize) -> Vec<Diagnostic> {
        let mut out = Vec::new();
        if capacity == 0 {
            return out;
        }
        for k in 0..self.chips {
            let t = self.chip_tiles(k);
            if t > capacity {
                out.push(diag(
                    None,
                    format!("chip{k}.mrr_capacity"),
                    format!("≤ {capacity} resident tiles"),
                    format!("{t}"),
                    "partition exceeds the chip's declared MRR bank",
                ));
            }
        }
        out
    }
}

fn diag(
    layer: Option<usize>,
    field: impl Into<String>,
    expected: impl Into<String>,
    found: String,
    message: &str,
) -> Diagnostic {
    Diagnostic {
        pass: "partition",
        layer,
        field: field.into(),
        expected: expected.into(),
        found,
        message: message.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        // conv: cout 16 / l 4 -> P=4, n_in 1·3·3=9 -> Q=3 (12 tiles/row-4)
        // fc: cout 8 / l 4 -> P=2, cin 64 -> Q=16
        Manifest::parse(
            r#"{
              "dataset": "synth_cxr", "classes": 8,
              "layers": [
                {"kind": "conv", "cin": 1, "cout": 16, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "fc", "cin": 64, "cout": 8, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0}
              ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn grids_and_demand() {
        let m = manifest();
        let g = circ_grids(&m);
        assert_eq!(g.len(), 2);
        assert_eq!((g[0].p, g[0].q, g[0].layer), (4, 3, 0));
        assert_eq!((g[1].p, g[1].q, g[1].layer), (2, 16, 2));
        assert_eq!(tile_demand(&m), 4 * 3 + 2 * 16);
    }

    #[test]
    fn plan_tiles_rows_exactly_for_any_width() {
        let m = manifest();
        for n in 1..=7 {
            let plan = PartitionPlan::plan(&m, n);
            assert!(plan.validate(&m).is_empty(), "n={n}");
            let total: usize = (0..n).map(|k| plan.chip_tiles(k)).sum();
            assert_eq!(total, tile_demand(&m), "n={n}: no tile lost or doubled");
        }
    }

    #[test]
    fn balanced_split_is_near_even() {
        let plan = PartitionPlan::plan(&manifest(), 2);
        // conv P=4 → 2+2 rows, fc P=2 → 1+1: both chips carry 6+16 tiles
        assert_eq!(plan.chip_tiles(0), 2 * 3 + 16);
        assert_eq!(plan.chip_tiles(1), 2 * 3 + 16);
    }

    #[test]
    fn required_chips_walks_up_and_detects_infeasible() {
        let m = manifest();
        assert_eq!(PartitionPlan::required_chips(&m, 0), Some(1));
        assert_eq!(PartitionPlan::required_chips(&m, 1000), Some(1));
        // demand is 44; half of it forces a 2-chip farm
        assert_eq!(PartitionPlan::required_chips(&m, 22), Some(2));
        // 19 tiles: a chip can hold one fc row (16) + one conv row (3),
        // which the balanced split first achieves at 4 chips
        assert_eq!(PartitionPlan::required_chips(&m, 19), Some(4));
        assert!(PartitionPlan::plan(&m, 4).fits(19));
        assert!(!PartitionPlan::plan(&m, 3).fits(19));
        // one fc block-row is 16 tiles: a 15-tile bank can never fit
        assert_eq!(PartitionPlan::required_chips(&m, 15), None);
    }

    #[test]
    fn validate_rejects_dangling_and_overlapping_shards() {
        let m = manifest();
        let mut plan = PartitionPlan::plan(&m, 2);
        plan.assignments[1][0].row1 = 9; // past conv P=4
        let d = &plan.validate(&m)[0];
        assert_eq!(d.pass, "partition");
        assert!(d.message.contains("dangling"), "{}", d.render());

        let mut plan = PartitionPlan::plan(&m, 2);
        plan.assignments[1][0].row0 = 1; // overlaps chip 0's [0, 2)
        assert!(!plan.validate(&m).is_empty());

        let mut plan = PartitionPlan::plan(&m, 2);
        plan.assignments[1][1].row1 = 1; // fc rows [1, 2) dropped
        let d = &plan.validate(&m)[0];
        assert_eq!(d.layer, Some(2));
        assert!(d.found.contains('1'), "{}", d.render());
    }

    #[test]
    fn capacity_diags_name_the_overloaded_chip() {
        let plan = PartitionPlan::plan(&manifest(), 2);
        assert!(plan.capacity_diags(0).is_empty());
        assert!(plan.capacity_diags(22).is_empty());
        let d = plan.capacity_diags(21);
        assert_eq!(d.len(), 2, "both chips hold 22 tiles");
        assert!(d[0].field.contains("chip0"));
        assert!(d[0].message.contains("MRR bank"));
    }
}
