//! Partitioned execution: one logical engine spread over N chips
//! (DESIGN.md §farm).
//!
//! A [`PartitionedEngine`] wraps a normal [`Engine`] plus a
//! [`PartitionPlan`] and executes each circ linear layer as N concurrent
//! **row-shard passes** followed by an electronic reduce:
//!
//! 1. the *shared* operand prep ([`Engine::pre_batch`] /
//!    `Engine::prep_linear`) packs one operand for the whole layer —
//!    every chip multiplies the same columns;
//! 2. chip `k` runs its block-row shard (sliced weights + sliced sign
//!    split from [`LinearPlan::shard_of`]) and writes rows
//!    `[r0·l, r1·l)` of the output — disjoint slices of one buffer, so
//!    the reduce is the write itself (a row concatenation);
//! 3. the shared tail (reshape + bias, [`Engine::post_batch`]) finishes
//!    the layer exactly as the single-chip path would.
//!
//! Because each shard keeps the layer's full Q extent, the parent sign
//! split's *global* rescale, and the same per-block-row inner-loop
//! order, the N-chip result is **bit-identical** to the single-chip
//! engine on deterministic backends — any N, digital or photonic
//! (propchecked in `rust/tests/farm_e2e.rs`).  Electronic (non-linear)
//! layers and the pre/post stages run once, on the front end, not per
//! chip.

use crate::bail;
use crate::onn::engine::{
    Activation, LinearPrep, MidState, PreState, PrepShape,
};
use crate::onn::plan::next_tile_owner;
use crate::onn::{Backend, Engine, LayerKind, MidBatch};
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::scratch;
use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::Arc;
use crate::util::threadpool::spawn_scoped_named;

use super::partition::PartitionPlan;

/// One chip's resident slice of one layer: the sliced weights and the
/// sliced-sign plan produced by [`LinearPlan::shard_of`]
/// (`crate::onn::plan`), plus where its output rows land.
struct ChipShard {
    chip: usize,
    /// first block-row (output rows start at `r0·l`)
    r0: usize,
    bcm: crate::circulant::Bcm,
    plan: crate::onn::plan::LinearPlan,
}

/// A logical engine partitioned across `plan.chips` physical chips.
pub struct PartitionedEngine {
    pub engine: Arc<Engine>,
    pub plan: PartitionPlan,
    /// per-chip tile-cache owner ids: chip `k` caches its shard tiles
    /// under `owners[k]`, so farm members never collide in a sim's
    /// encode cache even when two farms share a chip
    owners: Vec<u64>,
    /// per manifest layer, the non-empty shards sorted by `r0`
    layer_shards: Vec<Vec<ChipShard>>,
}

impl PartitionedEngine {
    /// Build the per-chip shard state for `plan` over `engine`'s weights.
    /// The plan is re-validated against the manifest (coverage, no
    /// dangling block refs) — a broken plan is refused here, not
    /// discovered as a garbled logit downstream.
    pub fn new(engine: Arc<Engine>, plan: PartitionPlan) -> Result<PartitionedEngine> {
        let diags = plan.validate(&engine.manifest);
        if let Some(d) = diags.first() {
            bail!("invalid partition plan: {}", d.render());
        }
        for (idx, spec) in engine.manifest.layers.iter().enumerate() {
            if matches!(spec.kind, LayerKind::Conv | LayerKind::Fc)
                && spec.arch != "circ"
            {
                bail!(
                    "layer {idx}: farm partitioning requires circ arch \
                     (gemm layers have no block-rows to shard)"
                );
            }
        }
        let mut layer_shards: Vec<Vec<ChipShard>> =
            (0..engine.manifest.layers.len()).map(|_| Vec::new()).collect();
        for (chip, shards) in plan.assignments.iter().enumerate() {
            for s in shards.iter().filter(|s| s.rows() > 0) {
                let (bcm, lp) = engine.linear_plan(s.layer)?;
                let (sbcm, splan) = lp.shard_of(bcm, s.row0, s.row1);
                layer_shards[s.layer].push(ChipShard {
                    chip,
                    r0: s.row0,
                    bcm: sbcm,
                    plan: splan,
                });
            }
        }
        for shards in &mut layer_shards {
            shards.sort_by_key(|s| s.r0);
        }
        let owners = (0..plan.chips).map(|_| next_tile_owner()).collect();
        Ok(PartitionedEngine { engine, plan, owners, layer_shards })
    }

    /// Forward a batch through the farm: shared pre stage, each linear
    /// layer as N concurrent row-shard passes + electronic reduce,
    /// shared post stage.  `backends[k]` is chip `k`; the set must be
    /// homogeneous (all digital or all photonic) because operand packing
    /// differs between the two paths.
    pub fn forward_batch(
        &self,
        imgs: &[Tensor],
        backends: &mut [Backend],
    ) -> Result<Vec<Vec<f32>>> {
        if backends.len() != self.plan.chips {
            bail!(
                "partition plan wants {} chips, got {} backends",
                self.plan.chips,
                backends.len()
            );
        }
        let photonic =
            matches!(backends.first(), Some(Backend::PhotonicSim(_)));
        if backends
            .iter()
            .any(|b| matches!(b, Backend::PhotonicSim(_)) != photonic)
        {
            bail!("farm backends must be homogeneous (all digital or all photonic)");
        }
        let e = &*self.engine;
        let pre = e.pre_batch(imgs, photonic, None)?;
        let (mut act, mut next) = match pre.state {
            PreState::Empty => return Ok(Vec::new()),
            PreState::Plain { act, next } => (act, next),
            PreState::Prepped { prep } => {
                let idx = prep.idx;
                (self.finish_sharded(prep, backends)?, idx + 1)
            }
        };
        let stop = e.last_linear().map(|i| i + 1).unwrap_or(next).max(next);
        while next < stop {
            let spec = &e.manifest.layers[next];
            act = match spec.kind {
                LayerKind::Conv | LayerKind::Fc => {
                    let prep =
                        e.prep_linear(next, spec, act, photonic, None)?;
                    self.finish_sharded(prep, backends)?
                }
                _ => e.run_electronic_layer(next, spec, act)?,
            };
            next += 1;
        }
        e.post_batch(MidBatch { state: MidState::Act { act, next } })
    }

    /// Execute one linear layer's packed operand as row-shard passes on
    /// the farm and reduce into the full (P·l, b) output.  The farm twin
    /// of `Engine::finish_linear`; the reshape + bias tail is identical.
    fn finish_sharded(
        &self,
        prep: LinearPrep,
        backends: &mut [Backend],
    ) -> Result<Activation> {
        let LinearPrep { idx, photonic: _, xp, enc, shape } = prep;
        if let Some(enc) = enc {
            // farm prep never pre-encodes (each chip has its own encode
            // generation); recycle defensively if a caller passed one
            enc.recycle();
        }
        let e = &*self.engine;
        let spec = &e.manifest.layers[idx];
        let (bcm, _) = e.linear_plan(idx)?;
        let b = xp.shape[1];
        let m = bcm.m();
        let mut y = Tensor::new(&[m, b], scratch::take(m * b));
        let shards = &self.layer_shards[idx];
        // chip index of the first shard whose photonic readout came back
        // non-finite (NaN/Inf readout fault); usize::MAX means clean
        let poisoned = AtomicUsize::new(usize::MAX);
        {
            // pair each shard with its disjoint row-slice of the output;
            // shard order is ascending r0 and validate() guaranteed an
            // exact tiling of [0, P), so the split walks the buffer once
            let mut parts: Vec<(&ChipShard, &mut [f32])> = Vec::new();
            let mut rest: &mut [f32] = &mut y.data;
            for sh in shards {
                let len = sh.bcm.m() * b;
                let (head, tail) = rest.split_at_mut(len);
                rest = tail;
                parts.push((sh, head));
            }
            // attach chip backends (shards ascend in chip order too —
            // contiguous row ranges are assigned to increasing chips)
            let mut jobs: Vec<(&ChipShard, &mut Backend, &mut [f32])> =
                Vec::new();
            let mut bes = backends.iter_mut().enumerate();
            for (sh, out) in parts {
                let be = loop {
                    match bes.next() {
                        Some((i, be)) if i == sh.chip => break be,
                        Some(_) => continue,
                        None => bail!(
                            "layer {idx}: shard for chip {} has no backend",
                            sh.chip
                        ),
                    }
                };
                jobs.push((sh, be, out));
            }
            let threads = (e.threads / jobs.len().max(1)).max(1);
            let use_plans = e.use_plans;
            let scale = spec.act_scale;
            let owners = &self.owners;
            let xref = &xp;
            let run = |sh: &ChipShard, be: &mut Backend, out: &mut [f32]| {
                let span = crate::obs::trace::begin();
                match be {
                    Backend::Digital => {
                        let yk = if use_plans {
                            sh.plan.multiply(&sh.bcm, xref, threads)
                        } else {
                            sh.plan.multiply_reference(&sh.bcm, xref)
                        };
                        out.copy_from_slice(&yk.data);
                        scratch::put(yk.data);
                    }
                    Backend::PhotonicSim(sim) => {
                        sim.threads = threads;
                        let mut yk = sim.forward_signed_planned(
                            owners[sh.chip],
                            idx,
                            &sh.plan.sign,
                            xref,
                        );
                        for v in yk.data.iter_mut() {
                            *v *= scale;
                        }
                        // a NaN/Inf readout (e.g. an injected
                        // `FaultKind::NaNReadout` episode) must surface
                        // as a fault verdict, never as a garbled logit:
                        // record the chip and let the reduce tail bail
                        if yk.data.iter().any(|v| !v.is_finite()) {
                            sim.note_fault();
                            let _ = poisoned.compare_exchange(
                                usize::MAX,
                                sh.chip,
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                            );
                        }
                        out.copy_from_slice(&yk.data);
                        scratch::put(yk.data);
                    }
                }
                crate::obs::trace::end(
                    span,
                    "shard_pass",
                    "farm",
                    [("chip", sh.chip as i64), ("rows", sh.bcm.m() as i64)],
                );
            };
            if jobs.len() <= 1 {
                for (sh, be, out) in jobs {
                    run(sh, be, out);
                }
            } else {
                let run = &run;
                std::thread::scope(|s| {
                    for (sh, be, out) in jobs {
                        spawn_scoped_named(s, "cirptc-farm-shard", move || {
                            run(sh, be, out)
                        });
                    }
                });
            }
        }
        scratch::put(xp.data);
        let bad = poisoned.load(Ordering::Relaxed);
        if bad != usize::MAX {
            scratch::put(y.data);
            bail!(
                "layer {idx}: chip {bad} produced a non-finite shard \
                 readout (treated as a detectable pass fault)"
            );
        }
        // shared electronic reduce tail — identical to finish_linear
        let bias = e.linear_bias(idx)?;
        match shape {
            PrepShape::Conv { b, h, w } => {
                let out = crate::onn::engine::cols_to_images(
                    &y, b, spec.cout, h, w,
                );
                scratch::put(y.data);
                Ok(Activation::Image(
                    crate::onn::engine::add_channel_bias_batch(out, bias),
                ))
            }
            PrepShape::Fc { b } => {
                let m = spec.cout.min(y.shape[0]);
                let mut out = Tensor::zeros(&[b, m]);
                for bi in 0..b {
                    for r in 0..m {
                        out.data[bi * m + r] = y.at2(r, bi)
                            + bias.get(r).copied().unwrap_or(0.0);
                    }
                }
                scratch::put(y.data);
                Ok(Activation::Matrix(out))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Bundle;
    use crate::onn::Manifest;
    use crate::simulator::{ChipDescription, ChipSim};
    use crate::util::rng::Rng;

    /// 4-block-row conv + 2-block-row fc model — wide enough that every
    /// farm width in {1, 2, 3} shards at least one layer non-trivially.
    fn wide_engine() -> Arc<Engine> {
        let manifest = Manifest::parse(
            r#"{
              "dataset": "synth_cxr", "classes": 8,
              "layers": [
                {"kind": "conv", "cin": 1, "cout": 16, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "fc", "cin": 256, "cout": 8, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0}
              ]}"#,
        )
        .unwrap();
        let mut bundle = Bundle::default();
        let mut rng = Rng::new(4242);
        // conv: P=4, Q=3
        let mut w0 = vec![0.0f32; 4 * 3 * 4];
        rng.fill_uniform(&mut w0);
        for v in w0.iter_mut() {
            *v = (*v - 0.5) * 0.5;
        }
        bundle.insert_f32("layer0.w", &[4, 3, 4], w0);
        bundle.insert_f32("layer0.b", &[16], vec![0.01; 16]);
        // fc: P=2, Q=64
        let mut w4 = vec![0.0f32; 2 * 64 * 4];
        rng.fill_uniform(&mut w4);
        for v in w4.iter_mut() {
            *v = (*v - 0.5) * 0.2;
        }
        bundle.insert_f32("layer4.w", &[2, 64, 4], w4);
        bundle.insert_f32("layer4.b", &[8], vec![0.1; 8]);
        Arc::new(Engine::from_parts(manifest, &bundle).unwrap())
    }

    fn inputs(n: usize) -> Vec<Tensor> {
        (0..n)
            .map(|i| {
                let mut rng = Rng::new(900 + i as u64);
                let mut d = vec![0.0f32; 8 * 8];
                rng.fill_uniform(&mut d);
                Tensor::new(&[1, 8, 8], d)
            })
            .collect()
    }

    fn nonideal() -> ChipDescription {
        let mut d = ChipDescription::ideal(4);
        d.w_bits = 6;
        d.x_bits = 4;
        d.dark = 0.015;
        d
    }

    #[test]
    fn digital_farm_matches_single_chip_bitwise() {
        let e = wide_engine();
        let imgs = inputs(5);
        let want =
            e.forward_batch(&imgs, &mut Backend::Digital).unwrap();
        for n in [1usize, 2, 3] {
            let plan = PartitionPlan::plan(&e.manifest, n);
            let part = PartitionedEngine::new(e.clone(), plan).unwrap();
            let mut chips: Vec<Backend> =
                (0..n).map(|_| Backend::Digital).collect();
            let got = part.forward_batch(&imgs, &mut chips).unwrap();
            assert_eq!(got, want, "n={n} digital farm must be bit-identical");
        }
    }

    #[test]
    fn photonic_farm_matches_single_chip_bitwise() {
        let e = wide_engine();
        let imgs = inputs(4);
        let want = e
            .forward_batch(
                &imgs,
                &mut Backend::PhotonicSim(ChipSim::deterministic(nonideal())),
            )
            .unwrap();
        for n in [1usize, 2, 4] {
            let plan = PartitionPlan::plan(&e.manifest, n);
            let part = PartitionedEngine::new(e.clone(), plan).unwrap();
            let mut chips: Vec<Backend> = (0..n)
                .map(|_| {
                    Backend::PhotonicSim(ChipSim::deterministic(nonideal()))
                })
                .collect();
            let got = part.forward_batch(&imgs, &mut chips).unwrap();
            assert_eq!(got, want, "n={n} photonic farm must be bit-identical");
        }
    }

    #[test]
    fn farm_rejects_mixed_backends_and_wrong_width() {
        let e = wide_engine();
        let plan = PartitionPlan::plan(&e.manifest, 2);
        let part = PartitionedEngine::new(e, plan).unwrap();
        let imgs = inputs(1);
        let mut mixed = vec![
            Backend::Digital,
            Backend::PhotonicSim(ChipSim::deterministic(nonideal())),
        ];
        assert!(part.forward_batch(&imgs, &mut mixed).is_err());
        let mut narrow = vec![Backend::Digital];
        assert!(part.forward_batch(&imgs, &mut narrow).is_err());
    }

    #[test]
    fn non_finite_shard_readout_is_a_fault_not_a_garbled_logit() {
        use crate::fault::{Episode, FaultKind, FaultPlan};
        let e = wide_engine();
        let plan = PartitionPlan::plan(&e.manifest, 2);
        let part = PartitionedEngine::new(e, plan).unwrap();
        let imgs = inputs(2);
        let mut sick = ChipSim::deterministic(nonideal());
        sick.set_fault(FaultPlan::new(
            7,
            vec![Episode {
                start_pass: 0,
                duration: u64::MAX / 2,
                kind: FaultKind::NaNReadout,
            }],
        ));
        let mut chips = vec![
            Backend::PhotonicSim(sick),
            Backend::PhotonicSim(ChipSim::deterministic(nonideal())),
        ];
        let err = part.forward_batch(&imgs, &mut chips).unwrap_err();
        assert!(
            format!("{err}").contains("non-finite"),
            "NaN readout must bail, got: {err}"
        );
    }

    #[test]
    fn empty_batch_flows_to_empty_logits() {
        let e = wide_engine();
        let plan = PartitionPlan::plan(&e.manifest, 2);
        let part = PartitionedEngine::new(e, plan).unwrap();
        let mut chips = vec![Backend::Digital, Backend::Digital];
        assert!(part.forward_batch(&[], &mut chips).unwrap().is_empty());
    }
}
