//! Health-aware batch router: the farm's failover stage between the
//! dynamic batcher and the per-chip pipelines (DESIGN.md §farm).
//!
//! The router owns the batcher's output and one **bounded**
//! `sync_channel` per farm member, so a slow or wedged chip exerts
//! backpressure toward admission control instead of queueing batches
//! without bound (`repo_lint`'s stage-buffer-bounded rule covers this
//! file).  Per batch it reads every member's live
//! [`ChipHealth`](super::ChipHealth) and dispatches by preference:
//!
//! 1. round-robin over serving-capable members (`Healthy` / `Drifting`);
//! 2. a `Recalibrating` member if nothing healthier is routable — its
//!    pipeline still serves on the old engine while the background
//!    recalibration runs, it is just a worse operating point
//!    (`farm_absorbed` counts these);
//! 3. a `Failed` member only when *every* member has failed — zero-drop
//!    beats refusing, and the operator sees it in the health states.
//!
//! A batch that lands anywhere other than the round-robin's natural next
//! member counts in `farm_rerouted`; observed health-state edges count
//! in `farm_transitions`.  Members whose pipeline is gone (teardown
//! race) are skipped; only when no member can take the batch at all are
//! its requests accounted as errors, so the submitted/completed/errors
//! conservation the coordinator tests pin still holds.

use crate::obs::trace;
use crate::util::sync::{mpsc, Arc};

use crate::coordinator::{Batch, Metrics};

use super::{ChipHealth, ChipStatus};

/// One routable farm member: its bounded batch queue and health handle.
pub(crate) struct RouteTarget {
    pub tx: mpsc::SyncSender<Batch>,
    pub status: Arc<ChipStatus>,
}

/// Router loop body (runs on its own thread).  Exits when the batcher's
/// sender closes; dropping the member senders then cascades shutdown
/// into the per-chip pipelines.
pub(crate) fn run(
    rx: mpsc::Receiver<Batch>,
    targets: Vec<RouteTarget>,
    metrics: Arc<Metrics>,
) {
    let n = targets.len();
    let mut cursor = 0usize;
    // transition edges count from the farm's documented starting state
    // (every member Healthy), not from a racy first observation
    let mut last: Vec<ChipHealth> = vec![ChipHealth::Healthy; n];
    while let Ok(batch) = rx.recv() {
        if n == 0 {
            // a farm always has ≥1 member (Farm::start asserts); this
            // arm only keeps accounting sound if that ever changes
            metrics.queue_depth.sub(batch.requests.len() as i64);
            metrics.errors.add(batch.requests.len());
            continue;
        }
        // observe health once per batch; count every state edge
        let health: Vec<ChipHealth> =
            targets.iter().map(|t| t.status.health()).collect();
        for (i, (h, l)) in health.iter().zip(last.iter_mut()).enumerate() {
            if h != l {
                metrics.farm_transitions.add(1);
                trace::instant(
                    "health",
                    "farm",
                    [("chip", i as i64), ("state", h.code())],
                );
                *l = *h;
            }
        }
        // preference order from the round-robin cursor: serving-capable
        // members first, then recalibrating, failed only as last resort
        let mut order: Vec<usize> = Vec::with_capacity(n);
        let mut absorbing = false;
        for pass in 0..3 {
            for k in 0..n {
                let i = (cursor + k) % n;
                let take = match pass {
                    0 => health[i].serves(),
                    1 => health[i] == ChipHealth::Recalibrating,
                    _ => health[i] == ChipHealth::Failed,
                };
                if take {
                    order.push(i);
                }
            }
            if pass == 0 {
                absorbing = order.is_empty();
            }
        }

        let natural = cursor % n;
        let mut pending = Some(batch);
        let mut routed = None;
        // first pass: first member in preference order with queue space
        // — a busy chip must not stall traffic a healthy sibling could
        // take right now
        for &i in &order {
            let Some(b) = pending.take() else { break };
            match targets[i].tx.try_send(b) {
                Ok(()) => {
                    routed = Some(i);
                    break;
                }
                Err(mpsc::TrySendError::Full(b))
                | Err(mpsc::TrySendError::Disconnected(b)) => pending = Some(b),
            }
        }
        // every queue full: block on the most-preferred live member, so
        // the backpressure reaches admission control at the intake queue
        if routed.is_none() {
            for &i in &order {
                let Some(b) = pending.take() else { break };
                match targets[i].tx.send(b) {
                    Ok(()) => {
                        routed = Some(i);
                        break;
                    }
                    Err(mpsc::SendError(b)) => pending = Some(b),
                }
            }
        }
        match routed {
            Some(i) => {
                trace::instant(
                    "route",
                    "farm",
                    [("chip", i as i64), ("rerouted", (i != natural) as i64)],
                );
                if i != natural {
                    metrics.farm_rerouted.add(1);
                }
                if absorbing {
                    metrics.farm_absorbed.add(1);
                }
                cursor = i + 1;
            }
            None => {
                // every member pipeline is gone (teardown race): account
                // the requests as errors so conservation holds
                if let Some(b) = pending {
                    metrics.queue_depth.sub(b.requests.len() as i64);
                    metrics.errors.add(b.requests.len());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;
    use crate::tensor::Tensor;
    use std::time::{Duration, Instant};

    fn batch(ids: &[u64]) -> Batch {
        Batch {
            requests: ids
                .iter()
                .map(|&id| {
                    // reply target irrelevant here: the router never
                    // answers requests, it only moves batches
                    let (reply, _rx) = mpsc::channel();
                    Request {
                        id,
                        image: Tensor::zeros(&[1, 2, 2]),
                        enqueued: Instant::now(),
                        reply,
                    }
                })
                .collect(),
            formed: Instant::now(),
        }
    }

    struct Farmlet {
        tx: mpsc::Sender<Batch>,
        rxs: Vec<mpsc::Receiver<Batch>>,
        status: Vec<Arc<ChipStatus>>,
        metrics: Arc<Metrics>,
        _h: std::thread::JoinHandle<()>,
    }

    fn farmlet(n: usize) -> Farmlet {
        let (tx, rx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let mut rxs = Vec::new();
        let mut status = Vec::new();
        let mut targets = Vec::new();
        for _ in 0..n {
            let (mtx, mrx) = mpsc::sync_channel::<Batch>(4);
            let st = ChipStatus::new(None, 10_000);
            targets.push(RouteTarget { tx: mtx, status: Arc::clone(&st) });
            rxs.push(mrx);
            status.push(st);
        }
        let m = Arc::clone(&metrics);
        let _h = std::thread::spawn(move || run(rx, targets, m));
        Farmlet { tx, rxs, status, metrics, _h }
    }

    fn recv(rx: &mpsc::Receiver<Batch>) -> Option<Batch> {
        rx.recv_timeout(Duration::from_secs(2)).ok()
    }

    #[test]
    fn round_robins_over_healthy_members() {
        let f = farmlet(3);
        for i in 0..6 {
            f.tx.send(batch(&[i])).unwrap();
        }
        for k in 0..3 {
            // member k gets batches k and k+3, in order
            let a = recv(&f.rxs[k]).unwrap();
            let b = recv(&f.rxs[k]).unwrap();
            assert_eq!(a.requests[0].id, k as u64);
            assert_eq!(b.requests[0].id, (k + 3) as u64);
        }
        assert_eq!(f.metrics.farm_rerouted.get(), 0);
        assert_eq!(f.metrics.farm_absorbed.get(), 0);
    }

    #[test]
    fn failed_member_is_skipped_and_counted() {
        let f = farmlet(3);
        f.status[1].fail();
        for i in 0..4 {
            f.tx.send(batch(&[i])).unwrap();
        }
        // member 1 never serves; 0 and 2 alternate
        assert_eq!(recv(&f.rxs[0]).unwrap().requests[0].id, 0);
        assert_eq!(recv(&f.rxs[2]).unwrap().requests[0].id, 1);
        assert_eq!(recv(&f.rxs[0]).unwrap().requests[0].id, 2);
        assert_eq!(recv(&f.rxs[2]).unwrap().requests[0].id, 3);
        assert!(
            f.rxs[1].recv_timeout(Duration::from_millis(50)).is_err(),
            "a failed chip must not receive traffic"
        );
        // one transition edge (Healthy → Failed), and every batch whose
        // natural round-robin slot was the dead member rerouted
        assert_eq!(f.metrics.farm_transitions.get(), 1);
        assert!(f.metrics.farm_rerouted.get() >= 1);
        assert_eq!(f.metrics.farm_absorbed.get(), 0);
        assert_eq!(f.metrics.errors.get(), 0);
    }

    #[test]
    fn drifting_member_still_serves() {
        let f = farmlet(2);
        f.status[0].set_residual_ppm(50_000); // ≥ the 10_000 threshold
        f.tx.send(batch(&[0])).unwrap();
        f.tx.send(batch(&[1])).unwrap();
        assert!(recv(&f.rxs[0]).is_some(), "drifting is degraded, not dead");
        assert!(recv(&f.rxs[1]).is_some());
        assert_eq!(f.metrics.farm_transitions.get(), 1);
    }

    #[test]
    fn all_failed_still_routes_zero_drop() {
        let f = farmlet(2);
        f.status[0].fail();
        f.status[1].fail();
        f.tx.send(batch(&[7, 8])).unwrap();
        let b = recv(&f.rxs[0]).unwrap();
        assert_eq!(b.requests.len(), 2, "zero-drop beats refusing");
        assert_eq!(f.metrics.farm_absorbed.get(), 1);
        assert_eq!(f.metrics.errors.get(), 0);
    }

    #[test]
    fn full_preferred_queue_spills_to_sibling() {
        // member 0's queue holds one undrained batch: when the cursor
        // comes back around, the next batch must spill to member 1
        // instead of waiting on the full queue
        let (tx, rx) = mpsc::channel::<Batch>();
        let metrics = Arc::new(Metrics::default());
        let (t0, r0) = mpsc::sync_channel::<Batch>(1);
        let (t1, r1) = mpsc::sync_channel::<Batch>(4);
        let targets = vec![
            RouteTarget { tx: t0, status: ChipStatus::new(None, 10_000) },
            RouteTarget { tx: t1, status: ChipStatus::new(None, 10_000) },
        ];
        let m = Arc::clone(&metrics);
        let _h = std::thread::spawn(move || run(rx, targets, m));
        tx.send(batch(&[0])).unwrap(); // → member 0 (now full)
        tx.send(batch(&[1])).unwrap(); // → member 1 (its natural turn)
        tx.send(batch(&[2])).unwrap(); // natural turn 0 is full → spills
        assert_eq!(recv(&r1).unwrap().requests[0].id, 1);
        assert_eq!(recv(&r1).unwrap().requests[0].id, 2, "spilled batch");
        assert_eq!(recv(&r0).unwrap().requests[0].id, 0);
        assert!(metrics.farm_rerouted.get() >= 1, "spill counts as reroute");
    }

    #[test]
    fn dead_members_fall_through_and_total_loss_counts_errors() {
        let f = farmlet(2);
        drop(f.rxs); // both pipelines gone
        f.tx.send(batch(&[1, 2, 3])).unwrap();
        // the router must not hang; the lost requests become errors
        let t0 = Instant::now();
        while f.metrics.errors.get() < 3 {
            assert!(t0.elapsed() < Duration::from_secs(2), "router stuck");
            std::thread::yield_now();
        }
        assert_eq!(f.metrics.errors.get(), 3);
        assert_eq!(f.metrics.queue_depth.get(), -3, "depth rebalanced");
    }
}
