//! Health-aware batch router: the farm's failover stage between the
//! dynamic batcher and the per-chip pipelines (DESIGN.md §farm, §fault).
//!
//! The router owns the batcher's output, the members' retry channel, and
//! one **bounded** `sync_channel` per farm member, so a slow or wedged
//! chip exerts backpressure toward admission control instead of queueing
//! batches without bound (`repo_lint`'s stage-buffer-bounded rule covers
//! this file).  Per batch it reads every member's live
//! [`ChipHealth`](super::ChipHealth) and dispatches by preference:
//!
//! 1. round-robin over serving-capable members (`Healthy` / `Drifting`);
//! 2. a `Recalibrating` member if nothing healthier is routable — its
//!    pipeline still serves on the old engine while the background
//!    recalibration runs, it is just a worse operating point
//!    (`farm_absorbed` counts these);
//! 3. with no fallback lane: a `Failed` member only when *every* member
//!    has failed — zero-drop beats refusing, and the operator sees it in
//!    the health states.  With a fallback lane, `Failed` members never
//!    receive traffic — the fallback absorbs instead (graceful
//!    degradation, `degraded_batches` / the `degraded` gauge).
//!
//! Redispatched batches (failed on a member, sent back through the retry
//! channel by [`crate::coordinator::pipeline`]) are drained ahead of new
//! intake, and their origin member is moved to the *end* of the
//! preference order so a retry lands on a different healthy member
//! whenever one exists.  A batch at or over
//! [`pipeline::FARM_RETRY_BUDGET`] attempts is not offered to chip
//! members at all — only the fallback lane (or the terminal error
//! accounting) may consume it, which is what bounds the retry loop.
//!
//! A batch that lands anywhere other than the round-robin's natural next
//! member counts in `farm_rerouted`; observed health-state edges count
//! in `farm_transitions`.  Members whose pipeline is gone (teardown
//! race) are skipped; only when no member *and no fallback* can take the
//! batch are its requests accounted as errors, so the
//! submitted/completed/errors conservation the coordinator tests pin
//! still holds.
//!
//! Shutdown: when the batcher's sender closes the router keeps draining
//! the retry channel until the farm-wide in-flight count reaches zero —
//! a member sends its retry *before* decrementing the count, so once the
//! router observes zero after a drain, no retry can still be unsent.
//! Only then do the member queues (and the fallback queue) drop,
//! cascading shutdown into the pipelines.

use std::time::Duration;

use crate::obs::trace;
use crate::util::sync::atomic::{AtomicI64, Ordering};
use crate::util::sync::{mpsc, Arc};

use crate::coordinator::{pipeline, Batch, Metrics};

use super::{ChipHealth, ChipStatus};

/// One routable farm member: its bounded batch queue and health handle.
pub(crate) struct RouteTarget {
    pub tx: mpsc::SyncSender<Batch>,
    pub status: Arc<ChipStatus>,
}

struct Router {
    targets: Vec<RouteTarget>,
    fallback: Option<mpsc::SyncSender<Batch>>,
    in_flight: Arc<AtomicI64>,
    metrics: Arc<Metrics>,
    cursor: usize,
    last: Vec<ChipHealth>,
}

impl Router {
    /// Route one batch.  `origin` is the member a redispatched batch just
    /// failed on (`None` for fresh batches from the batcher).
    fn dispatch(&mut self, batch: Batch, origin: Option<usize>) {
        let n = self.targets.len();
        if n == 0 {
            // a farm always has ≥1 member (Farm::start asserts); this
            // arm only keeps accounting sound if that ever changes
            self.metrics.queue_depth.sub(batch.requests.len() as i64);
            self.metrics.errors.add(batch.requests.len());
            return;
        }
        // observe health once per batch; count every state edge
        let health: Vec<ChipHealth> =
            self.targets.iter().map(|t| t.status.health()).collect();
        for (i, (h, l)) in health.iter().zip(self.last.iter_mut()).enumerate() {
            if h != l {
                self.metrics.farm_transitions.add(1);
                trace::instant(
                    "health",
                    "farm",
                    [("chip", i as i64), ("state", h.code())],
                );
                *l = *h;
            }
        }
        // a batch at its attempt budget is no longer offered to chip
        // members — only the fallback lane (or the error accounting) may
        // consume it, which bounds the retry loop
        let over_budget = batch.attempts >= pipeline::FARM_RETRY_BUDGET;
        // preference order from the round-robin cursor: serving-capable
        // members first, then recalibrating; failed-as-last-resort only
        // when there is no fallback lane to degrade to
        let mut order: Vec<usize> = Vec::with_capacity(n);
        let mut absorbing = false;
        if !over_budget {
            let passes: &[u8] =
                if self.fallback.is_some() { &[0, 1] } else { &[0, 1, 2] };
            for &pass in passes {
                for k in 0..n {
                    let i = (self.cursor + k) % n;
                    let take = match pass {
                        0 => health[i].serves(),
                        1 => health[i] == ChipHealth::Recalibrating,
                        _ => health[i] == ChipHealth::Failed,
                    };
                    if take {
                        order.push(i);
                    }
                }
                if pass == 0 {
                    absorbing = order.is_empty();
                }
            }
            // redispatch away from the origin: stable-move it to the end
            // of the order, so a retry lands on a *different* member
            // whenever any other can take it
            if let Some(o) = origin {
                if let Some(pos) = order.iter().position(|&i| i == o) {
                    let i = order.remove(pos);
                    order.push(i);
                }
            }
        }

        let natural = self.cursor % n;
        let mut pending = Some(batch);
        let mut routed = None;
        // first pass: first member in preference order with queue space
        // — a busy chip must not stall traffic a healthy sibling could
        // take right now
        for &i in &order {
            let Some(b) = pending.take() else { break };
            match self.targets[i].tx.try_send(b) {
                Ok(()) => {
                    routed = Some(i);
                    break;
                }
                Err(mpsc::TrySendError::Full(b))
                | Err(mpsc::TrySendError::Disconnected(b)) => pending = Some(b),
            }
        }
        // every queue full: block on the most-preferred live member, so
        // the backpressure reaches admission control at the intake queue
        if routed.is_none() {
            for &i in &order {
                let Some(b) = pending.take() else { break };
                match self.targets[i].tx.send(b) {
                    Ok(()) => {
                        routed = Some(i);
                        break;
                    }
                    Err(mpsc::SendError(b)) => pending = Some(b),
                }
            }
        }
        match routed {
            Some(i) => {
                // on the member's books until it replies, redispatches,
                // or drops the batch (see [`pipeline::FarmLink`])
                self.in_flight.fetch_add(1, Ordering::SeqCst);
                trace::instant(
                    "route",
                    "farm",
                    [("chip", i as i64), ("rerouted", (i != natural) as i64)],
                );
                if i != natural {
                    self.metrics.farm_rerouted.add(1);
                }
                if absorbing {
                    self.metrics.farm_absorbed.add(1);
                } else {
                    // a first-choice chip took traffic: the farm is not
                    // running on the digital fallback
                    self.metrics.degraded.set(0);
                }
                self.cursor = i + 1;
            }
            None => {
                let b = match pending.take() {
                    Some(b) => b,
                    None => return,
                };
                let len = b.requests.len();
                if let Some(fb) = &self.fallback {
                    // graceful degradation: the digital reference lane
                    // absorbs what no chip member may take, so completed
                    // still equals submitted under total photonic loss
                    let sent = match fb.try_send(b) {
                        Ok(()) => true,
                        Err(mpsc::TrySendError::Full(b)) => fb.send(b).is_ok(),
                        Err(mpsc::TrySendError::Disconnected(_)) => false,
                    };
                    if sent {
                        self.metrics.degraded_batches.add(1);
                        if absorbing {
                            self.metrics.degraded.set(1);
                        }
                        trace::instant(
                            "degraded",
                            "fault",
                            trace::arg1("size", len as i64),
                        );
                        return;
                    }
                }
                // nothing can take the batch — every pipeline gone
                // (teardown race), or over budget with no fallback:
                // account the requests as errors so conservation holds
                self.metrics.queue_depth.sub(len as i64);
                self.metrics.errors.add(len);
            }
        }
    }
}

/// Router loop body (runs on its own thread).  Exits when the batcher's
/// sender closes *and* every dispatched batch has reached a terminal
/// state; dropping the member senders then cascades shutdown into the
/// per-chip pipelines.
pub(crate) fn run(
    rx: mpsc::Receiver<Batch>,
    retry_rx: mpsc::Receiver<(usize, Batch)>,
    targets: Vec<RouteTarget>,
    fallback: Option<mpsc::SyncSender<Batch>>,
    in_flight: Arc<AtomicI64>,
    metrics: Arc<Metrics>,
) {
    let n = targets.len();
    let mut r = Router {
        targets,
        fallback,
        in_flight,
        metrics,
        cursor: 0,
        // transition edges count from the farm's documented starting
        // state (every member Healthy), not a racy first observation
        last: vec![ChipHealth::Healthy; n],
    };
    let mut closed = false;
    loop {
        // retries drain ahead of new intake: a redispatched batch has
        // already waited at least one full member attempt
        while let Ok((origin, b)) = retry_rx.try_recv() {
            r.dispatch(b, Some(origin));
        }
        let idle = r.in_flight.load(Ordering::SeqCst) == 0;
        if idle {
            // the in-flight count hit zero *after* the drain above, and
            // members send a retry before decrementing, so one more
            // non-blocking look settles whether anything is pending
            if let Ok((origin, b)) = retry_rx.try_recv() {
                r.dispatch(b, Some(origin));
                continue;
            }
            if closed {
                return;
            }
            // nothing in flight ⇒ no retry can be produced until the
            // next dispatch: safe to block on intake
            match rx.recv() {
                Ok(b) => r.dispatch(b, None),
                Err(_) => closed = true,
            }
        } else if closed {
            if let Ok((origin, b)) =
                retry_rx.recv_timeout(Duration::from_millis(1))
            {
                r.dispatch(b, Some(origin));
            }
        } else {
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(b) => r.dispatch(b, None),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;
    use std::time::{Duration, Instant};

    fn batch(ids: &[u64]) -> Batch {
        Batch {
            requests: ids
                .iter()
                .map(|&id| {
                    // reply target irrelevant here: the router never
                    // answers requests, it only moves batches
                    let (reply, _rx) = mpsc::channel();
                    Request {
                        id,
                        image: Tensor::zeros(&[1, 2, 2]),
                        enqueued: Instant::now(),
                        reply,
                    }
                })
                .collect(),
            formed: Instant::now(),
            attempts: 0,
        }
    }

    struct Farmlet {
        tx: mpsc::Sender<Batch>,
        retry: mpsc::Sender<(usize, Batch)>,
        rxs: Vec<mpsc::Receiver<Batch>>,
        fallback_rx: Option<mpsc::Receiver<Batch>>,
        status: Vec<Arc<ChipStatus>>,
        metrics: Arc<Metrics>,
        _h: std::thread::JoinHandle<()>,
    }

    fn build(n: usize, with_fallback: bool) -> Farmlet {
        let (tx, rx) = mpsc::channel::<Batch>();
        let (retry, retry_rx) = mpsc::channel::<(usize, Batch)>();
        let metrics = Arc::new(Metrics::default());
        let in_flight = Arc::new(AtomicI64::new(0));
        let mut rxs = Vec::new();
        let mut status = Vec::new();
        let mut targets = Vec::new();
        for _ in 0..n {
            let (mtx, mrx) = mpsc::sync_channel::<Batch>(4);
            let st = ChipStatus::new(None, 10_000);
            targets.push(RouteTarget { tx: mtx, status: Arc::clone(&st) });
            rxs.push(mrx);
            status.push(st);
        }
        let (fb_tx, fallback_rx) = if with_fallback {
            let (ftx, frx) = mpsc::sync_channel::<Batch>(4);
            (Some(ftx), Some(frx))
        } else {
            (None, None)
        };
        let m = Arc::clone(&metrics);
        let _h = std::thread::spawn(move || {
            run(rx, retry_rx, targets, fb_tx, in_flight, m)
        });
        Farmlet { tx, retry, rxs, fallback_rx, status, metrics, _h }
    }

    fn farmlet(n: usize) -> Farmlet {
        build(n, false)
    }

    fn recv(rx: &mpsc::Receiver<Batch>) -> Option<Batch> {
        rx.recv_timeout(Duration::from_secs(2)).ok()
    }

    #[test]
    fn round_robins_over_healthy_members() {
        let f = farmlet(3);
        for i in 0..6 {
            f.tx.send(batch(&[i])).unwrap();
        }
        for k in 0..3 {
            // member k gets batches k and k+3, in order
            let a = recv(&f.rxs[k]).unwrap();
            let b = recv(&f.rxs[k]).unwrap();
            assert_eq!(a.requests[0].id, k as u64);
            assert_eq!(b.requests[0].id, (k + 3) as u64);
        }
        assert_eq!(f.metrics.farm_rerouted.get(), 0);
        assert_eq!(f.metrics.farm_absorbed.get(), 0);
    }

    #[test]
    fn failed_member_is_skipped_and_counted() {
        let f = farmlet(3);
        f.status[1].fail();
        for i in 0..4 {
            f.tx.send(batch(&[i])).unwrap();
        }
        // member 1 never serves; 0 and 2 alternate
        assert_eq!(recv(&f.rxs[0]).unwrap().requests[0].id, 0);
        assert_eq!(recv(&f.rxs[2]).unwrap().requests[0].id, 1);
        assert_eq!(recv(&f.rxs[0]).unwrap().requests[0].id, 2);
        assert_eq!(recv(&f.rxs[2]).unwrap().requests[0].id, 3);
        assert!(
            f.rxs[1].recv_timeout(Duration::from_millis(50)).is_err(),
            "a failed chip must not receive traffic"
        );
        // one transition edge (Healthy → Failed), and every batch whose
        // natural round-robin slot was the dead member rerouted
        assert_eq!(f.metrics.farm_transitions.get(), 1);
        assert!(f.metrics.farm_rerouted.get() >= 1);
        assert_eq!(f.metrics.farm_absorbed.get(), 0);
        assert_eq!(f.metrics.errors.get(), 0);
    }

    #[test]
    fn drifting_member_still_serves() {
        let f = farmlet(2);
        f.status[0].set_residual_ppm(50_000); // ≥ the 10_000 threshold
        f.tx.send(batch(&[0])).unwrap();
        f.tx.send(batch(&[1])).unwrap();
        assert!(recv(&f.rxs[0]).is_some(), "drifting is degraded, not dead");
        assert!(recv(&f.rxs[1]).is_some());
        assert_eq!(f.metrics.farm_transitions.get(), 1);
    }

    #[test]
    fn all_failed_still_routes_zero_drop() {
        let f = farmlet(2);
        f.status[0].fail();
        f.status[1].fail();
        f.tx.send(batch(&[7, 8])).unwrap();
        let b = recv(&f.rxs[0]).unwrap();
        assert_eq!(b.requests.len(), 2, "zero-drop beats refusing");
        assert_eq!(f.metrics.farm_absorbed.get(), 1);
        assert_eq!(f.metrics.errors.get(), 0);
    }

    #[test]
    fn full_preferred_queue_spills_to_sibling() {
        // member 0's queue holds one undrained batch: when the cursor
        // comes back around, the next batch must spill to member 1
        // instead of waiting on the full queue
        let (tx, rx) = mpsc::channel::<Batch>();
        let (_retry, retry_rx) = mpsc::channel::<(usize, Batch)>();
        let metrics = Arc::new(Metrics::default());
        let in_flight = Arc::new(AtomicI64::new(0));
        let (t0, r0) = mpsc::sync_channel::<Batch>(1);
        let (t1, r1) = mpsc::sync_channel::<Batch>(4);
        let targets = vec![
            RouteTarget { tx: t0, status: ChipStatus::new(None, 10_000) },
            RouteTarget { tx: t1, status: ChipStatus::new(None, 10_000) },
        ];
        let m = Arc::clone(&metrics);
        let _h = std::thread::spawn(move || {
            run(rx, retry_rx, targets, None, in_flight, m)
        });
        tx.send(batch(&[0])).unwrap(); // → member 0 (now full)
        tx.send(batch(&[1])).unwrap(); // → member 1 (its natural turn)
        tx.send(batch(&[2])).unwrap(); // natural turn 0 is full → spills
        assert_eq!(recv(&r1).unwrap().requests[0].id, 1);
        assert_eq!(recv(&r1).unwrap().requests[0].id, 2, "spilled batch");
        assert_eq!(recv(&r0).unwrap().requests[0].id, 0);
        assert!(metrics.farm_rerouted.get() >= 1, "spill counts as reroute");
    }

    #[test]
    fn dead_members_fall_through_and_total_loss_counts_errors() {
        let f = farmlet(2);
        drop(f.rxs); // both pipelines gone
        f.tx.send(batch(&[1, 2, 3])).unwrap();
        // the router must not hang; the lost requests become errors
        let t0 = Instant::now();
        while f.metrics.errors.get() < 3 {
            assert!(t0.elapsed() < Duration::from_secs(2), "router stuck");
            std::thread::yield_now();
        }
        assert_eq!(f.metrics.errors.get(), 3);
        assert_eq!(f.metrics.queue_depth.get(), -3, "depth rebalanced");
    }

    #[test]
    fn retry_avoids_origin_and_over_budget_degrades_to_fallback() {
        let f = build(2, true);
        // a retry from member 0 must land on member 1 even though 0 is
        // the round-robin's natural next slot
        let mut b = batch(&[1]);
        b.attempts = 1;
        f.retry.send((0, b)).unwrap();
        let got = recv(&f.rxs[1]).expect("redispatch to the other member");
        assert_eq!(got.requests[0].id, 1);
        assert!(
            f.rxs[0].recv_timeout(Duration::from_millis(50)).is_err(),
            "the origin member must be the last resort, not the first"
        );
        // at the attempt budget no chip member may take the batch: the
        // fallback lane absorbs it
        let mut b = batch(&[2]);
        b.attempts = pipeline::FARM_RETRY_BUDGET;
        f.retry.send((1, b)).unwrap();
        let fb = f.fallback_rx.as_ref().unwrap();
        let got = recv(fb).expect("over-budget batch degrades to fallback");
        assert_eq!(got.requests[0].id, 2);
        assert_eq!(f.metrics.degraded_batches.get(), 1);
        // the chips themselves are healthy: the degraded *gauge* (farm
        // is running digitally) must not latch on a per-batch budget
        assert_eq!(f.metrics.degraded.get(), 0);
        assert_eq!(f.metrics.errors.get(), 0);
    }

    #[test]
    fn total_quarantine_degrades_to_fallback_and_gauge_recovers() {
        let f = build(2, true);
        f.status[0].quarantine();
        f.status[1].quarantine();
        f.tx.send(batch(&[5])).unwrap();
        let fb = f.fallback_rx.as_ref().unwrap();
        let got = recv(fb).expect("total quarantine must degrade, not drop");
        assert_eq!(got.requests[0].id, 5);
        assert_eq!(f.metrics.degraded.get(), 1, "farm is running digitally");
        assert_eq!(f.metrics.degraded_batches.get(), 1);
        // with a fallback lane, quarantined members never see traffic
        // (no failed-as-last-resort)
        assert!(f.rxs[0].recv_timeout(Duration::from_millis(50)).is_err());
        // a member restored: traffic returns to chips, the gauge clears
        f.status[0].restore();
        f.tx.send(batch(&[6])).unwrap();
        assert_eq!(recv(&f.rxs[0]).unwrap().requests[0].id, 6);
        assert_eq!(f.metrics.degraded.get(), 0, "degradation must clear");
        assert_eq!(f.metrics.errors.get(), 0);
    }

    #[test]
    fn propcheck_never_routes_to_failed_while_a_capable_member_exists() {
        // randomized fail/restore sequences over K ∈ {2, 3, 5}: every
        // batch lands somewhere (zero drops), and never on a Failed
        // member while any serving-capable member exists
        for &k in &[2usize, 3, 5] {
            let f = farmlet(k);
            let mut rng = Rng::new(0xFA11 + k as u64);
            for round in 0..40u64 {
                for st in &f.status {
                    if rng.f32() < 0.4 {
                        st.fail();
                    } else {
                        st.restore();
                    }
                }
                let failed: Vec<bool> = f
                    .status
                    .iter()
                    .map(|s| s.health() == ChipHealth::Failed)
                    .collect();
                f.tx.send(batch(&[round])).unwrap();
                let mut got = None;
                let t0 = Instant::now();
                'hunt: while t0.elapsed() < Duration::from_secs(2) {
                    for (i, rx) in f.rxs.iter().enumerate() {
                        if let Ok(b) = rx.try_recv() {
                            got = Some((i, b));
                            break 'hunt;
                        }
                    }
                    std::thread::yield_now();
                }
                let (i, b) = got.expect("zero drops: every batch must land");
                assert_eq!(b.requests[0].id, round);
                if failed.iter().any(|dead| !dead) {
                    assert!(
                        !failed[i],
                        "k={k} round {round}: routed to failed member {i}"
                    );
                }
            }
            assert_eq!(f.metrics.errors.get(), 0, "zero drops over k={k}");
        }
    }
}
