//! Worker loop: pulls batches from the shared queue, runs the backend,
//! replies to each request, and records metrics.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::Result;

use crate::onn::{Backend, Engine};
use crate::tensor::Tensor;

use super::metrics::Metrics;
use super::{Batch, Response};

/// Anything that can classify a batch of images.
pub trait InferenceBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>>;
    fn name(&self) -> String;
}

/// Constructs a backend *on the worker's own thread*.  PJRT clients are
/// `!Send` (Rc-based), so XLA backends cannot cross threads; the factory
/// pattern lets every worker build its own client/sim locally.
pub type BackendFactory = Box<dyn FnOnce() -> Box<dyn InferenceBackend> + Send>;

/// The ONN engine + execution mode as a serving backend.
pub struct EngineBackend {
    pub engine: Arc<Engine>,
    pub mode: Backend,
}

impl InferenceBackend for EngineBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        self.engine.forward_batch(imgs, &mut self.mode)
    }

    fn name(&self) -> String {
        match self.mode {
            Backend::Digital => "engine/digital".into(),
            Backend::PhotonicSim(_) => "engine/photonic-sim".into(),
        }
    }
}

/// An AOT XLA artifact as a serving backend.  Owns its own Runtime (PJRT
/// client), so it must be constructed by a [`BackendFactory`] on the
/// worker thread.  The artifact has a fixed batch dimension, so short
/// batches are zero-padded up to it.
pub struct XlaBackend {
    pub rt: crate::runtime::Runtime,
    pub model: String,
    pub batch: usize,
    pub classes: usize,
    pub input_chw: (usize, usize, usize),
}

impl XlaBackend {
    pub fn new(
        artifacts: &std::path::Path,
        model: &str,
        batch: usize,
        classes: usize,
        input_chw: (usize, usize, usize),
    ) -> Result<XlaBackend> {
        let mut rt = crate::runtime::Runtime::new(artifacts)?;
        rt.load(model)?; // compile eagerly so serving never stalls
        Ok(XlaBackend { rt, model: model.to_string(), batch, classes, input_chw })
    }
}

impl InferenceBackend for XlaBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let (c, h, w) = self.input_chw;
        let per = c * h * w;
        let mut out = Vec::with_capacity(imgs.len());
        for chunk in imgs.chunks(self.batch) {
            let mut data = vec![0.0f32; self.batch * per];
            for (i, im) in chunk.iter().enumerate() {
                data[i * per..(i + 1) * per].copy_from_slice(&im.data);
            }
            let x = Tensor::new(&[self.batch, c, h, w], data);
            let flat = self.rt.load(&self.model)?.run(&[&x])?;
            for i in 0..chunk.len() {
                out.push(flat[i * self.classes..(i + 1) * self.classes].to_vec());
            }
        }
        Ok(out)
    }

    fn name(&self) -> String {
        format!("xla/{}", self.model)
    }
}

/// Worker loop body (runs on its own thread).
pub fn run(
    mut backend: Box<dyn InferenceBackend>,
    rx: Arc<Mutex<mpsc::Receiver<Batch>>>,
    metrics: Arc<Metrics>,
) {
    loop {
        // take one batch while holding the lock, then release before compute
        let batch = match rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return, // queue closed
        };
        let images: Vec<Tensor> =
            batch.requests.iter().map(|r| r.image.clone()).collect();
        let t0 = Instant::now();
        match backend.infer_batch(&images) {
            Ok(all_logits) => {
                let compute_us =
                    (t0.elapsed().as_micros() as u64).max(1) / images.len() as u64;
                for (req, logits) in batch.requests.into_iter().zip(all_logits) {
                    let queue_us =
                        batch.formed.duration_since(req.enqueued).as_micros()
                            as u64;
                    let total =
                        req.enqueued.elapsed().as_micros() as u64;
                    metrics.record_latency_us(total);
                    metrics.completed.add(1);
                    let _ = req.reply.send(Response {
                        id: req.id,
                        logits,
                        queue_us,
                        compute_us,
                    });
                }
                metrics.batches.add(1);
            }
            Err(e) => {
                // fail the whole batch: drop reply senders (receivers see
                // a closed channel) and count the errors
                log::error!("backend {} failed: {e:#}", backend.name());
                metrics.errors.add(batch.requests.len());
            }
        }
    }
}

/// Join handle that detaches on drop failure-free (workers exit when their
/// channels close, so drop order guarantees termination).
pub struct JoinOnDrop(Option<thread::JoinHandle<()>>);

impl Drop for JoinOnDrop {
    fn drop(&mut self) {
        if let Some(h) = self.0.take() {
            let _ = h.join();
        }
    }
}

pub fn spawn_named<F: FnOnce() + Send + 'static>(name: &str, f: F) -> JoinOnDrop {
    JoinOnDrop(Some(
        thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("spawn thread"),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    struct CountBackend(usize);

    impl InferenceBackend for CountBackend {
        fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            self.0 += imgs.len();
            Ok(imgs.iter().map(|_| vec![0.0]).collect())
        }
        fn name(&self) -> String {
            "count".into()
        }
    }

    #[test]
    fn worker_exits_on_queue_close() {
        let (tx, rx) = mpsc::channel::<Batch>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let h = spawn_named("t", {
            let rx = Arc::clone(&rx);
            let m = Arc::clone(&metrics);
            move || run(Box::new(CountBackend(0)), rx, m)
        });
        drop(tx);
        drop(h); // join must not hang
    }

    #[test]
    fn xla_backend_padding_logic() {
        // shape math only (no PJRT in unit tests): chunks + per-image strides
        let imgs: Vec<Tensor> = (0..5).map(|_| Tensor::zeros(&[1, 2, 2])).collect();
        let chunks: Vec<usize> = imgs.chunks(4).map(|c| c.len()).collect();
        assert_eq!(chunks, vec![4, 1]);
    }
}
