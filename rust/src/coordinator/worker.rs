//! Worker loop: pulls batches from the shared queue, runs the backend,
//! replies to each request, and records metrics.

use std::thread;
use std::time::Instant;

use crate::util::sync::{mpsc, Arc, Mutex};

use crate::obs::trace;
use crate::onn::{Backend, Engine};
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::scratch;

use super::metrics::Metrics;
use super::{Batch, Response};

/// Anything that can classify a batch of images.
pub trait InferenceBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>>;
    fn name(&self) -> String;
}

/// Constructs a backend *on the worker's own thread*.  PJRT clients are
/// `!Send` (Rc-based), so XLA backends cannot cross threads; the factory
/// pattern lets every worker build its own client/sim locally.
pub type BackendFactory = Box<dyn FnOnce() -> Box<dyn InferenceBackend> + Send>;

/// The ONN engine + execution mode as a serving backend.
pub struct EngineBackend {
    pub engine: Arc<Engine>,
    pub mode: Backend,
}

impl InferenceBackend for EngineBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        self.engine.forward_batch(imgs, &mut self.mode)
    }

    fn name(&self) -> String {
        match self.mode {
            Backend::Digital => "engine/digital".into(),
            Backend::PhotonicSim(_) => "engine/photonic-sim".into(),
        }
    }
}

/// Zero-pad a short chunk of `per`-element images up to an artifact's
/// fixed batch dimension, row-major.  Shared by [`XlaBackend`] and the
/// offline mock in the tests, so the padding contract is exercised
/// without a PJRT client.
pub fn pack_padded_chunk(chunk: &[Tensor], batch: usize, per: usize) -> Vec<f32> {
    assert!(chunk.len() <= batch, "chunk longer than artifact batch");
    let mut data = vec![0.0f32; batch * per];
    for (i, im) in chunk.iter().enumerate() {
        data[i * per..(i + 1) * per].copy_from_slice(&im.data);
    }
    data
}

/// An AOT XLA artifact as a serving backend.  Owns its own Runtime (PJRT
/// client), so it must be constructed by a [`BackendFactory`] on the
/// worker thread.  The artifact has a fixed batch dimension, so short
/// batches are zero-padded up to it.
#[cfg(feature = "pjrt")]
pub struct XlaBackend {
    pub rt: crate::runtime::Runtime,
    pub model: String,
    pub batch: usize,
    pub classes: usize,
    pub input_chw: (usize, usize, usize),
}

#[cfg(feature = "pjrt")]
impl XlaBackend {
    pub fn new(
        artifacts: &std::path::Path,
        model: &str,
        batch: usize,
        classes: usize,
        input_chw: (usize, usize, usize),
    ) -> Result<XlaBackend> {
        let mut rt = crate::runtime::Runtime::new(artifacts)?;
        rt.load(model)?; // compile eagerly so serving never stalls
        Ok(XlaBackend { rt, model: model.to_string(), batch, classes, input_chw })
    }
}

#[cfg(feature = "pjrt")]
impl InferenceBackend for XlaBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let (c, h, w) = self.input_chw;
        let per = c * h * w;
        let mut out = Vec::with_capacity(imgs.len());
        for chunk in imgs.chunks(self.batch) {
            let data = pack_padded_chunk(chunk, self.batch, per);
            let x = Tensor::new(&[self.batch, c, h, w], data);
            let flat = self.rt.load(&self.model)?.run(&[&x])?;
            for i in 0..chunk.len() {
                out.push(flat[i * self.classes..(i + 1) * self.classes].to_vec());
            }
        }
        Ok(out)
    }

    fn name(&self) -> String {
        format!("xla/{}", self.model)
    }
}

/// Worker loop body (runs on its own thread).
pub fn run(
    mut backend: Box<dyn InferenceBackend>,
    rx: Arc<Mutex<mpsc::Receiver<Batch>>>,
    metrics: Arc<Metrics>,
) {
    loop {
        // take one batch while holding the lock, then release before
        // compute.  A poisoned lock means a sibling worker panicked while
        // holding it; the queue itself is still sound (recv is the only
        // op under the lock), so recover, count it, and keep serving —
        // one dead worker must not cascade into a dead pool.
        let batch = match rx
            .lock()
            .unwrap_or_else(|e| {
                metrics.lock_poisons.add(1);
                e.into_inner()
            })
            .recv()
        {
            Ok(b) => b,
            Err(_) => return, // queue closed
        };
        // the batcher never emits empty batches, but guard anyway: the
        // per-request accounting below divides by the batch size
        if batch.requests.is_empty() {
            continue;
        }
        let Batch { requests, formed, attempts: _ } = batch;
        let n = requests.len();
        // requests leave the queue the moment a worker owns them
        metrics.queue_depth.sub(n as i64);
        // move the images out of the requests — the engine consumes the
        // whole batch as one batch-major call, no per-image clones
        let mut images = Vec::with_capacity(n);
        let mut replies = Vec::with_capacity(n);
        for req in requests {
            images.push(req.image);
            replies.push((req.id, req.enqueued, req.reply));
        }
        let t0 = Instant::now();
        let span = trace::begin();
        match backend.infer_batch(&images) {
            Ok(all_logits) => {
                trace::end(span, "infer", "stage", trace::arg1("size", n as i64));
                let batch_us = t0.elapsed().as_micros() as u64;
                metrics.batch_compute_us.record(batch_us.max(1));
                metrics.batch_sizes.record(n as u64);
                // per-request share of the batch compute time; clamp to
                // ≥1µs *after* dividing so fast batches don't round to 0
                let compute_us = (batch_us / n as u64).max(1);
                for ((id, enqueued, reply), logits) in
                    replies.into_iter().zip(all_logits)
                {
                    let queue_us =
                        formed.duration_since(enqueued).as_micros() as u64;
                    let total = enqueued.elapsed().as_micros() as u64;
                    metrics.record_latency_us(total);
                    metrics.completed.add(1);
                    let _ = reply.send(Response {
                        id,
                        logits,
                        queue_us,
                        compute_us,
                    });
                }
                metrics.batches.add(1);
                // allocs-per-batch proxy: this worker's scratch-arena
                // counters (the planned path stops missing once warm)
                let st = scratch::stats();
                metrics.scratch_takes.set(st.takes as i64);
                metrics.scratch_misses.set(st.misses as i64);
            }
            Err(e) => {
                // fail the whole batch: drop reply senders (receivers see
                // a closed channel) and count the errors
                eprintln!("cirptc worker: backend {} failed: {e:#}", backend.name());
                metrics.errors.add(n);
            }
        }
    }
}

/// Join handle that detaches on drop failure-free (workers exit when their
/// channels close, so drop order guarantees termination).
pub struct JoinOnDrop(Option<thread::JoinHandle<()>>);

impl Drop for JoinOnDrop {
    fn drop(&mut self) {
        if let Some(h) = self.0.take() {
            let _ = h.join();
        }
    }
}

pub fn spawn_named<F: FnOnce() + Send + 'static>(name: &str, f: F) -> JoinOnDrop {
    JoinOnDrop(Some(
        thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            // lint:allow(hot-path-unwrap): spawn happens once at startup,
            // not per batch; if the OS refuses a thread the coordinator
            // cannot exist, and there is no caller to hand a Result to
            .expect("spawn thread"),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    struct CountBackend(usize);

    impl InferenceBackend for CountBackend {
        fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            self.0 += imgs.len();
            Ok(imgs.iter().map(|_| vec![0.0]).collect())
        }
        fn name(&self) -> String {
            "count".into()
        }
    }

    #[test]
    fn worker_exits_on_queue_close() {
        let (tx, rx) = mpsc::channel::<Batch>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let h = spawn_named("t", {
            let rx = Arc::clone(&rx);
            let m = Arc::clone(&metrics);
            move || run(Box::new(CountBackend(0)), rx, m)
        });
        drop(tx);
        drop(h); // join must not hang
    }

    #[test]
    fn empty_batch_is_skipped_and_compute_us_clamps_after_divide() {
        let (tx, rx) = mpsc::channel::<Batch>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let h = spawn_named("t", {
            let rx = Arc::clone(&rx);
            let m = Arc::clone(&metrics);
            move || run(Box::new(CountBackend(0)), rx, m)
        });
        // an empty batch must not kill the worker (the per-request
        // accounting divides by the batch size) or count as served work
        tx.send(Batch { requests: vec![], formed: Instant::now(), attempts: 0 })
            .unwrap();
        // ... and a real request submitted afterwards must still be served
        let (reply, reply_rx) = mpsc::channel();
        tx.send(Batch {
            requests: vec![super::super::Request {
                id: 7,
                image: Tensor::zeros(&[1, 2, 2]),
                enqueued: Instant::now(),
                reply,
            }],
            formed: Instant::now(),
            attempts: 0,
        })
        .unwrap();
        let resp = reply_rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("worker must survive the empty batch");
        assert_eq!(resp.id, 7);
        // an instant single-image batch rounds to 0µs/request before the
        // clamp; clamping after the division keeps the floor at 1µs
        assert!(resp.compute_us >= 1);
        drop(tx);
        drop(h);
        assert_eq!(metrics.batches.get(), 1, "empty batch must not count");
        assert_eq!(metrics.completed.get(), 1);
        // per-batch instrumentation: one compute sample, one size sample
        assert_eq!(metrics.batch_compute_us.count(), 1);
        assert_eq!(metrics.batch_sizes.count(), 1);
        assert_eq!(metrics.batch_sizes.percentile(1.0), 1);
        // the worker decremented the gauge for the one real request it
        // received (nothing ever incremented it in this direct-channel
        // test, so it ends at -1)
        assert_eq!(metrics.queue_depth.get(), -1);
    }

    #[test]
    fn worker_survives_poisoned_queue_lock() {
        let (tx, rx) = mpsc::channel::<Batch>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        // poison the shared queue lock: a "worker" panics while holding it
        let _ = {
            let rx = Arc::clone(&rx);
            std::thread::spawn(move || {
                let _g = rx.lock().unwrap();
                panic!("sibling worker died holding the queue lock");
            })
            .join()
        };
        let h = spawn_named("t", {
            let rx = Arc::clone(&rx);
            let m = Arc::clone(&metrics);
            move || run(Box::new(CountBackend(0)), rx, m)
        });
        let (reply, reply_rx) = mpsc::channel();
        tx.send(Batch {
            requests: vec![super::super::Request {
                id: 9,
                image: Tensor::zeros(&[1, 2, 2]),
                enqueued: Instant::now(),
                reply,
            }],
            formed: Instant::now(),
            attempts: 0,
        })
        .unwrap();
        let resp = reply_rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("worker must recover the poisoned lock and serve");
        assert_eq!(resp.id, 9);
        assert!(metrics.lock_poisons.get() >= 1, "recovery must be counted");
        drop(tx);
        drop(h);
    }

    /// Offline stand-in for the XLA artifact contract: fixed batch
    /// dimension, zero-padded tail, per-image logits sliced back out —
    /// the same chunk/pad pipeline as `XlaBackend::infer_batch`, without
    /// a PJRT client.
    struct MockArtifactBackend {
        batch: usize,
        classes: usize,
        per: usize,
        chunk_sizes: Vec<usize>,
    }

    impl InferenceBackend for MockArtifactBackend {
        fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            let mut out = Vec::with_capacity(imgs.len());
            for chunk in imgs.chunks(self.batch) {
                let data = pack_padded_chunk(chunk, self.batch, self.per);
                assert_eq!(data.len(), self.batch * self.per);
                assert!(
                    data[chunk.len() * self.per..].iter().all(|v| *v == 0.0),
                    "padding tail must be zero"
                );
                self.chunk_sizes.push(chunk.len());
                for i in 0..chunk.len() {
                    out.push(vec![data[i * self.per]; self.classes]);
                }
            }
            Ok(out)
        }
        fn name(&self) -> String {
            "mock-artifact".into()
        }
    }

    #[test]
    fn xla_backend_padding_logic() {
        // chunking + zero padding + per-image slicing, exercised offline
        // through a mock InferenceBackend (no PJRT in unit tests)
        let imgs: Vec<Tensor> = (0..5)
            .map(|i| Tensor::full(&[1, 2, 2], (i + 1) as f32))
            .collect();
        let mut be = MockArtifactBackend {
            batch: 4,
            classes: 3,
            per: 4,
            chunk_sizes: vec![],
        };
        let out = be.infer_batch(&imgs).unwrap();
        assert_eq!(be.chunk_sizes, vec![4, 1]);
        assert_eq!(out.len(), 5);
        for (i, logits) in out.iter().enumerate() {
            assert_eq!(logits, &vec![(i + 1) as f32; 3]);
        }
    }

    #[test]
    #[should_panic(expected = "chunk longer than artifact batch")]
    fn pack_rejects_oversized_chunk() {
        let imgs: Vec<Tensor> = (0..3).map(|_| Tensor::zeros(&[1, 1, 1])).collect();
        pack_padded_chunk(&imgs, 2, 1);
    }
}
