//! Dynamic batcher: groups incoming requests up to `max_batch` or until
//! `max_wait_us` expires, whichever first (the standard serving trade-off
//! between throughput and tail latency — the knob the serving bench sweeps).

use std::time::{Duration, Instant};

use crate::obs::trace;
use crate::util::sync::mpsc;

use super::Request;

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    /// maximum time the *oldest* request may wait before dispatch (µs).
    /// The batcher drains on deadline-or-size: a batch goes out the
    /// moment it reaches `max_batch` *or* its oldest request has waited
    /// `max_wait_us`, whichever first.  `0` degenerates to the greedy
    /// drain — whatever is already queued dispatches immediately, never
    /// waiting for stragglers.
    pub max_wait_us: u64,
    /// admission-control bound on requests in flight (intake channel +
    /// formed-but-unclaimed batches): [`super::Coordinator::submit`]
    /// sheds with [`super::Admission::Shed`] once `queue_depth` reaches
    /// this, trading a fast rejection for unbounded queueing latency.
    /// `0` = unbounded (the pre-SLO behavior).
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait_us: 2000, queue_cap: 0 }
    }
}

/// A dispatched batch.
pub struct Batch {
    pub requests: Vec<Request>,
    pub formed: Instant,
    /// Delivery attempts consumed so far: 0 from the batcher; bumped by
    /// the farm pipeline each time the batch fails on a member and is
    /// redispatched (see [`super::pipeline::FARM_RETRY_BUDGET`]).
    pub attempts: u32,
}

/// Batcher loop: drains the intake channel into batches.  Exits when the
/// intake channel closes (coordinator drop), flushing any pending batch.
pub fn run(
    rx: mpsc::Receiver<Request>,
    out: mpsc::Sender<Batch>,
    cfg: BatcherConfig,
) {
    let max_wait = Duration::from_micros(cfg.max_wait_us);
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    // spans one batch's formation window: opened when pending goes 0→1,
    // closed (as a "batch_form" complete event) at dispatch
    let mut form_start: Option<trace::SpanStart> = None;
    loop {
        let timeout = if pending.is_empty() {
            // idle: block until something arrives (bounded poll so channel
            // close is observed promptly)
            Duration::from_millis(50)
        } else {
            max_wait
                .checked_sub(pending[0].enqueued.elapsed())
                .unwrap_or(Duration::ZERO)
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if pending.is_empty() {
                    form_start = Some(trace::begin());
                }
                pending.push(req);
                // greedily drain whatever is already queued: under burst
                // load this forms full batches in one wakeup instead of
                // one recv per request, feeding the engine's batch-major
                // forward the widest operand block the policy allows
                while pending.len() < cfg.max_batch {
                    match rx.try_recv() {
                        Ok(r) => pending.push(r),
                        Err(_) => break,
                    }
                }
                if pending.len() >= cfg.max_batch {
                    dispatch(&mut pending, &out, &mut form_start);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if !pending.is_empty() {
                    dispatch(&mut pending, &out, &mut form_start);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    dispatch(&mut pending, &out, &mut form_start);
                }
                return;
            }
        }
    }
}

fn dispatch(
    pending: &mut Vec<Request>,
    out: &mpsc::Sender<Batch>,
    form_start: &mut Option<trace::SpanStart>,
) {
    if let Some(start) = form_start.take() {
        trace::end(
            start,
            "batch_form",
            "request",
            trace::arg1("size", pending.len() as i64),
        );
    }
    let batch = Batch {
        requests: std::mem::take(pending),
        formed: Instant::now(),
        attempts: 0,
    };
    // receiver gone ⇒ shutting down; requests drop, senders see RecvError
    let _ = out.send(batch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::thread;

    fn req(id: u64) -> (Request, mpsc::Receiver<super::super::Response>) {
        let (reply, rx) = mpsc::channel();
        (
            Request {
                id,
                image: Tensor::zeros(&[1, 2, 2]),
                enqueued: Instant::now(),
                reply,
            },
            rx,
        )
    }

    fn start(cfg: BatcherConfig) -> (mpsc::Sender<Request>, mpsc::Receiver<Batch>) {
        let (tx, rx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        thread::spawn(move || run(rx, btx, cfg));
        (tx, brx)
    }

    #[test]
    fn fills_to_max_batch() {
        let (tx, brx) = start(BatcherConfig {
            max_batch: 4,
            max_wait_us: 1_000_000,
            queue_cap: 0,
        });
        for i in 0..4 {
            tx.send(req(i).0).unwrap();
        }
        let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.requests.len(), 4);
    }

    #[test]
    fn flushes_on_timeout() {
        let (tx, brx) = start(BatcherConfig {
            max_batch: 64,
            max_wait_us: 3_000,
            queue_cap: 0,
        });
        tx.send(req(1).0).unwrap();
        let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.requests.len(), 1, "partial batch must flush");
    }

    #[test]
    fn flushes_remainder_on_shutdown() {
        let (tx, brx) = start(BatcherConfig {
            max_batch: 64,
            max_wait_us: 10_000_000,
            queue_cap: 0,
        });
        tx.send(req(1).0).unwrap();
        tx.send(req(2).0).unwrap();
        drop(tx);
        let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.requests.len(), 2);
    }

    #[test]
    fn greedy_drain_fills_batch_from_backlog() {
        // requests queued before the batcher wakes must come out as one
        // full batch, not max_batch singleton batches
        let (tx, rx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        for i in 0..4 {
            tx.send(req(i).0).unwrap();
        }
        thread::spawn(move || {
            run(
                rx,
                btx,
                BatcherConfig {
                    max_batch: 4,
                    max_wait_us: 1_000_000,
                    queue_cap: 0,
                },
            )
        });
        let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.requests.len(), 4, "backlog must batch in one dispatch");
    }

    #[test]
    fn order_preserved_within_batch() {
        let (tx, brx) = start(BatcherConfig {
            max_batch: 3,
            max_wait_us: 1_000_000,
            queue_cap: 0,
        });
        for i in [10u64, 11, 12] {
            tx.send(req(i).0).unwrap();
        }
        let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![10, 11, 12]);
    }

    #[test]
    fn idle_batcher_never_emits_empty_batches() {
        // deadline edge 1: an empty queue riding through many timeout
        // cycles must stay silent — the deadline only applies to a
        // non-empty pending set
        let (tx, brx) = start(BatcherConfig {
            max_batch: 4,
            max_wait_us: 1_000,
            queue_cap: 0,
        });
        assert!(
            brx.recv_timeout(Duration::from_millis(120)).is_err(),
            "idle batcher must not dispatch"
        );
        // and it is still alive and batching afterwards
        tx.send(req(1).0).unwrap();
        let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.requests.len(), 1);
    }

    #[test]
    fn burst_larger_than_max_batch_splits_into_full_batches() {
        // deadline edge 2: a 10-request burst against max_batch=4 must
        // come out as [4, 4, 2] — full batches immediately on size, the
        // remainder on the deadline — with order preserved across splits
        let (tx, rx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i).0).unwrap();
        }
        thread::spawn(move || {
            run(
                rx,
                btx,
                BatcherConfig {
                    max_batch: 4,
                    max_wait_us: 5_000,
                    queue_cap: 0,
                },
            )
        });
        let mut sizes = Vec::new();
        let mut ids = Vec::new();
        for _ in 0..3 {
            let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
            sizes.push(b.requests.len());
            ids.extend(b.requests.iter().map(|r| r.id));
        }
        assert_eq!(sizes, vec![4, 4, 2]);
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn max_wait_zero_dispatches_greedily() {
        // the greedy pre-deadline policy is the max_wait = 0 case: a
        // backlog dispatches as one batch the instant the batcher wakes,
        // and a lone request never waits for company
        let (tx, rx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        tx.send(req(0).0).unwrap();
        tx.send(req(1).0).unwrap();
        thread::spawn(move || {
            run(
                rx,
                btx,
                BatcherConfig { max_batch: 8, max_wait_us: 0, queue_cap: 0 },
            )
        });
        let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.requests.len(), 2, "backlog goes out in one batch");
        tx.send(req(2).0).unwrap();
        let b = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.requests.len(), 1, "a singleton must not wait");
    }
}
