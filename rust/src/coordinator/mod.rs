//! L3 serving coordinator (vLLM-router-style): request intake → dynamic
//! batcher → worker pool → per-request responses, with latency/throughput
//! metrics.
//!
//! The coordinator is generic over [`InferenceBackend`], so the same
//! router/batcher serves the pure-rust digital engine, the photonic-chip
//! simulator, and the AOT XLA artifacts (`runtime::Executable`) — the
//! paper's digital-vs-CirPTC comparisons run through identical serving
//! machinery.

pub mod batcher;
pub mod metrics;
pub mod scheduler;
pub mod worker;

use std::time::Instant;

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{mpsc, Arc, Mutex};

use crate::tensor::Tensor;
use crate::util::error::Result;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{Gauge, Histogram, Metrics};
pub use scheduler::TileScheduler;
pub use worker::{BackendFactory, InferenceBackend};

/// One classification request.
pub struct Request {
    pub id: u64,
    pub image: Tensor,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// The response delivered to the submitter.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub queue_us: u64,
    pub compute_us: u64,
}

/// Handle returned by [`Coordinator::submit`].
pub struct Pending {
    rx: mpsc::Receiver<Response>,
}

impl Pending {
    pub fn wait(self) -> Result<Response> {
        Ok(self.rx.recv()?)
    }
}

/// The running coordinator: intake channel + batcher thread + workers.
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    // keep the threads alive; joined on drop
    _batcher: worker::JoinOnDrop,
    _workers: Vec<worker::JoinOnDrop>,
}

impl Coordinator {
    /// Start a coordinator over a set of backend *factories* (one worker
    /// thread per factory; each worker constructs its backend on its own
    /// thread — required because PJRT clients are thread-local (!Send),
    /// and desirable because the photonic sim is stateful: each worker
    /// owns its own "chip").
    pub fn start(backends: Vec<BackendFactory>, cfg: BatcherConfig) -> Coordinator {
        Coordinator::start_with_metrics(backends, cfg, Arc::new(Metrics::default()))
    }

    /// [`Coordinator::start`] with a caller-supplied metrics sink.  The
    /// drift subsystem ([`crate::drift`]) shares one [`Metrics`] between
    /// the worker loop, the drift monitor and the recalibrator, so probe
    /// residuals and hot-swap counts land next to the serving latencies.
    pub fn start_with_metrics(
        backends: Vec<BackendFactory>,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let _batcher = worker::spawn_named("cirptc-batcher", {
            let cfg = cfg.clone();
            move || batcher::run(rx, batch_tx, cfg)
        });

        let _workers = backends
            .into_iter()
            .enumerate()
            .map(|(i, factory)| {
                let rx = Arc::clone(&batch_rx);
                let metrics = Arc::clone(&metrics);
                worker::spawn_named(&format!("cirptc-worker-{i}"), move || {
                    worker::run(factory(), rx, metrics)
                })
            })
            .collect();

        Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            _batcher,
            _workers,
        }
    }

    /// Submit one image; returns a handle to await the response.
    pub fn submit(&self, image: Tensor) -> Pending {
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let sent = self
            .tx
            .send(Request { id, image, enqueued: Instant::now(), reply })
            .is_ok();
        if sent {
            self.metrics.submitted.add(1);
            self.metrics.queue_depth.add(1);
        } else {
            // batcher gone (it only exits when the coordinator is being
            // torn down): the dropped reply sender surfaces as a clean
            // "reply channel closed" error from Pending::wait, instead
            // of a panic in the submitting thread
            self.metrics.errors.add(1);
        }
        Pending { rx }
    }

    /// Submit a whole slice and wait for all responses (ordered by input).
    pub fn classify_all(&self, images: &[Tensor]) -> Result<Vec<Response>> {
        let pendings: Vec<Pending> =
            images.iter().map(|im| self.submit(im.clone())).collect();
        pendings.into_iter().map(|p| p.wait()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Backend that returns the negated channel means as "logits".
    struct MeanBackend;

    impl InferenceBackend for MeanBackend {
        fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            Ok(imgs
                .iter()
                .map(|im| {
                    let m: f32 =
                        im.data.iter().sum::<f32>() / im.numel() as f32;
                    vec![m, -m, 2.0 * m]
                })
                .collect())
        }

        fn name(&self) -> String {
            "mean".into()
        }
    }

    fn img(seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut d = vec![0.0f32; 3 * 4 * 4];
        r.fill_uniform(&mut d);
        Tensor::new(&[3, 4, 4], d)
    }

    #[test]
    fn end_to_end_single() {
        let c = Coordinator::start(
            vec![Box::new(|| Box::new(MeanBackend) as _)],
            BatcherConfig { max_batch: 4, max_wait_us: 500 },
        );
        let r = c.submit(img(1)).wait().unwrap();
        assert_eq!(r.logits.len(), 3);
        assert!((r.logits[2] - 2.0 * r.logits[0]).abs() < 1e-6);
    }

    #[test]
    fn conservation_no_request_lost_or_duplicated() {
        let c = Coordinator::start(
            vec![
                Box::new(|| Box::new(MeanBackend) as _),
                Box::new(|| Box::new(MeanBackend) as _),
            ],
            BatcherConfig { max_batch: 8, max_wait_us: 200 },
        );
        let images: Vec<Tensor> = (0..100).map(img).collect();
        let responses = c.classify_all(&images).unwrap();
        assert_eq!(responses.len(), 100);
        // every id exactly once
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100);
        assert_eq!(c.metrics.completed.get(), 100);
        assert_eq!(c.metrics.submitted.get(), 100);
    }

    #[test]
    fn responses_match_inputs() {
        let c = Coordinator::start(
            vec![Box::new(|| Box::new(MeanBackend) as _)],
            BatcherConfig { max_batch: 3, max_wait_us: 100 },
        );
        let images: Vec<Tensor> = (0..10).map(img).collect();
        let responses = c.classify_all(&images).unwrap();
        for (im, r) in images.iter().zip(&responses) {
            let m: f32 = im.data.iter().sum::<f32>() / im.numel() as f32;
            assert!((r.logits[0] - m).abs() < 1e-6, "response routed wrongly");
        }
    }

    #[test]
    fn queue_depth_drains_to_zero_and_batches_instrumented() {
        let c = Coordinator::start(
            vec![Box::new(|| Box::new(MeanBackend) as _)],
            BatcherConfig { max_batch: 4, max_wait_us: 200 },
        );
        let images: Vec<Tensor> = (0..30).map(img).collect();
        c.classify_all(&images).unwrap();
        // every admitted request has been handed to a backend
        assert_eq!(c.metrics.queue_depth.get(), 0);
        // per-batch histograms populated by the worker loop
        assert_eq!(
            c.metrics.batch_sizes.count(),
            c.metrics.batches.get() as u64
        );
        assert_eq!(
            c.metrics.batch_compute_us.count(),
            c.metrics.batches.get() as u64
        );
        // max_batch=4 caps every recorded batch size (upper edge of the
        // log2 bucket holding 4 is 7)
        assert!(c.metrics.batch_sizes.percentile(1.0) <= 7);
        let s = c.metrics.summary();
        assert!(s.contains("queue_depth=0"), "summary: {s}");
    }

    #[test]
    fn metrics_latencies_recorded() {
        let c = Coordinator::start(
            vec![Box::new(|| Box::new(MeanBackend) as _)],
            BatcherConfig { max_batch: 2, max_wait_us: 100 },
        );
        let images: Vec<Tensor> = (0..20).map(img).collect();
        c.classify_all(&images).unwrap();
        let (p50, p99) = c.metrics.latency_percentiles_us();
        assert!(p50 > 0 && p99 >= p50);
        assert!(c.metrics.batches.get() >= 10, "max_batch=2 => ≥10 batches");
    }
}
