//! L3 serving coordinator (vLLM-router-style): request intake → dynamic
//! batcher → worker pool → per-request responses, with latency/throughput
//! metrics.
//!
//! The coordinator is generic over [`InferenceBackend`], so the same
//! router/batcher serves the pure-rust digital engine, the photonic-chip
//! simulator, and the AOT XLA artifacts (`runtime::Executable`) — the
//! paper's digital-vs-CirPTC comparisons run through identical serving
//! machinery.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod scheduler;
pub mod worker;

use std::time::Instant;

use crate::bail;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{mpsc, Arc, Mutex};

use crate::obs::trace;
use crate::tensor::Tensor;
use crate::util::error::Result;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{Gauge, Histogram, Metrics, TimerGuard};
pub use pipeline::{EngineSource, PipelineConfig, Staged, StagedFactory};
pub use scheduler::TileScheduler;
pub use worker::{BackendFactory, InferenceBackend};

/// One classification request.
pub struct Request {
    pub id: u64,
    pub image: Tensor,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// The response delivered to the submitter.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub queue_us: u64,
    pub compute_us: u64,
}

/// Handle returned by [`Coordinator::submit`].
pub struct Pending {
    rx: mpsc::Receiver<Response>,
}

impl Pending {
    pub fn wait(self) -> Result<Response> {
        Ok(self.rx.recv()?)
    }
}

/// Admission-control outcome of [`Coordinator::submit`]: either the
/// request entered the queue ([`Admission::Accepted`]) or it was shed at
/// the door because `queue_cap` requests were already in flight
/// ([`Admission::Shed`]).  Shedding is the SLO-preserving alternative to
/// unbounded queueing: a rejected client learns *now* instead of holding
/// a slot whose deadline has already passed.
#[must_use = "a shed admission must be observed, or the rejection is silent"]
pub enum Admission {
    Accepted(Pending),
    Shed { id: u64 },
}

impl Admission {
    pub fn is_shed(&self) -> bool {
        matches!(self, Admission::Shed { .. })
    }

    pub fn pending(self) -> Option<Pending> {
        match self {
            Admission::Accepted(p) => Some(p),
            Admission::Shed { .. } => None,
        }
    }

    /// Wait for the response; a shed request surfaces as an error (so
    /// call sites that never configure a `queue_cap` can keep chaining
    /// `submit(..).wait()` — with `queue_cap = 0` nothing sheds).
    pub fn wait(self) -> Result<Response> {
        match self {
            Admission::Accepted(p) => p.wait(),
            Admission::Shed { id } => {
                bail!("request {id} shed: serving queue at capacity")
            }
        }
    }
}

/// The running coordinator: intake channel + batcher thread + workers.
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    next_id: AtomicU64,
    queue_cap: usize,
    pub metrics: Arc<Metrics>,
    // keep the threads alive; joined on drop
    _batcher: worker::JoinOnDrop,
    _workers: Vec<worker::JoinOnDrop>,
}

impl Coordinator {
    /// Start a coordinator over a set of backend *factories* (one worker
    /// thread per factory; each worker constructs its backend on its own
    /// thread — required because PJRT clients are thread-local (!Send),
    /// and desirable because the photonic sim is stateful: each worker
    /// owns its own "chip").
    pub fn start(backends: Vec<BackendFactory>, cfg: BatcherConfig) -> Coordinator {
        Coordinator::start_with_metrics(backends, cfg, Arc::new(Metrics::default()))
    }

    /// [`Coordinator::start`] with a caller-supplied metrics sink.  The
    /// drift subsystem ([`crate::drift`]) shares one [`Metrics`] between
    /// the worker loop, the drift monitor and the recalibrator, so probe
    /// residuals and hot-swap counts land next to the serving latencies.
    pub fn start_with_metrics(
        backends: Vec<BackendFactory>,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let _batcher = worker::spawn_named("cirptc-batcher", {
            let cfg = cfg.clone();
            move || batcher::run(rx, batch_tx, cfg)
        });

        let _workers = backends
            .into_iter()
            .enumerate()
            .map(|(i, factory)| {
                let rx = Arc::clone(&batch_rx);
                let metrics = Arc::clone(&metrics);
                worker::spawn_named(&format!("cirptc-worker-{i}"), move || {
                    worker::run(factory(), rx, metrics)
                })
            })
            .collect();

        Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            queue_cap: cfg.queue_cap,
            metrics,
            _batcher,
            _workers,
        }
    }

    /// [`Coordinator::start`], but each worker runs the three-stage
    /// pipeline executor ([`pipeline::run`]) instead of the monolithic
    /// [`worker::run`] loop: batch *i+1*'s electronic operand prep
    /// overlaps batch *i*'s chip passes, bit-identical to sequential.
    pub fn start_pipelined(
        staged: Vec<StagedFactory>,
        cfg: BatcherConfig,
    ) -> Coordinator {
        Coordinator::start_pipelined_with_metrics(
            staged,
            cfg,
            Arc::new(Metrics::default()),
        )
    }

    /// [`Coordinator::start_pipelined`] with a caller-supplied metrics
    /// sink (shared with the drift monitor/recalibrator, same as
    /// [`Coordinator::start_with_metrics`]).
    pub fn start_pipelined_with_metrics(
        staged: Vec<StagedFactory>,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let _batcher = worker::spawn_named("cirptc-batcher", {
            let cfg = cfg.clone();
            move || batcher::run(rx, batch_tx, cfg)
        });

        let _workers = staged
            .into_iter()
            .enumerate()
            .map(|(i, factory)| {
                let rx = Arc::clone(&batch_rx);
                let metrics = Arc::clone(&metrics);
                worker::spawn_named(&format!("cirptc-pipe-{i}"), move || {
                    pipeline::run(factory(), rx, metrics)
                })
            })
            .collect();

        Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            queue_cap: cfg.queue_cap,
            metrics,
            _batcher,
            _workers,
        }
    }

    /// Assemble a coordinator from externally wired parts.  The farm
    /// ([`crate::farm`]) builds its own thread topology — batcher →
    /// health router → per-chip pipelines — but serves through the same
    /// submit/shed/classify front end; `workers` joins in Vec order
    /// after `batcher`, so list threads in channel-cascade order.
    pub(crate) fn assemble(
        tx: mpsc::Sender<Request>,
        queue_cap: usize,
        metrics: Arc<Metrics>,
        batcher: worker::JoinOnDrop,
        workers: Vec<worker::JoinOnDrop>,
    ) -> Coordinator {
        Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            queue_cap,
            metrics,
            _batcher: batcher,
            _workers: workers,
        }
    }

    /// Submit one image; returns the admission outcome.  With
    /// `queue_cap = 0` (the default) every request is accepted and this
    /// behaves exactly like the pre-admission-control submit.
    pub fn submit(&self, image: Tensor) -> Admission {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if self.queue_cap > 0
            && self.metrics.queue_depth.get() >= self.queue_cap as i64
        {
            // shed at the door: counted in `submitted` (it *was* offered)
            // and `rejected`, never in `completed`/`errors`
            self.metrics.submitted.add(1);
            self.metrics.rejected.add(1);
            trace::instant("shed", "request", trace::arg1("id", id as i64));
            return Admission::Shed { id };
        }
        let (reply, rx) = mpsc::channel();
        let sent = self
            .tx
            .send(Request { id, image, enqueued: Instant::now(), reply })
            .is_ok();
        if sent {
            self.metrics.submitted.add(1);
            self.metrics.queue_depth.add(1);
            trace::instant("submit", "request", trace::arg1("id", id as i64));
        } else {
            // batcher gone (it only exits when the coordinator is being
            // torn down): the dropped reply sender surfaces as a clean
            // "reply channel closed" error from Pending::wait, instead
            // of a panic in the submitting thread
            self.metrics.errors.add(1);
        }
        Admission::Accepted(Pending { rx })
    }

    /// Submit a whole slice and wait for all responses (ordered by input).
    /// Errors if any request was shed (only possible with `queue_cap > 0`).
    pub fn classify_all(&self, images: &[Tensor]) -> Result<Vec<Response>> {
        let admissions: Vec<Admission> =
            images.iter().map(|im| self.submit(im.clone())).collect();
        admissions.into_iter().map(|a| a.wait()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Backend that returns the negated channel means as "logits".
    struct MeanBackend;

    impl InferenceBackend for MeanBackend {
        fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            Ok(imgs
                .iter()
                .map(|im| {
                    let m: f32 =
                        im.data.iter().sum::<f32>() / im.numel() as f32;
                    vec![m, -m, 2.0 * m]
                })
                .collect())
        }

        fn name(&self) -> String {
            "mean".into()
        }
    }

    fn img(seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut d = vec![0.0f32; 3 * 4 * 4];
        r.fill_uniform(&mut d);
        Tensor::new(&[3, 4, 4], d)
    }

    #[test]
    fn end_to_end_single() {
        let c = Coordinator::start(
            vec![Box::new(|| Box::new(MeanBackend) as _)],
            BatcherConfig { max_batch: 4, max_wait_us: 500, queue_cap: 0 },
        );
        let r = c.submit(img(1)).wait().unwrap();
        assert_eq!(r.logits.len(), 3);
        assert!((r.logits[2] - 2.0 * r.logits[0]).abs() < 1e-6);
    }

    #[test]
    fn conservation_no_request_lost_or_duplicated() {
        let c = Coordinator::start(
            vec![
                Box::new(|| Box::new(MeanBackend) as _),
                Box::new(|| Box::new(MeanBackend) as _),
            ],
            BatcherConfig { max_batch: 8, max_wait_us: 200, queue_cap: 0 },
        );
        let images: Vec<Tensor> = (0..100).map(img).collect();
        let responses = c.classify_all(&images).unwrap();
        assert_eq!(responses.len(), 100);
        // every id exactly once
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100);
        assert_eq!(c.metrics.completed.get(), 100);
        assert_eq!(c.metrics.submitted.get(), 100);
    }

    #[test]
    fn responses_match_inputs() {
        let c = Coordinator::start(
            vec![Box::new(|| Box::new(MeanBackend) as _)],
            BatcherConfig { max_batch: 3, max_wait_us: 100, queue_cap: 0 },
        );
        let images: Vec<Tensor> = (0..10).map(img).collect();
        let responses = c.classify_all(&images).unwrap();
        for (im, r) in images.iter().zip(&responses) {
            let m: f32 = im.data.iter().sum::<f32>() / im.numel() as f32;
            assert!((r.logits[0] - m).abs() < 1e-6, "response routed wrongly");
        }
    }

    #[test]
    fn queue_depth_drains_to_zero_and_batches_instrumented() {
        let c = Coordinator::start(
            vec![Box::new(|| Box::new(MeanBackend) as _)],
            BatcherConfig { max_batch: 4, max_wait_us: 200, queue_cap: 0 },
        );
        let images: Vec<Tensor> = (0..30).map(img).collect();
        c.classify_all(&images).unwrap();
        // every admitted request has been handed to a backend
        assert_eq!(c.metrics.queue_depth.get(), 0);
        // per-batch histograms populated by the worker loop
        assert_eq!(
            c.metrics.batch_sizes.count(),
            c.metrics.batches.get() as u64
        );
        assert_eq!(
            c.metrics.batch_compute_us.count(),
            c.metrics.batches.get() as u64
        );
        // max_batch=4 caps every recorded batch size (upper edge of the
        // log2 bucket holding 4 is 7)
        assert!(c.metrics.batch_sizes.percentile(1.0) <= 7);
        let s = c.metrics.summary();
        assert!(s.contains("queue_depth=0"), "summary: {s}");
    }

    /// Backend that reports entering each batch and then blocks until
    /// released, so the test can pin requests in the queue
    /// deterministically.
    struct GateBackend {
        entered: mpsc::Sender<usize>,
        release: mpsc::Receiver<()>,
    }

    impl InferenceBackend for GateBackend {
        fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            self.entered.send(imgs.len()).ok();
            let _ = self.release.recv();
            Ok(imgs.iter().map(|_| vec![0.0]).collect())
        }

        fn name(&self) -> String {
            "gate".into()
        }
    }

    #[test]
    fn submit_sheds_at_capacity_and_recovers() {
        let (entered_tx, entered) = mpsc::channel();
        let (release, release_rx) = mpsc::channel();
        let c = Coordinator::start(
            vec![Box::new(move || {
                Box::new(GateBackend { entered: entered_tx, release: release_rx })
                    as _
            })],
            BatcherConfig { max_batch: 1, max_wait_us: 0, queue_cap: 2 },
        );
        // first request reaches the (gated) backend: its queue_depth
        // decrement has happened by the time `entered` fires
        let a = c.submit(img(1));
        assert!(!a.is_shed());
        entered.recv().unwrap();
        // the worker is now pinned inside infer_batch, so the next two
        // admissions stay queued: depth 1, then 2 == queue_cap
        let b = c.submit(img(2));
        let d = c.submit(img(3));
        assert!(!b.is_shed() && !d.is_shed());
        // at capacity: the fourth request sheds at the door
        let e = c.submit(img(4));
        assert!(e.is_shed(), "submit above queue_cap must shed");
        assert!(e.wait().is_err(), "a shed admission reports as an error");
        assert_eq!(c.metrics.rejected.get(), 1);
        assert_eq!(c.metrics.submitted.get(), 4);
        // open the gate: every *accepted* request still completes
        for _ in 0..3 {
            release.send(()).unwrap();
        }
        for adm in [a, b, d] {
            adm.wait().unwrap();
        }
        assert_eq!(c.metrics.completed.get(), 3);
        assert_eq!(c.metrics.errors.get(), 0);
        assert_eq!(c.metrics.queue_depth.get(), 0);
    }

    #[test]
    fn metrics_latencies_recorded() {
        let c = Coordinator::start(
            vec![Box::new(|| Box::new(MeanBackend) as _)],
            BatcherConfig { max_batch: 2, max_wait_us: 100, queue_cap: 0 },
        );
        let images: Vec<Tensor> = (0..20).map(img).collect();
        c.classify_all(&images).unwrap();
        let (p50, p99) = c.metrics.latency_percentiles_us();
        assert!(p50 > 0 && p99 >= p50);
        assert!(c.metrics.batches.get() >= 10, "max_batch=2 => ≥10 batches");
    }
}
