//! Stage-pipelined worker executor (DESIGN.md §pipeline): splits each
//! batch into the engine's pre / chip / post stages and runs them on
//! three lanes, so batch *i+1*'s electronic operand prep (im2col, clamp,
//! pad, quantize + Γ-mix) overlaps batch *i*'s chip passes, and batch
//! *i−1*'s bias/activation/logits work overlaps both.
//!
//! Bit-identity with the sequential worker loop is structural, not
//! aspirational: `Engine::forward_batch` *is* `pre_batch ∘ chip_batch ∘
//! post_batch`, the chip stage is the only lane that touches the backend
//! (so the sim's pass-count drift clock advances in FIFO batch order,
//! exactly as sequentially), and the pre stage's speculative operand
//! encode is stamped with the chip's encoding generation — the chip
//! stage re-encodes inline whenever the chip moved in between
//! (`rust/tests/pipelined_path.rs` pins all of this).
//!
//! Lane layout per worker (one OS thread each, scoped to the executor):
//!
//! ```text
//!   shared batch queue ──▶ [pre]──bounded(depth)──▶ [chip]──bounded(depth)──▶ [post]──▶ replies
//!        (electronic: pack, im2col,     (crossbar passes,      (bias, relu, pool,
//!         clamp, pad, Γ-encode)          drift clock, hook)     logits, metrics)
//! ```
//!
//! The inter-stage channels are *bounded* (capacity = `depth`): if the
//! chip is the bottleneck the pre lane blocks instead of buffering
//! unboundedly, and queueing pressure stays visible to admission control
//! at the intake queue where [`super::Coordinator::submit`] can shed.

use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicI64, Ordering};
use crate::util::sync::{mpsc, Arc, Mutex};

use crate::drift::{DriftShared, EngineSlot};
use crate::obs::trace;
use crate::onn::{Backend, Engine, MidBatch, PreBatch};
use crate::simulator::EncodeSnapshot;
use crate::tensor::Tensor;
use crate::util::scratch;
use crate::util::threadpool::spawn_scoped_named;

use super::metrics::Metrics;
use super::{Batch, Request, Response};

/// Tuning for one pipelined worker.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// capacity of each inter-stage channel — how many batches a stage
    /// may run ahead of the next.  `1` (the default) already yields full
    /// three-stage overlap; larger values only smooth jittery stage
    /// times, at the cost of latency hidden from admission control.
    pub depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { depth: 1 }
    }
}

/// Attempt budget for farm redispatch: a batch that has already failed on
/// this many members is no longer offered to chip members by the router —
/// it may only land on the digital fallback lane (or, with no fallback,
/// be dropped with `errors` accounting).  Every retry site references
/// this bound (`bin/repo_lint.rs` rejects retry sends in files that
/// don't), so no retry loop is unbounded.
pub const FARM_RETRY_BUDGET: u32 = 3;

/// A pipelined worker's handle back into the farm's retry plumbing
/// (absent outside a farm): where failed batches are redispatched, and
/// the farm-wide in-flight count the router uses to decide when the
/// retry channel may close.
#[derive(Clone)]
pub struct FarmLink {
    /// this worker's member index — the router moves the origin member to
    /// the *end* of its preference order when redispatching, so a retry
    /// lands on a different healthy member whenever one exists
    pub member: usize,
    /// failed batches go back to the router tagged with their origin
    pub retry_tx: mpsc::Sender<(usize, Batch)>,
    /// batches dispatched to members and not yet terminal (replied,
    /// redispatched, or dropped).  A retry send happens *before* the
    /// decrement, so the router never observes zero while a retry from a
    /// still-counted batch is unsent (the shutdown-drain invariant).
    pub in_flight: Arc<AtomicI64>,
    /// per-batch chip-stage deadline: a pass stream exceeding it is
    /// treated as a fault (wedged backend becomes a verdict, not a hang)
    /// and the batch is redispatched
    pub deadline: Option<Duration>,
}

/// Where the pipeline reads "the engine to use for the next batch":
/// fixed, hot-swappable ([`EngineSlot`]), or the drift subsystem's shared
/// state.  Read once per batch at the *pre* stage; the same `Arc` rides
/// the batch through chip and post, so a hot swap never splits a batch
/// across engines.
pub enum EngineSource {
    Fixed(Arc<Engine>),
    Slot(Arc<EngineSlot>),
    Shared(Arc<DriftShared>),
}

impl EngineSource {
    pub fn current(&self) -> Arc<Engine> {
        match self {
            EngineSource::Fixed(e) => Arc::clone(e),
            EngineSource::Slot(s) => s.current(),
            EngineSource::Shared(d) => d.slot.current(),
        }
    }
}

/// Chip-stage hook, run after each batch's passes while the backend is
/// quiescent — exactly where the sequential [`super::worker`] loop's
/// drift monitor runs ([`crate::drift::DriftBackend`]), so probe passes
/// and recalibration triggers interleave with traffic identically.
pub type ChipHook = Box<dyn FnMut(&mut Backend) + Send>;

/// Everything one pipelined worker owns: the engine source, its private
/// backend (its own "chip"), an optional chip-stage hook and tuning.
pub struct Staged {
    pub source: EngineSource,
    pub backend: Backend,
    pub hook: Option<ChipHook>,
    pub cfg: PipelineConfig,
    /// run this hook whenever the chip lane has seen no traffic for the
    /// given interval — how a quarantined (traffic-less) member still
    /// runs its probation probes off the serving path
    pub idle: Option<(Duration, ChipHook)>,
    /// farm retry/deadline plumbing (absent for standalone pipelines)
    pub link: Option<FarmLink>,
}

impl Staged {
    pub fn new(source: EngineSource, backend: Backend) -> Staged {
        Staged {
            source,
            backend,
            hook: None,
            cfg: PipelineConfig::default(),
            idle: None,
            link: None,
        }
    }

    pub fn with_hook(mut self, hook: ChipHook) -> Staged {
        self.hook = Some(hook);
        self
    }

    pub fn with_idle(mut self, every: Duration, hook: ChipHook) -> Staged {
        self.idle = Some((every, hook));
        self
    }

    pub fn with_farm_link(mut self, link: FarmLink) -> Staged {
        self.link = Some(link);
        self
    }

    pub fn with_depth(mut self, depth: usize) -> Staged {
        self.cfg.depth = depth.max(1);
        self
    }
}

/// Constructs a [`Staged`] worker *on its own thread* (same rationale as
/// [`super::worker::BackendFactory`]: each worker owns its own chip sim).
pub type StagedFactory = Box<dyn FnOnce() -> Staged + Send>;

type Reply = (u64, Instant, mpsc::Sender<Response>);

/// A batch between pre and chip: prepped operand + everything needed to
/// answer the requests downstream.
struct PreItem {
    engine: Arc<Engine>,
    pre: PreBatch,
    replies: Vec<Reply>,
    /// original input tensors, retained so a stage failure can reassemble
    /// the requests for redispatch to a different member
    images: Vec<Tensor>,
    formed: Instant,
    pre_us: u64,
    /// delivery attempts consumed before this dispatch (see [`Batch`])
    attempts: u32,
    /// worker-local batch sequence number, stamped on the stage spans so
    /// a trace view lines the three lanes up per batch
    seq: u64,
}

/// A batch between chip and post.
struct PostItem {
    engine: Arc<Engine>,
    mid: MidBatch,
    replies: Vec<Reply>,
    images: Vec<Tensor>,
    formed: Instant,
    /// pre + chip stage time so far (µs); post adds its own share
    work_us: u64,
    attempts: u32,
    seq: u64,
}

/// Redispatch a failed batch through the farm's retry channel.  The
/// requests are reassembled from the retained reply handles and input
/// tensors — each reply sender still rides exactly one batch, so the
/// no-double-delivery argument of the FIFO chip lane is unchanged — and
/// the attempt counter is bumped.  The router stops offering the batch to
/// chip members once `attempts` reaches [`FARM_RETRY_BUDGET`]; beyond
/// that only the digital fallback lane (or the terminal drop accounting)
/// can consume it, so the retry loop is bounded.
fn requeue(
    link: &FarmLink,
    replies: Vec<Reply>,
    images: Vec<Tensor>,
    formed: Instant,
    attempts: u32,
    metrics: &Metrics,
) {
    let n = replies.len();
    let requests: Vec<Request> = replies
        .into_iter()
        .zip(images)
        .map(|((id, enqueued, reply), image)| Request {
            id,
            image,
            enqueued,
            reply,
        })
        .collect();
    let attempts = attempts + 1;
    metrics.retries.add(1);
    trace::instant(
        "retry",
        "fault",
        [("attempt", attempts as i64), ("member", link.member as i64)],
    );
    // back onto the queue-depth books: the router's drop path and the pre
    // lane's take account against queue_depth exactly like a fresh batch
    metrics.queue_depth.add(n as i64);
    let send =
        link.retry_tx.send((link.member, Batch { requests, formed, attempts }));
    if send.is_err() {
        // router already gone (teardown): terminal, same books as a
        // stage failure without a farm
        metrics.queue_depth.sub(n as i64);
        metrics.errors.add(n);
    }
    // decrement *after* the send: the router treats in-flight == 0 as
    // "no further retries can arrive" when deciding to close the lanes
    link.in_flight.fetch_sub(1, Ordering::SeqCst);
}

/// Pipelined worker loop body (runs on its own thread; the pre and post
/// lanes are scoped children of it).  Exits when the shared batch queue
/// closes, draining every in-flight batch first — accounting is
/// one-for-one with [`super::worker::run`]: a request ends in exactly one
/// of `completed` (reply sent) or `errors` (reply dropped).
pub fn run(
    staged: Staged,
    rx: Arc<Mutex<mpsc::Receiver<Batch>>>,
    metrics: Arc<Metrics>,
) {
    let Staged { source, mut backend, mut hook, cfg, mut idle, link } = staged;
    let depth = cfg.depth.max(1);
    let photonic = matches!(backend, Backend::PhotonicSim(_));
    // the chip stage publishes an encoding snapshot after each batch's
    // passes; the pre stage speculatively Γ-encodes the *next* batch
    // against it.  Generation-stamped: a stale encode is detected per
    // pass and redone inline, so this is purely a throughput lever.
    let snap: Mutex<Option<EncodeSnapshot>> = Mutex::new(match &backend {
        Backend::PhotonicSim(sim) => Some(sim.encode_snapshot()),
        Backend::Digital => None,
    });

    std::thread::scope(|s| {
        let (pre_tx, pre_rx) = mpsc::sync_channel::<PreItem>(depth);
        let (post_tx, post_rx) = mpsc::sync_channel::<PostItem>(depth);

        // ── pre lane ────────────────────────────────────────────────
        spawn_scoped_named(s, "cirptc-pre", {
            let metrics = &metrics;
            let snap = &snap;
            let source = &source;
            let link = link.clone();
            let mut seq = 0u64;
            move || loop {
                // same shared-queue discipline as worker::run: take one
                // batch under the lock, recover a poisoned lock (a dead
                // sibling must not kill the pool), release before work
                let batch = match rx
                    .lock()
                    .unwrap_or_else(|e| {
                        metrics.lock_poisons.add(1);
                        e.into_inner()
                    })
                    .recv()
                {
                    Ok(b) => b,
                    Err(_) => return, // queue closed: pre_tx drops, lanes drain
                };
                if batch.requests.is_empty() {
                    continue;
                }
                let Batch { requests, formed, attempts } = batch;
                let n = requests.len();
                // requests leave the queue the moment a worker owns them
                metrics.queue_depth.sub(n as i64);
                let mut images = Vec::with_capacity(n);
                let mut replies: Vec<Reply> = Vec::with_capacity(n);
                for req in requests {
                    // wait time is recorded once per request, on its
                    // first dispatch — a redispatched batch would skew
                    // the histogram with double counts
                    if attempts == 0 {
                        metrics.batch_wait_us.record(
                            formed.duration_since(req.enqueued).as_micros()
                                as u64,
                        );
                    }
                    images.push(req.image);
                    replies.push((req.id, req.enqueued, req.reply));
                }
                // engine read once per batch: hot swaps land *between*
                // batches; this Arc rides the batch through all stages
                let engine = source.current();
                let snap_now = snap
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .clone();
                seq += 1;
                let gen =
                    snap_now.as_ref().map(|sn| sn.generation()).unwrap_or(0);
                let span = trace::begin();
                let t = metrics.stage_pre_us.timer();
                match engine.pre_batch(&images, photonic, snap_now.as_ref()) {
                    Ok(pre) => {
                        let pre_us = t.stop();
                        trace::end(
                            span,
                            "pre",
                            "stage",
                            [("batch", seq as i64), ("gen", gen as i64)],
                        );
                        if pre_tx
                            .send(PreItem {
                                engine,
                                pre,
                                replies,
                                images,
                                formed,
                                pre_us,
                                attempts,
                                seq,
                            })
                            .is_err()
                        {
                            // chip lane gone mid-teardown: terminal for
                            // this batch (reply senders drop with it)
                            metrics.errors.add(n);
                            if let Some(l) = link.as_ref() {
                                l.in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            return;
                        }
                    }
                    Err(e) => {
                        // in a farm the batch is redispatched; standalone,
                        // fail it here: drop reply senders (receivers see
                        // a closed channel), count errors
                        eprintln!("cirptc pre stage failed: {e:#}");
                        match link.as_ref() {
                            Some(l) => requeue(
                                l, replies, images, formed, attempts, metrics,
                            ),
                            None => metrics.errors.add(n),
                        }
                    }
                }
            }
        });

        // ── post lane ───────────────────────────────────────────────
        spawn_scoped_named(s, "cirptc-post", {
            let metrics = &metrics;
            let link = link.clone();
            move || {
                for PostItem {
                    engine,
                    mid,
                    replies,
                    images,
                    formed,
                    work_us,
                    attempts,
                    seq,
                } in post_rx
                {
                    let n = replies.len();
                    let span = trace::begin();
                    let t = metrics.stage_post_us.timer();
                    match engine.post_batch(mid) {
                        Ok(all_logits) => {
                            let post_us = t.stop();
                            trace::end(
                                span,
                                "post",
                                "stage",
                                [("batch", seq as i64), ("size", n as i64)],
                            );
                            // the batch's *work* time: the sum of its
                            // three stage times (what the batch cost),
                            // not wall time (which overlaps neighbors)
                            let batch_us = (work_us + post_us).max(1);
                            metrics.batch_compute_us.record(batch_us);
                            metrics.batch_sizes.record(n as u64);
                            let compute_us = (batch_us / n as u64).max(1);
                            for ((id, enqueued, reply), logits) in
                                replies.into_iter().zip(all_logits)
                            {
                                let queue_us = formed
                                    .duration_since(enqueued)
                                    .as_micros()
                                    as u64;
                                let total =
                                    enqueued.elapsed().as_micros() as u64;
                                metrics.record_latency_us(total);
                                metrics.completed.add(1);
                                let _ = reply.send(Response {
                                    id,
                                    logits,
                                    queue_us,
                                    compute_us,
                                });
                            }
                            metrics.batches.add(1);
                            let st = scratch::stats();
                            metrics.scratch_takes.set(st.takes as i64);
                            metrics.scratch_misses.set(st.misses as i64);
                            // the batch is terminal (replies delivered):
                            // off the farm's in-flight books
                            if let Some(l) = link.as_ref() {
                                l.in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                        }
                        Err(e) => {
                            eprintln!("cirptc post stage failed: {e:#}");
                            match link.as_ref() {
                                Some(l) => requeue(
                                    l, replies, images, formed, attempts,
                                    metrics,
                                ),
                                None => metrics.errors.add(n),
                            }
                        }
                    }
                }
            }
        });

        // ── chip lane (this thread) ─────────────────────────────────
        loop {
            let item = match idle.as_mut() {
                // an idle interval is configured: poll, so a traffic-less
                // member (e.g. one the router stopped routing to) still
                // runs its probation probes off the serving path
                Some((every, idle_hook)) => match pre_rx.recv_timeout(*every) {
                    Ok(it) => it,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        idle_hook(&mut backend);
                        if let Backend::PhotonicSim(sim) = &backend {
                            *snap.lock().unwrap_or_else(|e| e.into_inner()) =
                                Some(sim.encode_snapshot());
                        }
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                },
                None => match pre_rx.recv() {
                    Ok(it) => it,
                    Err(_) => break,
                },
            };
            let PreItem {
                engine,
                pre,
                replies,
                images,
                formed,
                pre_us,
                attempts,
                seq,
            } = item;
            let n = replies.len();
            let span = trace::begin();
            let t = metrics.stage_chip_us.timer();
            match engine.chip_batch(pre, &mut backend) {
                Ok(mid) => {
                    let chip_us = t.stop();
                    trace::end(
                        span,
                        "chip",
                        "stage",
                        [("batch", seq as i64), ("size", n as i64)],
                    );
                    // monitor/recal hook sees the chip between batches,
                    // exactly like the sequential DriftBackend
                    if let Some(h) = hook.as_mut() {
                        h(&mut backend);
                    }
                    // publish the post-hook encoding state: probe passes
                    // may have ticked the drift clock, and the next
                    // batch's speculative encode must target *this*
                    if let Backend::PhotonicSim(sim) = &backend {
                        *snap.lock().unwrap_or_else(|e| e.into_inner()) =
                            Some(sim.encode_snapshot());
                    }
                    // fault verdicts: a detectable readout fault latched
                    // during this batch's passes (or the hook's probes)
                    // poisons the mid-results; a pass stream over the
                    // farm deadline marks the member wedged.  Either way
                    // the batch is redispatched, never delivered corrupt.
                    let mut fault = match &mut backend {
                        Backend::PhotonicSim(sim) => sim.take_fault_event(),
                        Backend::Digital => None,
                    };
                    if fault.is_none() {
                        if let Some(d) = link.as_ref().and_then(|l| l.deadline)
                        {
                            if chip_us as u128 > d.as_micros() {
                                fault = Some("pass_deadline");
                                if let Backend::PhotonicSim(sim) = &mut backend
                                {
                                    sim.note_fault();
                                }
                            }
                        }
                    }
                    if let Some(event) = fault {
                        eprintln!("cirptc chip stage fault: {event}");
                        trace::instant(
                            "fault",
                            "fault",
                            [("batch", seq as i64), ("size", n as i64)],
                        );
                        match link.as_ref() {
                            Some(l) => requeue(
                                l, replies, images, formed, attempts, metrics,
                            ),
                            None => metrics.errors.add(n),
                        }
                        continue;
                    }
                    let item = PostItem {
                        engine,
                        mid,
                        replies,
                        images,
                        formed,
                        work_us: pre_us + chip_us,
                        attempts,
                        seq,
                    };
                    if post_tx.send(item).is_err() {
                        // post lane gone mid-teardown: terminal
                        metrics.errors.add(n);
                        if let Some(l) = link.as_ref() {
                            l.in_flight.fetch_sub(1, Ordering::SeqCst);
                        }
                        break;
                    }
                }
                Err(e) => {
                    eprintln!("cirptc chip stage failed: {e:#}");
                    match link.as_ref() {
                        Some(l) => requeue(
                            l, replies, images, formed, attempts, metrics,
                        ),
                        None => metrics.errors.add(n),
                    }
                }
            }
        }
        // shutdown order matters: the pre lane exited (queue closed) and
        // dropped pre_tx, which ended the loop above; dropping post_tx now
        // lets the post lane drain and exit, then the scope joins both
        drop(post_tx);
    });
}

/// Convenience for the common fleet shape: `n` pipelined workers over one
/// engine source, each constructing its own backend on its own thread.
pub fn staged_fleet(
    n: usize,
    source: impl Fn() -> EngineSource + Send + Sync + 'static,
    backend: impl Fn() -> Backend + Send + Sync + 'static,
) -> Vec<StagedFactory> {
    let source = Arc::new(source);
    let backend = Arc::new(backend);
    (0..n.max(1))
        .map(|_| {
            let source = Arc::clone(&source);
            let backend = Arc::clone(&backend);
            Box::new(move || Staged::new(source(), backend())) as StagedFactory
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, Coordinator};
    use crate::data::Bundle;
    use crate::onn::Manifest;
    use crate::simulator::{ChipDescription, ChipSim};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    /// Tiny circ conv→relu→flatten→fc engine (same shape as the drift
    /// unit tests).
    fn tiny_engine(seed: u64) -> Engine {
        let manifest = Manifest::parse(
            r#"{
              "dataset": "synth_cxr", "classes": 3,
              "layers": [
                {"kind": "conv", "cin": 1, "cout": 4, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "fc", "cin": 256, "cout": 3, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0}
              ]}"#,
        )
        .unwrap();
        let mut bundle = Bundle::default();
        let mut rng = Rng::new(seed);
        let mut w0 = vec![0.0f32; 3 * 4];
        rng.fill_uniform(&mut w0);
        bundle.insert_f32("layer0.w", &[1, 3, 4], w0);
        bundle.insert_f32("layer0.b", &[4], vec![0.1; 4]);
        let mut w3 = vec![0.0f32; 64 * 4];
        rng.fill_uniform(&mut w3);
        bundle.insert_f32("layer3.w", &[1, 64, 4], w3);
        bundle.insert_f32("layer3.b", &[3], vec![0.0; 3]);
        Engine::from_parts(manifest, &bundle).unwrap()
    }

    fn img(seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut d = vec![0.0f32; 64];
        r.fill_uniform(&mut d);
        Tensor::new(&[1, 8, 8], d)
    }

    #[test]
    fn pipelined_digital_matches_per_image_oracle_and_records_stages() {
        let oracle = Arc::new(tiny_engine(5));
        let engine = Arc::clone(&oracle);
        let c = Coordinator::start_pipelined(
            vec![Box::new(move || {
                Staged::new(EngineSource::Fixed(engine), Backend::Digital)
            })],
            BatcherConfig { max_batch: 4, max_wait_us: 300, queue_cap: 0 },
        );
        let images: Vec<Tensor> = (0..24).map(img).collect();
        let responses = c.classify_all(&images).unwrap();
        assert_eq!(responses.len(), 24);
        for (im, r) in images.iter().zip(&responses) {
            let want = oracle.forward(im, &mut Backend::Digital).unwrap();
            assert_eq!(r.logits, want, "pipelined digital must be exact");
        }
        assert_eq!(c.metrics.completed.get(), 24);
        assert_eq!(c.metrics.errors.get(), 0);
        assert_eq!(c.metrics.queue_depth.get(), 0);
        // every stage lane is instrumented per batch, and the batch
        // histograms stay one-sample-per-batch like the sequential loop
        let batches = c.metrics.batches.get() as u64;
        assert!(batches >= 6, "max_batch=4 over 24 ⇒ ≥6 batches");
        assert_eq!(c.metrics.stage_pre_us.count(), batches);
        assert_eq!(c.metrics.stage_chip_us.count(), batches);
        assert_eq!(c.metrics.stage_post_us.count(), batches);
        assert_eq!(c.metrics.batch_compute_us.count(), batches);
        assert_eq!(c.metrics.batch_wait_us.count(), 24);
    }

    #[test]
    fn pipelined_photonic_matches_sequential_twin_chip() {
        // one pipelined worker over a deterministic chip; a twin sim
        // served sequentially is the oracle.  Submitting one request at a
        // time makes the batch partition deterministic (all singletons),
        // so the two pass streams line up one-to-one.
        let engine = Arc::new(tiny_engine(7));
        let desc = ChipDescription::ideal(4);
        let sim = ChipSim::deterministic(desc.clone());
        let mut twin = Backend::PhotonicSim(ChipSim::deterministic(desc));
        let c = Coordinator::start_pipelined(
            vec![{
                let engine = Arc::clone(&engine);
                Box::new(move || {
                    Staged::new(
                        EngineSource::Fixed(engine),
                        Backend::PhotonicSim(sim),
                    )
                }) as StagedFactory
            }],
            BatcherConfig { max_batch: 1, max_wait_us: 0, queue_cap: 0 },
        );
        for i in 0..8 {
            let im = img(100 + i);
            let got = c.submit(im.clone()).wait().unwrap().logits;
            let want = engine
                .forward_batch(std::slice::from_ref(&im), &mut twin)
                .unwrap();
            assert_eq!(got, want[0], "image {i}: photonic pipeline must be exact");
        }
        assert_eq!(c.metrics.errors.get(), 0);
    }

    #[test]
    fn pipeline_exits_cleanly_when_queue_closes() {
        let engine = Arc::new(tiny_engine(9));
        let (tx, rx) = mpsc::channel::<Batch>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let h = crate::coordinator::worker::spawn_named("t", {
            let rx = Arc::clone(&rx);
            let m = Arc::clone(&metrics);
            move || {
                run(
                    Staged::new(EngineSource::Fixed(engine), Backend::Digital),
                    rx,
                    m,
                )
            }
        });
        // a batch in flight while the queue closes must still be answered
        let (reply, reply_rx) = mpsc::channel();
        tx.send(Batch {
            requests: vec![crate::coordinator::Request {
                id: 3,
                image: img(3),
                enqueued: Instant::now(),
                reply,
            }],
            formed: Instant::now(),
            attempts: 0,
        })
        .unwrap();
        drop(tx);
        let resp = reply_rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("in-flight batch must drain on shutdown");
        assert_eq!(resp.id, 3);
        drop(h); // join must not hang (lane channels close in order)
        assert_eq!(metrics.completed.get(), 1);
    }

    #[test]
    fn staged_fleet_builds_n_independent_workers() {
        let engine = Arc::new(tiny_engine(11));
        let factories = staged_fleet(
            3,
            move || EngineSource::Fixed(Arc::clone(&engine)),
            || Backend::Digital,
        );
        assert_eq!(factories.len(), 3);
        let c = Coordinator::start_pipelined(
            factories,
            BatcherConfig { max_batch: 2, max_wait_us: 100, queue_cap: 0 },
        );
        let images: Vec<Tensor> = (0..12).map(img).collect();
        let responses = c.classify_all(&images).unwrap();
        assert_eq!(responses.len(), 12);
        assert_eq!(c.metrics.completed.get(), 12);
    }
}
