//! Serving metrics: counters, a lock-striped latency reservoir giving
//! p50/p99 (the numbers the classification_serving example reports),
//! per-batch latency histograms and a queue-depth gauge for the
//! batch-major worker loop.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use crate::util::sync::{Mutex, MutexGuard};
use crate::util::threadpool::WorkCounter;

/// A current-value gauge (e.g. requests admitted but not yet computed).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    /// Overwrite the current value (level gauges like drift-clock age).
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Lock-free log₂-bucketed histogram of positive integer samples
/// (microseconds, batch sizes, …).  Bucket `i` holds samples in
/// `[2^i, 2^(i+1))`; percentiles report the bucket's upper edge, so they
/// are upper bounds within a factor of two — plenty for serving
/// dashboards, and recordable from every worker without a lock.
pub struct Histogram {
    buckets: [AtomicU64; 40],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket(v: u64) -> usize {
        (63 - v.max(1).leading_zeros() as usize).min(39)
    }

    pub fn record(&self, v: u64) {
        let v = v.max(1);
        self.buckets[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate quantile: the upper edge of the bucket holding the
    /// q-th sample (0 when empty).
    pub fn percentile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((total - 1) as f64 * q) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen > rank {
                return (1u64 << (i + 1)) - 1;
            }
        }
        (1u64 << 40) - 1
    }

    /// Total of all recorded samples (each clamped to ≥1 on record).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact per-bucket counts — the raw data `summary()` rounds away.
    /// Bucket `i` holds samples in `[2^i, 2^(i+1))` (the last bucket is
    /// open-ended); [`Histogram::bucket_edge`] gives the upper edge.
    pub fn bucket_counts(&self) -> [u64; 40] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Inclusive upper edge of bucket `i` (`2^(i+1) - 1`); the final
    /// bucket is reported at its nominal edge but is open-ended.
    pub const fn bucket_edge(i: usize) -> u64 {
        (1u64 << (i + 1)) - 1
    }

    /// Start an RAII stage timer recording into this histogram: elapsed
    /// µs land on drop, or explicitly via [`TimerGuard::stop`] (which
    /// also returns the reading — the pipeline threads sum stage times
    /// into the per-batch compute figure).
    pub fn timer(&self) -> TimerGuard<'_> {
        TimerGuard { h: self, t0: Instant::now(), armed: true }
    }
}

/// RAII timer for a pipeline stage (see [`Histogram::timer`]): records
/// the elapsed µs (clamped to ≥1) exactly once — on [`TimerGuard::stop`]
/// or, if the stage unwinds early, on drop.
pub struct TimerGuard<'a> {
    h: &'a Histogram,
    t0: Instant,
    armed: bool,
}

impl TimerGuard<'_> {
    /// Record now and return the elapsed µs (disarms the drop record).
    pub fn stop(mut self) -> u64 {
        self.armed = false;
        let us = (self.t0.elapsed().as_micros() as u64).max(1);
        self.h.record(us);
        us
    }
}

impl Drop for TimerGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.h.record((self.t0.elapsed().as_micros() as u64).max(1));
        }
    }
}

/// All coordinator metrics (shared via Arc).
#[derive(Default)]
pub struct Metrics {
    pub submitted: WorkCounter,
    pub completed: WorkCounter,
    pub errors: WorkCounter,
    /// requests shed at admission: the bounded submit queue was at
    /// capacity, so the caller got an immediate
    /// [`super::Admission::Shed`] instead of unbounded queueing latency.
    /// Shed requests also count in `submitted` (they were offered), but
    /// never in `completed` or `errors`.
    pub rejected: WorkCounter,
    pub batches: WorkCounter,
    /// requests admitted (submit) minus requests handed to a backend —
    /// the live queue depth across intake channel + formed batches
    pub queue_depth: Gauge,
    /// wall time of each backend `infer_batch` call, µs (whole batch);
    /// on the pipelined path, the sum of a batch's pre+chip+post stage
    /// work (comparable, but stages of *different* batches overlap)
    pub batch_compute_us: Histogram,
    /// dispatched batch sizes (requests per batch)
    pub batch_sizes: Histogram,
    /// pipelined path, per batch: electronic pre-stage wall time
    /// (validate/pack, prefix layers, im2col + operand encode), µs
    pub stage_pre_us: Histogram,
    /// pipelined path, per batch: chip-stage wall time (the sign-split
    /// crossbar passes and inter-linear layers), µs — the stage whose
    /// share of `batch_compute_us` says where the next bottleneck is
    pub stage_chip_us: Histogram,
    /// pipelined path, per batch: electronic post-stage wall time
    /// (suffix layers + logits extraction), µs
    pub stage_post_us: Histogram,
    /// per request: time spent waiting in the batcher between submit and
    /// batch formation, µs (the deadline-batching knob's direct cost)
    pub batch_wait_us: Histogram,
    /// calibration probes executed by drift-aware workers
    /// ([`crate::drift::DriftMonitor`])
    pub probes: WorkCounter,
    /// completed recalibration + engine hot-swap cycles
    /// ([`crate::drift::Recalibrator`])
    pub recalibrations: WorkCounter,
    /// normalized probe residuals in parts-per-million (log₂ buckets)
    pub probe_residual_ppm: Histogram,
    /// most recent probe residual, ppm — the live drift signal
    pub last_probe_residual_ppm: Gauge,
    /// chip passes since the last recalibration (drift-clock age)
    pub passes_since_recal: Gauge,
    /// drift ticks applied to the worker's chip so far
    pub drift_ticks: Gauge,
    /// cumulative scratch-arena checkouts of the last reporting worker
    /// ([`crate::util::scratch`]); with `scratch_misses`, the
    /// allocs-per-batch proxy the serving benches track across PRs
    pub scratch_takes: Gauge,
    /// cumulative scratch-arena misses (checkouts that had to allocate)
    /// of the last reporting worker — flat once the arena is warm
    pub scratch_misses: Gauge,
    /// poisoned-lock recoveries: a thread panicked while holding a shared
    /// mutex and another thread took the lock anyway.  Non-zero means a
    /// worker died mid-update — the data is still structurally valid (all
    /// updates here are single `push`/`drain` calls), but the count is the
    /// signal to go look at worker logs.
    pub lock_poisons: WorkCounter,
    /// farm router: chip health-state transitions observed
    /// ([`crate::farm::ChipHealth`]) — each edge of the
    /// Healthy → Drifting → Recalibrating → … machine counts once
    pub farm_transitions: WorkCounter,
    /// farm router: batches routed *around* a recalibrating or failed
    /// chip (the preferred member was skipped, another absorbed the load)
    pub farm_rerouted: WorkCounter,
    /// farm router: batches absorbed by the fallback member because no
    /// healthy or merely-drifting chip was routable at dispatch time
    pub farm_absorbed: WorkCounter,
    /// fault injection: passes whose readout a [`crate::fault::FaultPlan`]
    /// corrupted (silent or detectable), summed across chips
    pub faults_injected: WorkCounter,
    /// farm pipeline: batch redispatches after a member failure (each
    /// consumes one unit of [`crate::coordinator::pipeline::FARM_RETRY_BUDGET`])
    pub retries: WorkCounter,
    /// supervisor verdicts that took a member out of routing
    /// ([`crate::fault::Verdict::Fail`] / `Quarantine` applied to
    /// [`crate::farm::ChipStatus`])
    pub quarantines: WorkCounter,
    /// batches served by the digital fallback lane because no photonic
    /// member was routable (graceful degradation)
    pub degraded_batches: WorkCounter,
    /// level gauge: 1 while the farm is degraded to the digital fallback
    /// (no serving-capable photonic member), else 0
    pub degraded: Gauge,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    /// Lock the latency reservoir, recovering (and counting) a poisoned
    /// lock instead of cascading the panic through every metrics reader.
    fn latencies(&self) -> MutexGuard<'_, Vec<u64>> {
        self.latencies_us.lock().unwrap_or_else(|e| {
            self.lock_poisons.add(1);
            e.into_inner()
        })
    }

    pub fn record_latency_us(&self, us: u64) {
        let mut v = self.latencies();
        // bounded reservoir: keep the most recent 100k samples
        if v.len() >= 100_000 {
            v.drain(..50_000);
        }
        v.push(us.max(1));
    }

    /// (p50, p99) end-to-end latency in µs.
    pub fn latency_percentiles_us(&self) -> (u64, u64) {
        let mut v = self.latencies().clone();
        if v.is_empty() {
            return (0, 0);
        }
        v.sort_unstable();
        let pick = |q: f64| v[((v.len() - 1) as f64 * q) as usize];
        (pick(0.5), pick(0.99))
    }

    pub fn mean_latency_us(&self) -> f64 {
        let v = self.latencies();
        if v.is_empty() {
            return 0.0;
        }
        v.iter().sum::<u64>() as f64 / v.len() as f64
    }

    /// Mean requests per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.completed.get() as f64 / b as f64
        }
    }

    /// Every counter as `(name, value)` — one stable list shared by the
    /// JSON export and the Prometheus renderer (`obs::prom`), so the two
    /// cannot drift apart.
    pub fn counters(&self) -> Vec<(&'static str, usize)> {
        vec![
            ("submitted", self.submitted.get()),
            ("completed", self.completed.get()),
            ("errors", self.errors.get()),
            ("rejected", self.rejected.get()),
            ("batches", self.batches.get()),
            ("probes", self.probes.get()),
            ("recalibrations", self.recalibrations.get()),
            ("lock_poisons", self.lock_poisons.get()),
            ("farm_transitions", self.farm_transitions.get()),
            ("farm_rerouted", self.farm_rerouted.get()),
            ("farm_absorbed", self.farm_absorbed.get()),
            ("faults_injected", self.faults_injected.get()),
            ("retries", self.retries.get()),
            ("quarantines", self.quarantines.get()),
            ("degraded_batches", self.degraded_batches.get()),
        ]
    }

    /// Every gauge as `(name, value)`.
    pub fn gauges(&self) -> Vec<(&'static str, i64)> {
        vec![
            ("queue_depth", self.queue_depth.get()),
            ("last_probe_residual_ppm", self.last_probe_residual_ppm.get()),
            ("passes_since_recal", self.passes_since_recal.get()),
            ("drift_ticks", self.drift_ticks.get()),
            ("scratch_takes", self.scratch_takes.get()),
            ("scratch_misses", self.scratch_misses.get()),
            ("degraded", self.degraded.get()),
        ]
    }

    /// Every histogram as `(name, histogram)`.
    pub fn histograms(&self) -> Vec<(&'static str, &Histogram)> {
        vec![
            ("batch_compute_us", &self.batch_compute_us),
            ("batch_sizes", &self.batch_sizes),
            ("stage_pre_us", &self.stage_pre_us),
            ("stage_chip_us", &self.stage_chip_us),
            ("stage_post_us", &self.stage_post_us),
            ("batch_wait_us", &self.batch_wait_us),
            ("probe_residual_ppm", &self.probe_residual_ppm),
        ]
    }

    /// Full-resolution structured snapshot: exact counter/gauge values
    /// and, per histogram, the exact `count`/`sum`/40 log₂ bucket counts
    /// that [`Metrics::summary`] rounds to upper edges.  This is the one
    /// shape the JSONL sampler, the `/metrics` endpoint and `--json`
    /// reports all derive from.
    pub fn export(&self) -> Json {
        let counters = self
            .counters()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        let gauges = self
            .gauges()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        let hists = self
            .histograms()
            .into_iter()
            .map(|(k, h)| {
                let buckets = h
                    .bucket_counts()
                    .iter()
                    .map(|&c| Json::Num(c as f64))
                    .collect();
                (
                    k,
                    Json::obj(vec![
                        ("count", Json::Num(h.count() as f64)),
                        ("sum", Json::Num(h.sum() as f64)),
                        ("buckets", Json::Arr(buckets)),
                    ]),
                )
            })
            .collect();
        let (p50, p99) = self.latency_percentiles_us();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
            (
                "latency_us",
                Json::obj(vec![
                    ("p50", Json::Num(p50 as f64)),
                    ("p99", Json::Num(p99 as f64)),
                    ("mean", Json::Num(self.mean_latency_us())),
                ]),
            ),
        ])
    }

    /// One-line summary for logs / benches.
    pub fn summary(&self) -> String {
        let (p50, p99) = self.latency_percentiles_us();
        format!(
            "submitted={} completed={} errors={} rejected={} batches={} \
             mean_batch={:.2} \
             p50={}µs p99={}µs queue_depth={} batch_p50≤{}µs batch_p99≤{}µs \
             pre_p99≤{}µs chip_p99≤{}µs post_p99≤{}µs wait_p99≤{}µs \
             probes={} recals={} probe_res≤{}ppm scratch_miss={}/{} \
             lock_poisons={} \
             farm_transitions={} farm_rerouted={} farm_absorbed={} \
             faults={} retries={} quarantines={} degraded={}/{}",
            self.submitted.get(),
            self.completed.get(),
            self.errors.get(),
            self.rejected.get(),
            self.batches.get(),
            self.mean_batch_size(),
            p50,
            p99,
            self.queue_depth.get(),
            self.batch_compute_us.percentile(0.5),
            self.batch_compute_us.percentile(0.99),
            self.stage_pre_us.percentile(0.99),
            self.stage_chip_us.percentile(0.99),
            self.stage_post_us.percentile(0.99),
            self.batch_wait_us.percentile(0.99),
            self.probes.get(),
            self.recalibrations.get(),
            self.probe_residual_ppm.percentile(0.99),
            self.scratch_misses.get(),
            self.scratch_takes.get(),
            self.lock_poisons.get(),
            self.farm_transitions.get(),
            self.farm_rerouted.get(),
            self.farm_absorbed.get(),
            self.faults_injected.get(),
            self.retries.get(),
            self.quarantines.get(),
            self.degraded_batches.get(),
            self.degraded.get(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        for us in 1..=100 {
            m.record_latency_us(us);
        }
        let (p50, p99) = m.latency_percentiles_us();
        assert_eq!(p50, 50);
        assert_eq!(p99, 99);
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_percentiles_us(), (0, 0));
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
    }

    #[test]
    fn reservoir_bounded() {
        let m = Metrics::default();
        for i in 0..150_000u64 {
            m.record_latency_us(i + 1);
        }
        let v = m.latencies_us.lock().unwrap();
        assert!(v.len() <= 100_000);
    }

    #[test]
    fn gauge_tracks_in_flight() {
        let g = Gauge::default();
        g.add(5);
        g.sub(2);
        assert_eq!(g.get(), 3);
        g.sub(3);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn histogram_buckets_and_percentiles() {
        let h = Histogram::default();
        assert_eq!(h.percentile(0.5), 0);
        for v in [1u64, 1, 1, 1000, 1000, 1000, 1000, 1_000_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        // p50 (rank 3) lands in the [512, 1024) bucket → upper edge 1023
        assert_eq!(h.percentile(0.5), 1023);
        // p99 (rank 6 of 8) still in the 1000 bucket; max sample's bucket
        // upper edge covers 2^20-1
        assert!(h.percentile(0.99) >= 1023);
        assert_eq!(h.percentile(1.0), (1u64 << 20) - 1);
        let expect_mean = (1.0 * 3.0 + 1000.0 * 4.0 + 1_000_000.0) / 8.0;
        assert!((h.mean() - expect_mean).abs() < 1e-6);
    }

    #[test]
    fn histogram_clamps_zero_to_one() {
        let h = Histogram::default();
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(0.5), 1);
    }

    #[test]
    fn summary_contains_counts() {
        let m = Metrics::default();
        m.submitted.add(3);
        m.completed.add(3);
        m.batches.add(1);
        m.record_latency_us(10);
        let s = m.summary();
        assert!(s.contains("submitted=3"));
        assert!(s.contains("mean_batch=3.00"));
        assert!(s.contains("probes=0"), "drift metrics in summary: {s}");
    }

    #[test]
    fn histogram_log2_bucket_edges_at_extremes() {
        // the degenerate inputs of the log₂ bucketing: 0 (clamped to 1),
        // 1 (bucket 0, upper edge 1) and u64::MAX (capped final bucket)
        let h = Histogram::default();
        h.record(0);
        h.record(1);
        h.record(u64::MAX);
        assert_eq!(h.count(), 3);
        // ranks 0 and 1 land in bucket 0 → upper edge (1<<1)-1 = 1
        assert_eq!(h.percentile(0.0), 1);
        assert_eq!(h.percentile(0.5), 1);
        // the max sample saturates the final bucket's upper edge
        assert_eq!(h.percentile(1.0), (1u64 << 40) - 1);
        // boundary values of interior buckets: 2^k sits in bucket k,
        // 2^k - 1 in bucket k-1
        let h2 = Histogram::default();
        h2.record(1024);
        assert_eq!(h2.percentile(1.0), 2047);
        let h3 = Histogram::default();
        h3.record(1023);
        assert_eq!(h3.percentile(1.0), 1023);
    }

    #[test]
    fn poisoned_reservoir_recovers_and_counts() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::default());
        let m2 = Arc::clone(&m);
        // poison the reservoir lock: panic while holding it
        let _ = std::thread::spawn(move || {
            let _g = m2.latencies_us.lock().unwrap();
            panic!("worker died mid-record");
        })
        .join();
        // readers and writers keep working, and the recovery is counted
        m.record_latency_us(7);
        assert_eq!(m.latency_percentiles_us(), (7, 7));
        assert!(m.lock_poisons.get() >= 1, "recovery must be counted");
        assert!(m.summary().contains("lock_poisons="));
    }

    #[test]
    fn timer_guard_records_on_stop_and_on_drop() {
        let h = Histogram::default();
        let us = h.timer().stop();
        assert!(us >= 1, "stop clamps to ≥1µs");
        assert_eq!(h.count(), 1, "stop records exactly once");
        {
            let _t = h.timer();
            // dropped without stop: the guard must still record
        }
        assert_eq!(h.count(), 2, "drop records a stage that unwound");
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert_eq!(h.count(), 2, "a consumed guard must not record again");
    }

    #[test]
    fn stage_and_rejection_metrics_surface_in_summary() {
        let m = Metrics::default();
        m.rejected.add(2);
        m.stage_pre_us.record(10);
        m.stage_chip_us.record(100);
        m.stage_post_us.record(5);
        m.batch_wait_us.record(50);
        let s = m.summary();
        assert!(s.contains("rejected=2"), "summary: {s}");
        assert!(s.contains("pre_p99≤15µs"), "summary: {s}");
        assert!(s.contains("chip_p99≤127µs"), "summary: {s}");
        assert!(s.contains("post_p99≤7µs"), "summary: {s}");
        assert!(s.contains("wait_p99≤63µs"), "summary: {s}");
    }

    #[test]
    fn farm_counters_surface_in_summary() {
        let m = Metrics::default();
        m.farm_transitions.add(4);
        m.farm_rerouted.add(2);
        m.farm_absorbed.add(1);
        let s = m.summary();
        assert!(s.contains("farm_transitions=4"), "summary: {s}");
        assert!(s.contains("farm_rerouted=2"), "summary: {s}");
        assert!(s.contains("farm_absorbed=1"), "summary: {s}");
    }

    #[test]
    fn fault_counters_surface_in_summary_and_export() {
        let m = Metrics::default();
        m.faults_injected.add(7);
        m.retries.add(3);
        m.quarantines.add(1);
        m.degraded_batches.add(2);
        m.degraded.set(1);
        let s = m.summary();
        assert!(s.contains("faults=7"), "summary: {s}");
        assert!(s.contains("retries=3"), "summary: {s}");
        assert!(s.contains("quarantines=1"), "summary: {s}");
        assert!(s.contains("degraded=2/1"), "summary: {s}");
        let e = m.export();
        let counter = |k: &str| {
            e.get("counters").and_then(|c| c.get(k)).and_then(Json::as_f64)
        };
        assert_eq!(counter("faults_injected"), Some(7.0));
        assert_eq!(counter("retries"), Some(3.0));
        assert_eq!(counter("quarantines"), Some(1.0));
        assert_eq!(counter("degraded_batches"), Some(2.0));
        assert_eq!(
            e.get("gauges").and_then(|g| g.get("degraded")).and_then(Json::as_f64),
            Some(1.0)
        );
    }

    #[test]
    fn gauge_set_overwrites() {
        let g = Gauge::default();
        g.add(41);
        g.set(7);
        assert_eq!(g.get(), 7);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn export_exposes_exact_buckets() {
        let m = Metrics::default();
        m.submitted.add(5);
        m.queue_depth.set(2);
        m.batch_compute_us.record(1000); // bucket 9
        m.batch_compute_us.record(1000);
        m.batch_compute_us.record(3); // bucket 1
        let e = m.export();
        assert_eq!(
            e.get("counters").and_then(|c| c.get("submitted")).and_then(Json::as_f64),
            Some(5.0)
        );
        assert_eq!(
            e.get("gauges").and_then(|g| g.get("queue_depth")).and_then(Json::as_f64),
            Some(2.0)
        );
        let h = e
            .get("histograms")
            .and_then(|h| h.get("batch_compute_us"))
            .expect("histogram present");
        assert_eq!(h.get("count").and_then(Json::as_f64), Some(3.0));
        assert_eq!(h.get("sum").and_then(Json::as_f64), Some(2003.0));
        let buckets = h.get("buckets").and_then(Json::as_arr).expect("buckets");
        assert_eq!(buckets.len(), 40);
        assert_eq!(buckets[9].as_f64(), Some(2.0));
        assert_eq!(buckets[1].as_f64(), Some(1.0));
        // the exact buckets round-trip through the dump/parse cycle the
        // sampler and /metrics endpoint rely on
        let parsed = Json::parse(&e.dump()).expect("export parses");
        assert_eq!(
            parsed
                .get("histograms")
                .and_then(|h| h.get("batch_compute_us"))
                .and_then(|h| h.get("sum"))
                .and_then(Json::as_f64),
            Some(2003.0)
        );
    }

    #[test]
    fn histogram_accessors_match_records() {
        let h = Histogram::default();
        for v in [1u64, 2, 2, 1024] {
            h.record(v);
        }
        let b = h.bucket_counts();
        assert_eq!(b[0], 1);
        assert_eq!(b[1], 2);
        assert_eq!(b[10], 1);
        assert_eq!(b.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum(), 1029);
        assert_eq!(Histogram::bucket_edge(0), 1);
        assert_eq!(Histogram::bucket_edge(10), 2047);
    }

    #[test]
    fn gauge_consistent_under_concurrent_worker_updates() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::default());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        m.queue_depth.add(3);
                        m.queue_depth.sub(3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            m.queue_depth.get(),
            0,
            "matched add/sub from 8 workers must cancel exactly"
        );
    }

    #[test]
    fn histogram_consistent_under_concurrent_records() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::default());
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..5_000u64 {
                        m.probe_residual_ppm.record(1 + (t * 5_000 + i) % 64);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.probe_residual_ppm.count(), 20_000);
        // every sample is ≤ 64 → everything below the bucket-6 upper edge
        assert!(m.probe_residual_ppm.percentile(1.0) <= 127);
    }
}
