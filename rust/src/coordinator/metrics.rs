//! Serving metrics: counters + a lock-striped latency reservoir giving
//! p50/p99 (the numbers the classification_serving example reports).

use std::sync::Mutex;

use crate::util::threadpool::WorkCounter;

/// All coordinator metrics (shared via Arc).
#[derive(Default)]
pub struct Metrics {
    pub submitted: WorkCounter,
    pub completed: WorkCounter,
    pub errors: WorkCounter,
    pub batches: WorkCounter,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn record_latency_us(&self, us: u64) {
        let mut v = self.latencies_us.lock().unwrap();
        // bounded reservoir: keep the most recent 100k samples
        if v.len() >= 100_000 {
            v.drain(..50_000);
        }
        v.push(us.max(1));
    }

    /// (p50, p99) end-to-end latency in µs.
    pub fn latency_percentiles_us(&self) -> (u64, u64) {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return (0, 0);
        }
        v.sort_unstable();
        let pick = |q: f64| v[((v.len() - 1) as f64 * q) as usize];
        (pick(0.5), pick(0.99))
    }

    pub fn mean_latency_us(&self) -> f64 {
        let v = self.latencies_us.lock().unwrap();
        if v.is_empty() {
            return 0.0;
        }
        v.iter().sum::<u64>() as f64 / v.len() as f64
    }

    /// Mean requests per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.completed.get() as f64 / b as f64
        }
    }

    /// One-line summary for logs / benches.
    pub fn summary(&self) -> String {
        let (p50, p99) = self.latency_percentiles_us();
        format!(
            "submitted={} completed={} errors={} batches={} mean_batch={:.2} \
             p50={}µs p99={}µs",
            self.submitted.get(),
            self.completed.get(),
            self.errors.get(),
            self.batches.get(),
            self.mean_batch_size(),
            p50,
            p99
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        for us in 1..=100 {
            m.record_latency_us(us);
        }
        let (p50, p99) = m.latency_percentiles_us();
        assert_eq!(p50, 50);
        assert_eq!(p99, 99);
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.latency_percentiles_us(), (0, 0));
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
    }

    #[test]
    fn reservoir_bounded() {
        let m = Metrics::default();
        for i in 0..150_000u64 {
            m.record_latency_us(i + 1);
        }
        let v = m.latencies_us.lock().unwrap();
        assert!(v.len() <= 100_000);
    }

    #[test]
    fn summary_contains_counts() {
        let m = Metrics::default();
        m.submitted.add(3);
        m.completed.add(3);
        m.batches.add(1);
        m.record_latency_us(10);
        let s = m.summary();
        assert!(s.contains("submitted=3"));
        assert!(s.contains("mean_batch=3.00"));
    }
}
