//! Tile scheduler: maps the BCM tiles of a large MVM onto a farm of
//! (simulated) CirPTC chips, respecting each chip's physical size and the
//! weight-reprogramming cost (paper: weights are "shared and remain
//! constant during the inference phase", so the scheduler maximises tile
//! reuse before reprogramming — time-domain hardware reuse).

use crate::arch::CirPtcConfig;

/// One unit of chip work: a (P_t × Q_t) sub-BCM against a batch column set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tile {
    /// block-row range [p0, p1) of the full BCM
    pub p0: usize,
    pub p1: usize,
    /// block-col range [q0, q1)
    pub q0: usize,
    pub q1: usize,
    /// chip this tile is assigned to
    pub chip: usize,
    /// sequence number on that chip (weights reprogrammed when it changes)
    pub step: usize,
}

/// Schedule description for one MVM.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub tiles: Vec<Tile>,
    pub chips: usize,
    /// weight reprogramming events (tile loads)
    pub reprograms: usize,
}

/// Static tile scheduler over identical chips.
pub struct TileScheduler {
    pub chip: CirPtcConfig,
    pub n_chips: usize,
}

impl TileScheduler {
    pub fn new(chip: CirPtcConfig, n_chips: usize) -> TileScheduler {
        assert!(n_chips >= 1);
        TileScheduler { chip, n_chips }
    }

    /// Tile capacity of one chip in block units.
    fn cap(&self) -> (usize, usize) {
        (self.chip.m / self.chip.l, self.chip.effective_n() / self.chip.l)
    }

    /// Partition a (P × Q)-block BCM into chip-sized tiles, round-robin
    /// across chips; per-chip step counts weight loads.
    pub fn schedule(&self, p_blocks: usize, q_blocks: usize) -> Schedule {
        let (cap_p, cap_q) = self.cap();
        assert!(cap_p > 0 && cap_q > 0);
        let mut tiles = Vec::new();
        let mut steps = vec![0usize; self.n_chips];
        let mut rr = 0usize;
        for p0 in (0..p_blocks).step_by(cap_p) {
            for q0 in (0..q_blocks).step_by(cap_q) {
                let chip = rr % self.n_chips;
                tiles.push(Tile {
                    p0,
                    p1: (p0 + cap_p).min(p_blocks),
                    q0,
                    q1: (q0 + cap_q).min(q_blocks),
                    chip,
                    step: steps[chip],
                });
                steps[chip] += 1;
                rr += 1;
            }
        }
        Schedule {
            reprograms: tiles.len(),
            tiles,
            chips: self.n_chips,
        }
    }

    /// Estimated MVM latency (cycles) for the schedule with `batch`
    /// input columns: per tile, weight-load cost + one cycle per column;
    /// chips run in parallel.
    pub fn estimated_cycles(
        &self,
        sched: &Schedule,
        batch: usize,
        weight_load_cycles: usize,
    ) -> usize {
        let mut per_chip = vec![0usize; self.n_chips];
        for t in &sched.tiles {
            per_chip[t.chip] += weight_load_cycles + batch;
        }
        per_chip.into_iter().max().unwrap_or(0)
    }
}

/// Verify a schedule covers every block exactly once (test invariant).
pub fn covers_exactly_once(sched: &Schedule, p_blocks: usize, q_blocks: usize) -> bool {
    let mut cover = vec![0u8; p_blocks * q_blocks];
    for t in &sched.tiles {
        for p in t.p0..t.p1 {
            for q in t.q0..t.q1 {
                cover[p * q_blocks + q] += 1;
            }
        }
    }
    cover.iter().all(|&c| c == 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck;

    fn chip() -> CirPtcConfig {
        CirPtcConfig { n: 16, m: 16, l: 4, fold: 1, f_op: 1e9 }
    }

    #[test]
    fn exact_fit_single_tile() {
        let s = TileScheduler::new(chip(), 1).schedule(4, 4);
        assert_eq!(s.tiles.len(), 1);
        assert!(covers_exactly_once(&s, 4, 4));
    }

    #[test]
    fn larger_matrix_tiles_and_covers() {
        propcheck::check("schedule covers exactly once", 60, |g| {
            let p = g.usize_in(1, 20);
            let q = g.usize_in(1, 20);
            let chips = g.usize_in(1, 4);
            let s = TileScheduler::new(chip(), chips).schedule(p, q);
            prop_assert!(covers_exactly_once(&s, p, q), "p={p} q={q}");
            Ok(())
        });
    }

    #[test]
    fn multi_chip_balances() {
        let s = TileScheduler::new(chip(), 4).schedule(16, 16);
        // 16 tiles round-robin across 4 chips => 4 each
        let mut per = [0usize; 4];
        for t in &s.tiles {
            per[t.chip] += 1;
        }
        assert_eq!(per, [4, 4, 4, 4]);
    }

    #[test]
    fn more_chips_fewer_cycles() {
        let sched1 = TileScheduler::new(chip(), 1);
        let sched4 = TileScheduler::new(chip(), 4);
        let s1 = sched1.schedule(16, 16);
        let s4 = sched4.schedule(16, 16);
        let c1 = sched1.estimated_cycles(&s1, 32, 10);
        let c4 = sched4.estimated_cycles(&s4, 32, 10);
        assert!(c4 < c1, "{c4} !< {c1}");
        assert_eq!(c4 * 4, c1, "perfect balance at this size");
    }

    #[test]
    fn steps_monotone_per_chip() {
        let s = TileScheduler::new(chip(), 2).schedule(8, 8);
        let mut last = vec![None::<usize>; 2];
        for t in &s.tiles {
            if let Some(prev) = last[t.chip] {
                assert_eq!(t.step, prev + 1);
            } else {
                assert_eq!(t.step, 0);
            }
            last[t.chip] = Some(t.step);
        }
    }
}
