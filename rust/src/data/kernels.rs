//! The convolution kernels demonstrated on-chip in paper Fig. 3: blur,
//! Sobel (vertical/horizontal), sharpen, emboss — plus the block-circulant
//! extension that lets an *arbitrary* kernel run on CirPTC by targeting a
//! single crossbar column (paper Supplementary Note 5: "we can still
//! implement arbitrary kernels by exclusively targeting one column in the
//! crossbar array after block-circulant extension").

use crate::circulant::Bcm;
use crate::tensor::Tensor;

/// A named 3×3 image kernel.
#[derive(Clone, Debug)]
pub struct ImageKernel {
    pub name: &'static str,
    pub k: [f32; 9],
}

pub fn blur() -> ImageKernel {
    ImageKernel { name: "blur", k: [1.0 / 9.0; 9] }
}

pub fn sobel_v() -> ImageKernel {
    ImageKernel {
        name: "sobel_v",
        k: [-1.0, 0.0, 1.0, -2.0, 0.0, 2.0, -1.0, 0.0, 1.0],
    }
}

pub fn sobel_h() -> ImageKernel {
    ImageKernel {
        name: "sobel_h",
        k: [-1.0, -2.0, -1.0, 0.0, 0.0, 0.0, 1.0, 2.0, 1.0],
    }
}

pub fn sharpen() -> ImageKernel {
    ImageKernel {
        name: "sharpen",
        k: [0.0, -1.0, 0.0, -1.0, 5.0, -1.0, 0.0, -1.0, 0.0],
    }
}

pub fn emboss() -> ImageKernel {
    ImageKernel {
        name: "emboss",
        k: [-2.0, -1.0, 0.0, -1.0, 1.0, 1.0, 0.0, 1.0, 2.0],
    }
}

/// The four kernels applied to the CXR image in paper Fig. 3e.
pub fn fig3e_kernels() -> Vec<ImageKernel> {
    vec![blur(), sobel_v(), sobel_h(), sharpen()]
}

/// Block-circulant extension of one arbitrary 3×3 kernel: the 9 taps are
/// zero-padded to 12 (the paper's "addition of 3 rows of padding") and laid
/// out as a (1, 3, 4) compressed BCM whose *first dense row* equals the
/// padded kernel — so the kernel's exact output appears on dense row 0
/// (one crossbar column), and rows 1..3 carry the circulant replicas.
pub fn extend_kernel(k: &ImageKernel, l: usize) -> Bcm {
    let n_pad = (9 + l - 1) / l * l;
    let q = n_pad / l;
    let mut w = vec![0.0f32; q * l];
    w[..9].copy_from_slice(&k.k);
    Bcm::new(1, q, l, w)
}

/// Dense weight-matrix form (Cout rows = kernels) for digital reference.
pub fn kernels_to_matrix(ks: &[ImageKernel]) -> Tensor {
    let mut data = Vec::with_capacity(ks.len() * 9);
    for k in ks {
        data.extend_from_slice(&k.k);
    }
    Tensor::new(&[ks.len(), 9], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{conv2d, im2col};
    use crate::util::rng::Rng;

    #[test]
    fn blur_sums_to_one() {
        assert!((blur().k.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn sobel_sums_to_zero() {
        assert!(sobel_v().k.iter().sum::<f32>().abs() < 1e-6);
        assert!(sobel_h().k.iter().sum::<f32>().abs() < 1e-6);
    }

    #[test]
    fn extension_first_row_is_kernel() {
        let b = extend_kernel(&sobel_v(), 4);
        let dense = b.expand();
        assert_eq!(dense.shape, vec![4, 12]);
        for (i, &tap) in sobel_v().k.iter().enumerate() {
            assert_eq!(dense.at2(0, i), tap);
        }
        for i in 9..12 {
            assert_eq!(dense.at2(0, i), 0.0, "padding column {i}");
        }
    }

    #[test]
    fn extended_kernel_convolves_exactly() {
        // one-channel image: BCM row 0 on padded im2col == direct conv
        let mut r = Rng::new(3);
        let mut img = vec![0.0f32; 8 * 8];
        r.fill_uniform(&mut img);
        let img = Tensor::new(&[1, 8, 8], img);
        let k = sharpen();
        let want = conv2d(&img, &kernels_to_matrix(&[k.clone()]), 3, false);

        let bcm = extend_kernel(&k, 4);
        let xm = im2col(&img, 3);
        // pad patch matrix rows 9 -> 12
        let cols = xm.shape[1];
        let mut xp = Tensor::zeros(&[12, cols]);
        xp.data[..9 * cols].copy_from_slice(&xm.data);
        let y = bcm.matmul(&xp);
        for c in 0..cols {
            assert!((y.at2(0, c) - want.data[c]).abs() < 1e-5);
        }
    }

    #[test]
    fn fig3e_has_four_kernels() {
        assert_eq!(fig3e_kernels().len(), 4);
    }
}
