//! Data layer: the CPT1 tensor-bundle interchange format and the synthetic
//! dataset generators (rust mirrors of `python/compile/data.py`).

pub mod bundle;
pub mod datasets;
pub mod kernels;

pub use bundle::Bundle;
