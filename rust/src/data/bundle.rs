//! CPT1 tensor-bundle reader/writer — the python↔rust weight interchange
//! (format spec in `python/compile/export.py`):
//!
//! ```text
//! magic  b"CPT1"
//! u32    n_tensors
//! repeat: u32 name_len; name; u8 dtype(0=f32,1=i32); u8 ndim; u32[ndim]; data
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

/// One named tensor in a bundle.
#[derive(Clone, Debug, PartialEq)]
pub enum Entry {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Entry {
    pub fn shape(&self) -> &[usize] {
        match self {
            Entry::F32 { shape, .. } | Entry::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Entry::F32 { data, .. } => Ok(data),
            Entry::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Entry::I32 { data, .. } => Ok(data),
            Entry::F32 { .. } => bail!("tensor is f32, expected i32"),
        }
    }
}

/// A named-tensor bundle.
#[derive(Clone, Debug, Default)]
pub struct Bundle {
    pub tensors: BTreeMap<String, Entry>,
}

const MAGIC: &[u8; 4] = b"CPT1";

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

impl Bundle {
    pub fn load(path: &Path) -> Result<Bundle> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = std::io::BufReader::new(file);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: bad magic {:?}", path.display(), magic);
        }
        let n = read_u32(&mut r)?;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name utf-8")?;
            let dtype = read_u8(&mut r)?;
            let ndim = read_u8(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut r)? as usize);
            }
            let count: usize = shape.iter().product::<usize>().max(1);
            let mut raw = vec![0u8; count * 4];
            r.read_exact(&mut raw)?;
            let entry = match dtype {
                0 => Entry::F32 {
                    shape,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                },
                1 => Entry::I32 {
                    shape,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                },
                d => bail!("unknown dtype {d} for tensor {name}"),
            };
            tensors.insert(name, entry);
        }
        Ok(Bundle { tensors })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, entry) in &self.tensors {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            match entry {
                Entry::F32 { shape, data } => {
                    w.write_all(&[0u8, shape.len() as u8])?;
                    for d in shape {
                        w.write_all(&(*d as u32).to_le_bytes())?;
                    }
                    for v in data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
                Entry::I32 { shape, data } => {
                    w.write_all(&[1u8, shape.len() as u8])?;
                    for d in shape {
                        w.write_all(&(*d as u32).to_le_bytes())?;
                    }
                    for v in data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn insert_f32(&mut self, name: &str, shape: &[usize], data: Vec<f32>) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.tensors
            .insert(name.into(), Entry::F32 { shape: shape.to_vec(), data });
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor '{name}' missing from bundle"))
    }

    /// f32 tensor accessor with shape check.
    pub fn f32_checked(&self, name: &str, shape: &[usize]) -> Result<&[f32]> {
        let e = self.get(name)?;
        if e.shape() != shape {
            bail!("tensor '{name}': shape {:?}, expected {shape:?}", e.shape());
        }
        e.as_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut b = Bundle::default();
        b.insert_f32("a.w", &[2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-9, 7.0]);
        b.tensors.insert(
            "labels".into(),
            Entry::I32 { shape: vec![4], data: vec![0, 1, 2, 3] },
        );
        let dir = std::env::temp_dir().join("cirptc_bundle_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.cpt");
        b.save(&path).unwrap();
        let back = Bundle::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.get("a.w").unwrap(), b.get("a.w").unwrap());
        assert_eq!(back.get("labels").unwrap().as_i32().unwrap(), &[0, 1, 2, 3]);
    }

    #[test]
    fn missing_tensor_errors() {
        let b = Bundle::default();
        assert!(b.get("nope").is_err());
    }

    #[test]
    fn shape_check() {
        let mut b = Bundle::default();
        b.insert_f32("x", &[2, 2], vec![0.0; 4]);
        assert!(b.f32_checked("x", &[2, 2]).is_ok());
        assert!(b.f32_checked("x", &[4]).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("cirptc_bundle_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.cpt");
        std::fs::write(&path, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(Bundle::load(&path).is_err());
    }
}
