//! Synthetic dataset generators — rust mirrors of `python/compile/data.py`
//! (same class structure; exact bitwise parity with numpy is not required
//! because the *served* test sets are exported by python into
//! `artifacts/models/*_testset.cpt`; these generators power rust-only
//! workloads: the Fig. 3 image-processing bench and load generation).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A labelled image-classification split.
#[derive(Clone, Debug)]
pub struct Split {
    /// (n, c, h, w) row-major
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub classes: usize,
}

impl Split {
    pub fn image(&self, i: usize) -> Tensor {
        let sz = self.c * self.h * self.w;
        Tensor::new(&[self.c, self.h, self.w],
                    self.images[i * sz..(i + 1) * sz].to_vec())
    }
}

/// The order-4 StrC stack for the 16×16 [`synth_shapes`] set (the same
/// topology family as python `model.net_config`).  One shared source so
/// the HAT example, the serving bench's drift scenario and the
/// train/drift e2e tests all train and serve the *same* model.
pub const SHAPES_MANIFEST_JSON: &str = r#"{
  "dataset": "synth_shapes", "classes": 3,
  "layers": [
    {"kind": "conv", "cin": 1, "cout": 8, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 4.0},
    {"kind": "bn", "cin": 8, "cout": 0, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 4.0},
    {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 4.0},
    {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 4.0},
    {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 4.0},
    {"kind": "fc", "cin": 512, "cout": 3, "k": 3, "pool": 2,
     "arch": "circ", "l": 4, "act_scale": 4.0}
  ]}"#;

const GLYPHS: [[u8; 7]; 10] = [
    // 5-bit rows, MSB = left column (mirrors python _DIGIT_GLYPHS)
    [0b11111, 0b10001, 0b10001, 0b10001, 0b10001, 0b10001, 0b11111],
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110],
    [0b11111, 0b00001, 0b00001, 0b11111, 0b10000, 0b10000, 0b11111],
    [0b11111, 0b00001, 0b00001, 0b01111, 0b00001, 0b00001, 0b11111],
    [0b10001, 0b10001, 0b10001, 0b11111, 0b00001, 0b00001, 0b00001],
    [0b11111, 0b10000, 0b10000, 0b11111, 0b00001, 0b00001, 0b11111],
    [0b11111, 0b10000, 0b10000, 0b11111, 0b10001, 0b10001, 0b11111],
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000],
    [0b11111, 0b10001, 0b10001, 0b11111, 0b10001, 0b10001, 0b11111],
    [0b11111, 0b10001, 0b10001, 0b11111, 0b00001, 0b00001, 0b11111],
];

/// SVHN stand-in: colored digit glyphs on textured backgrounds.
pub fn synth_digits(n: usize, seed: u64) -> Split {
    let (c, sz) = (3usize, 32usize);
    let mut rng = Rng::new(seed);
    let mut images = vec![0.0f32; n * c * sz * sz];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let digit = rng.below(10);
        labels[i] = digit as u8;
        let img = &mut images[i * c * sz * sz..(i + 1) * c * sz * sz];
        for v in img.iter_mut() {
            *v = rng.range(0.0, 0.35) as f32;
        }
        let scale = rng.int_in(2, 3) as usize;
        let (gh, gw) = (7 * scale, 5 * scale);
        let r0 = rng.below(sz - gh + 1);
        let c0 = rng.below(sz - gw + 1);
        let color: Vec<f32> = (0..3).map(|_| rng.range(0.6, 1.0) as f32).collect();
        for gy in 0..gh {
            for gx in 0..gw {
                let on = GLYPHS[digit][gy / scale] >> (4 - gx / scale) & 1 == 1;
                if on {
                    for ch in 0..3 {
                        img[ch * sz * sz + (r0 + gy) * sz + c0 + gx] = color[ch];
                    }
                }
            }
        }
        for v in img.iter_mut() {
            *v = (*v + rng.normal_f32(0.0, 0.05)).clamp(0.0, 1.0);
        }
    }
    Split { images, labels, n, c, h: sz, w: sz, classes: 10 }
}

/// CIFAR-10 stand-in: oriented/frequency Gabor-texture classes.
pub fn synth_textures(n: usize, seed: u64) -> Split {
    let (c, sz) = (3usize, 32usize);
    let mut rng = Rng::new(seed);
    let mut images = vec![0.0f32; n * c * sz * sz];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let class = rng.below(10);
        labels[i] = class as u8;
        let theta = std::f64::consts::PI * (class % 5) as f64 / 5.0
            + rng.normal() * 0.08;
        let freq = [2.0, 4.0][class / 5] * rng.range(0.9, 1.1);
        let phase = rng.range(0.0, 2.0 * std::f64::consts::PI);
        let tint: Vec<f64> = (0..3).map(|_| rng.range(0.7, 1.0)).collect();
        let img = &mut images[i * c * sz * sz..(i + 1) * c * sz * sz];
        for y in 0..sz {
            for x in 0..sz {
                let u = theta.cos() * (x as f64 / sz as f64)
                    + theta.sin() * (y as f64 / sz as f64);
                let base = 0.5
                    + 0.45 * (2.0 * std::f64::consts::PI * freq * u + phase).sin();
                for ch in 0..3 {
                    img[ch * sz * sz + y * sz + x] = (base * tint[ch]) as f32;
                }
            }
        }
        for v in img.iter_mut() {
            *v = (*v + rng.normal_f32(0.0, 0.08)).clamp(0.0, 1.0);
        }
    }
    Split { images, labels, n, c, h: sz, w: sz, classes: 10 }
}

/// COVID-QU-Ex stand-in: 3-class grayscale CXR-like images
/// (0 normal / 1 diffuse "covid" haze / 2 focal opacities).
pub fn synth_cxr(n: usize, seed: u64) -> Split {
    let sz = 64usize;
    let mut rng = Rng::new(seed);
    let mut images = vec![0.0f32; n * sz * sz];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let class = rng.below(3);
        labels[i] = class as u8;
        let gain = rng.range(0.9, 1.1);
        let img = &mut images[i * sz * sz..(i + 1) * sz * sz];
        for y in 0..sz {
            for x in 0..sz {
                let (xf, yf) = (x as f64 / sz as f64, y as f64 / sz as f64);
                let mut v = 0.15 + 0.1 * yf;
                for cx in [0.32, 0.68] {
                    let d = ((xf - cx) / 0.18).powi(2)
                        + ((yf - 0.52) / 0.32).powi(2);
                    v += 0.55 * (-d * 1.5).exp();
                }
                img[y * sz + x] = (v * gain) as f32;
            }
        }
        match class {
            1 => {
                let haze = rng.range(0.12, 0.25);
                let th = rng.range(0.0, std::f64::consts::PI);
                for y in 0..sz {
                    for x in 0..sz {
                        let u = th.cos() * (x as f64 / sz as f64)
                            + th.sin() * (y as f64 / sz as f64);
                        img[y * sz + x] += (haze
                            * (0.6
                                + 0.4
                                    * (2.0 * std::f64::consts::PI * 3.0 * u)
                                        .sin()))
                            as f32;
                    }
                }
            }
            2 => {
                for _ in 0..rng.int_in(1, 3) {
                    let cx = rng.range(0.2, 0.8);
                    let cy = rng.range(0.3, 0.75);
                    let rad = rng.range(0.05, 0.12);
                    for y in 0..sz {
                        for x in 0..sz {
                            let d = ((x as f64 / sz as f64 - cx).powi(2)
                                + (y as f64 / sz as f64 - cy).powi(2))
                                / (rad * rad);
                            img[y * sz + x] += (0.35 * (-d).exp()) as f32;
                        }
                    }
                }
            }
            _ => {}
        }
        for v in img.iter_mut() {
            *v = (*v + rng.normal_f32(0.0, 0.04)).clamp(0.0, 1.0);
        }
    }
    Split { images, labels, n, c: 1, h: sz, w: sz, classes: 3 }
}

/// Rust-native quick-training set for the `make train-smoke` workload:
/// 1×16×16 images, 3 classes of oriented sinusoid stripes (0 horizontal /
/// 1 vertical / 2 diagonal) with frequency, phase and amplitude jitter
/// plus additive noise.  Small enough that the chip-in-the-loop HAT loop
/// ([`crate::train`]) separates the classes in a few dozen minibatches.
pub fn synth_shapes(n: usize, seed: u64) -> Split {
    let sz = 16usize;
    let mut rng = Rng::new(seed);
    let mut images = vec![0.0f32; n * sz * sz];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let class = rng.below(3);
        labels[i] = class as u8;
        let freq = rng.range(1.6, 2.4);
        let phase = rng.range(0.0, 2.0 * std::f64::consts::PI);
        let amp = rng.range(0.35, 0.48);
        let img = &mut images[i * sz * sz..(i + 1) * sz * sz];
        for y in 0..sz {
            for x in 0..sz {
                let u = match class {
                    0 => y as f64,
                    1 => x as f64,
                    _ => (x + y) as f64 * std::f64::consts::FRAC_1_SQRT_2,
                } / sz as f64;
                let v = 0.5
                    + amp * (2.0 * std::f64::consts::PI * freq * u + phase).sin();
                img[y * sz + x] =
                    (v + rng.normal() * 0.03).clamp(0.0, 1.0) as f32;
            }
        }
    }
    Split { images, labels, n, c: 1, h: sz, w: sz, classes: 3 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_well_formed() {
        let s = synth_digits(64, 1);
        assert_eq!(s.images.len(), 64 * 3 * 32 * 32);
        assert!(s.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(s.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = synth_textures(16, 7);
        let b = synth_textures(16, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = synth_textures(16, 8);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn cxr_classes_distinguishable() {
        // class means should differ: haze/opacity add brightness
        let s = synth_cxr(150, 3);
        let mut sums = [0.0f64; 3];
        let mut counts = [0usize; 3];
        for i in 0..s.n {
            let img = &s.images[i * 64 * 64..(i + 1) * 64 * 64];
            sums[s.labels[i] as usize] +=
                img.iter().map(|&v| v as f64).sum::<f64>();
            counts[s.labels[i] as usize] += 1;
        }
        let mean =
            |k: usize| sums[k] / (counts[k].max(1) as f64 * 64.0 * 64.0);
        assert!(mean(1) > mean(0) + 0.02, "haze brighter than normal");
        assert!(mean(2) > mean(0), "opacities brighter than normal");
    }

    #[test]
    fn all_classes_generated() {
        let s = synth_digits(200, 5);
        let mut seen = [false; 10];
        for &l in &s.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn image_accessor_shape() {
        let s = synth_cxr(4, 9);
        let img = s.image(2);
        assert_eq!(img.shape, vec![1, 64, 64]);
    }

    #[test]
    fn shapes_well_formed_and_deterministic() {
        let s = synth_shapes(64, 11);
        assert_eq!(s.images.len(), 64 * 16 * 16);
        assert_eq!((s.c, s.h, s.w, s.classes), (1, 16, 16, 3));
        assert!(s.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mut seen = [false; 3];
        for &l in &s.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&x| x), "all 3 classes generated");
        let s2 = synth_shapes(64, 11);
        assert_eq!(s.images, s2.images);
    }
}
