//! Zero-downtime recalibration: the background half of the drift
//! subsystem (rust twin of `python/compile/recalib.py`, but *on-line* —
//! it runs while the coordinator keeps serving).
//!
//! When the [`super::DriftMonitor`] trigger fires, the [`Recalibrator`]
//! receives the drifted [`crate::simulator::ChipDescription`] snapshot
//! and, on its own thread:
//!
//! 1. optionally writes the snapshot to disk for attribution
//!    (`ChipDescription::save`; loaded back through the path-attributed
//!    `ChipDescription::load`);
//! 2. runs a **bounded** number of chip-in-the-loop fine-tune steps
//!    against a simulator pinned to the drifted operating point
//!    ([`crate::train::TrainBackend::Chip`] — noisy forward,
//!    deterministic-surrogate gradients);
//! 3. recomputes exact BN statistics at that operating point
//!    ([`crate::train::TrainModel::recalibrate_bn`], the paper's one-shot
//!    calibration);
//! 4. builds a fresh [`Engine`] from the fine-tuned weights and **hot
//!    swaps** it into the shared [`super::EngineSlot`] — workers pick it
//!    up between drained batches, so no request is ever dropped or
//!    stalled.
//!
//! The recalibrator owns the canonical [`TrainModel`]: serving weights
//! only ever change through it, so the trainable copy never goes stale.

use std::path::PathBuf;
use crate::util::sync::{mpsc, Arc, PoisonError};

use crate::coordinator::worker::{spawn_named, JoinOnDrop};
use crate::data::datasets::Split;
use crate::obs::trace;
use crate::onn::Engine;
use crate::simulator::{ChipDescription, ChipSim};
use crate::tensor::Tensor;
use crate::train::{
    fit, gather_batch, Optimizer, TrainBackend, TrainConfig, TrainModel,
};
use crate::util::error::Result;

use super::{DriftShared, RecalRequest};

/// Recalibration policy knobs.
#[derive(Clone, Debug)]
pub struct RecalConfig {
    /// chip-in-the-loop fine-tune steps per recalibration (0 = BN-only)
    pub fine_tune_steps: usize,
    /// Adam learning rate for the fine-tune steps
    pub lr: f32,
    /// minibatch size for fine-tune and BN recalibration
    pub batch: usize,
    /// BN-recalibration batches drawn from the calibration set
    pub bn_batches: usize,
    /// seed of the fine-tune shuffling stream
    pub seed: u64,
    /// run the recalibration sim with stochastic noise (realistic) or
    /// deterministically (reproducible tests)
    pub noisy: bool,
    /// write each drifted-chip snapshot to `<dir>/drift_snapshot_<n>.json`
    pub snapshot_dir: Option<PathBuf>,
}

impl Default for RecalConfig {
    fn default() -> RecalConfig {
        RecalConfig {
            fine_tune_steps: 32,
            lr: 2e-3,
            batch: 16,
            bn_batches: 4,
            seed: 0x2ECA_1,
            noisy: false,
            snapshot_dir: None,
        }
    }
}

/// Background recalibration worker.
pub struct Recalibrator {
    model: TrainModel,
    calib: Split,
    cfg: RecalConfig,
    shared: Arc<DriftShared>,
    /// completed cycles of *this* recalibrator (snapshot numbering)
    cycles: usize,
}

impl Recalibrator {
    /// `model` is the trainable twin of the engine currently in the slot
    /// (build it with [`TrainModel::from_parts`] when serving from disk
    /// artifacts); `calib` is the labelled calibration set fine-tune and
    /// BN recalibration draw from.
    pub fn new(
        model: TrainModel,
        calib: Split,
        cfg: RecalConfig,
        shared: Arc<DriftShared>,
    ) -> Recalibrator {
        Recalibrator { model, calib, cfg, shared, cycles: 0 }
    }

    /// One full recalibration cycle against the drifted operating point
    /// `desc`: bounded fine-tune → exact BN recalibration → engine hot
    /// swap.  Synchronous — callers that must not block use
    /// [`Recalibrator::spawn`].
    pub fn recalibrate(&mut self, desc: ChipDescription) -> Result<()> {
        let span = trace::begin();
        let point = desc.clone();
        if let Some(dir) = &self.cfg.snapshot_dir {
            let n = self.cycles;
            let path = dir.join(format!("drift_snapshot_{n}.json"));
            if let Err(e) = desc.save(&path) {
                eprintln!("cirptc recalibrator: snapshot failed: {e:#}");
            }
        }
        let sim = if self.cfg.noisy {
            ChipSim::new(desc)
        } else {
            ChipSim::deterministic(desc)
        };
        let mut backend = TrainBackend::Chip(sim);
        if self.cfg.fine_tune_steps > 0 && self.calib.n >= self.cfg.batch {
            let mut opt = Optimizer::adam(self.cfg.lr);
            let tcfg = TrainConfig {
                // max_steps is the binding cap; epochs just has to cover it
                epochs: self.cfg.fine_tune_steps,
                batch: self.cfg.batch,
                max_steps: self.cfg.fine_tune_steps,
                seed: self.cfg.seed,
            };
            fit(&mut self.model, &mut backend, &mut opt, &self.calib, &tcfg)?;
        }
        // exact BN statistics at the new operating point — fine-tuning
        // moved the weights, and the EMA stats predate the drift anyway
        let bs = self.cfg.batch.min(self.calib.n).max(1);
        let nb = (self.calib.n / bs).min(self.cfg.bn_batches.max(1)).max(1);
        let batches: Vec<Tensor> = (0..nb)
            .map(|i| {
                let idx: Vec<usize> = (i * bs..(i + 1) * bs).collect();
                gather_batch(&self.calib, &idx).0
            })
            .collect();
        self.model.recalibrate_bn(&batches, &mut backend)?;
        // hot swap: workers pick the new engine up on their next batch,
        // and their monitors rebase to the point this cycle trained for
        let bundle = self.model.export_bundle();
        let engine = Engine::from_parts(self.model.manifest.clone(), &bundle)?;
        *self.shared.recal_point.lock().unwrap_or_else(PoisonError::into_inner) =
            Some(point);
        self.shared.slot.swap(engine);
        self.cycles += 1;
        // generation first (the monitors' rebase key), then the shared
        // observability counter
        self.shared.recal_generation.add(1);
        self.shared.metrics.recalibrations.add(1);
        trace::instant(
            "hot_swap",
            "drift",
            trace::arg1("generation", self.shared.recal_generation.get() as i64),
        );
        trace::end(
            span,
            "recalibrate",
            "drift",
            trace::arg1("cycle", self.cycles as i64),
        );
        Ok(())
    }

    /// Thread body: serve recalibration requests until every sender
    /// (i.e. every [`super::DriftBackend`]) is gone.
    pub fn run(mut self, rx: mpsc::Receiver<RecalRequest>) {
        while let Ok(req) = rx.recv() {
            let outcome = self.recalibrate(req.desc);
            // clear the in-flight gate *after* the swap so the monitor
            // can't double-fire on the pre-swap residual
            self.shared.recal_in_flight.finish();
            if let Err(e) = outcome {
                eprintln!(
                    "cirptc recalibrator: recalibration failed \
                     (residual {:.4} at pass {}): {e:#}",
                    req.residual, req.passes
                );
            }
        }
    }

    /// Spawn the recalibrator on its own thread.  The handle joins on
    /// drop; drop it *after* the coordinator so the workers' request
    /// senders are gone by the time the join runs.
    pub fn spawn(self, rx: mpsc::Receiver<RecalRequest>) -> JoinOnDrop {
        spawn_named("cirptc-recalibrator", move || self.run(rx))
    }
}
