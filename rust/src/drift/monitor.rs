//! On-line drift monitoring: cheap calibration-probe passes interleaved
//! with serving traffic.
//!
//! A probe streams a small, fixed, positive operand block through a fixed
//! positive probe BCM — one extra chip pass — and compares the
//! photocurrents against the *calibration-point prediction* (the same
//! tile executed on a deterministic twin of the chip as it looked when
//! last calibrated).  The normalized residual is the drift signal:
//! exactly zero on a deterministic un-drifted chip, the noise floor on a
//! noisy one, and growing as Γ / responsivity / dark walk away from the
//! calibration point.  A single unsigned pass is used deliberately — the
//! sign-split serving path cancels dark current, a probe must not.
//!
//! The monitor owns the trigger policy (residual threshold + pass-count
//! cooldown) and, when it fires, hands a [`super::RecalRequest`] carrying
//! the drifted [`ChipDescription`] snapshot to the background
//! [`super::Recalibrator`].  When a recalibration lands (observed through
//! the shared [`crate::coordinator::Metrics`] counter) the monitor
//! **rebases** its reference to the operating point that recalibration
//! was trained against, so residuals always measure drift the served
//! weights have never seen.

use crate::obs::trace;
use crate::util::sync::{mpsc, PoisonError};

use crate::circulant::Bcm;
use crate::simulator::{ChipDescription, ChipSim};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::{DriftShared, RecalRequest};

/// Probe cadence + trigger policy.
#[derive(Clone, Debug)]
pub struct MonitorConfig {
    /// run one probe every this many drained batches (0 = never probe)
    pub probe_every: u64,
    /// normalized probe residual (RMSE / reference range) that fires the
    /// recalibration trigger; `f32::INFINITY` = monitor-only deployment
    pub residual_trigger: f32,
    /// minimum chip passes between recalibrations
    pub cooldown_passes: u64,
    /// operand columns per probe pass
    pub probe_cols: usize,
    /// seed of the fixed probe tile + operand
    pub seed: u64,
}

impl Default for MonitorConfig {
    fn default() -> MonitorConfig {
        MonitorConfig {
            probe_every: 8,
            residual_trigger: 0.05,
            cooldown_passes: 512,
            probe_cols: 4,
            seed: 0x0D11_F70B,
        }
    }
}

/// Per-worker drift monitor (each worker owns its chip, so each owns its
/// monitor).
pub struct DriftMonitor {
    cfg: MonitorConfig,
    probe_w: Bcm,
    probe_x: Tensor,
    /// calibration-point prediction for the probe tile
    want: Tensor,
    /// recalibration generation observed through
    /// [`super::DriftShared::recal_generation`]
    recals_seen: u64,
    /// chip pass count at the last (re)calibration point
    last_recal_pass: u64,
    /// this monitor's key space in the sim's pre-encoded tile cache: the
    /// probe tile is static, so its device encode is cached between
    /// probes (and re-encoded automatically after every drift tick) —
    /// probe passes stop paying per-probe encode + FFT/alloc setup
    owner: u64,
    /// most recent probe residual (0 until the first probe runs) — the
    /// member-local drift signal the farm health machine classifies on
    last_residual: f32,
}

impl DriftMonitor {
    /// Build a monitor whose reference is `calibration` — the chip as it
    /// looked when the served weights were calibrated.
    pub fn new(cfg: MonitorConfig, calibration: &ChipDescription) -> DriftMonitor {
        let mut rng = Rng::new(cfg.seed ^ 0x90BE_5);
        let l = calibration.l;
        let (p, q) = (1usize, 2usize);
        let mut w = vec![0.0f32; p * q * l];
        rng.fill_uniform(&mut w);
        let mut xd = vec![0.0f32; q * l * cfg.probe_cols];
        rng.fill_uniform(&mut xd);
        let probe_x = Tensor::new(&[q * l, cfg.probe_cols], xd);
        let mut m = DriftMonitor {
            cfg,
            probe_w: Bcm::new(p, q, l, w),
            probe_x,
            want: Tensor::zeros(&[p * l, 0]),
            recals_seen: 0,
            last_recal_pass: 0,
            owner: crate::onn::plan::next_tile_owner(),
            last_residual: 0.0,
        };
        m.rebase(calibration);
        m
    }

    /// Recompute the probe reference at a new calibration point: the
    /// probe tile executed on a deterministic twin of `desc` (noise off,
    /// quantizers on — the clean expectation of the programmed tile).
    pub fn rebase(&mut self, desc: &ChipDescription) {
        let mut reference = ChipSim::deterministic(desc.clone());
        self.want = reference.forward(&self.probe_w, &self.probe_x);
        // a fresh reference means the drift the last probe saw is gone;
        // drop the stale signal so farm health doesn't linger in Drifting
        self.last_residual = 0.0;
    }

    /// One calibration-probe pass on the live chip; returns the
    /// normalized residual against the calibration-point prediction.
    /// Runs through the planned path so the static probe tile's device
    /// encode is cached between probes (bit-identical to an unplanned
    /// `sim.forward` pass — `rust/tests/planned_path.rs`).
    pub fn probe(&mut self, sim: &mut ChipSim) -> f32 {
        let got =
            sim.forward_planned(self.owner, 0, false, &self.probe_w, &self.probe_x);
        let res = got.normalized_rmse(&self.want);
        // the photocurrent buffer came from the scratch arena — park it
        // again so probes stay alloc-free instead of draining the pool
        crate::util::scratch::put(got.data);
        self.last_residual = res;
        res
    }

    /// Most recent probe residual (0 before the first probe).  The farm
    /// health machine reads this to classify a member as Drifting without
    /// forcing an extra chip pass.
    pub fn last_residual(&self) -> f32 {
        self.last_residual
    }

    /// Worker-loop hook, called after every drained batch: refresh the
    /// drift gauges, run a probe on cadence, and fire the recalibration
    /// trigger when the policy says so.  `batches` is the worker's
    /// drained-batch count.  Returns the probe residual when this call
    /// ran a probe (`None` off-cadence) — the farm supervisor feeds every
    /// observed residual into its fail/restore state machine.
    pub fn after_batch(
        &mut self,
        sim: &mut ChipSim,
        batches: u64,
        shared: &DriftShared,
        recal_tx: &mpsc::Sender<RecalRequest>,
    ) -> Option<f32> {
        // a recalibration of *this stack* landed since we last looked:
        // rebase the probe reference to the point it was trained against,
        // so the residual keeps measuring drift the new weights have
        // never seen (the chip kept drifting while the recalibration
        // ran).  Keyed on the stack-local generation, not the metrics
        // counter — the metrics sink may be shared across stacks.
        let recals = shared.recal_generation.get() as u64;
        if recals != self.recals_seen {
            self.recals_seen = recals;
            self.last_recal_pass = sim.passes();
            let point = shared
                .recal_point
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .clone()
                .unwrap_or_else(|| sim.desc.clone());
            self.rebase(&point);
        }
        let age = sim.passes().saturating_sub(self.last_recal_pass);
        shared.metrics.passes_since_recal.set(age as i64);
        if let Some(d) = sim.drift() {
            shared.metrics.drift_ticks.set(d.ticks() as i64);
        }
        if self.cfg.probe_every == 0 || batches % self.cfg.probe_every != 0 {
            return None;
        }
        let res = self.probe(sim);
        let ppm = (res as f64 * 1e6) as u64;
        shared.metrics.probes.add(1);
        shared.metrics.probe_residual_ppm.record(ppm.max(1));
        shared.metrics.last_probe_residual_ppm.set(ppm as i64);
        trace::instant("probe", "drift", trace::arg1("residual_ppm", ppm as i64));
        if res >= self.cfg.residual_trigger
            && sim.passes().saturating_sub(self.last_recal_pass)
                >= self.cfg.cooldown_passes
            && shared.recal_in_flight.try_begin()
        {
            trace::instant(
                "recal_trigger",
                "drift",
                [("residual_ppm", ppm as i64), ("passes", sim.passes() as i64)],
            );
            let req = RecalRequest {
                desc: sim.desc.clone(),
                residual: res,
                passes: sim.passes(),
            };
            if recal_tx.send(req).is_err() {
                // monitor-only deployment: nobody is listening
                shared.recal_in_flight.finish();
            }
        }
        Some(res)
    }
}

/// Convenience for benches / logs: ppm back to a fraction.
pub fn ppm_to_residual(ppm: i64) -> f32 {
    (ppm as f64 / 1e6) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drift::{DriftConfig, DriftModel};

    fn chip() -> ChipDescription {
        let mut d = ChipDescription::ideal(4);
        d.w_bits = 6;
        d.x_bits = 4;
        d.dark = 0.01;
        d
    }

    #[test]
    fn residual_zero_at_calibration_point_grows_under_drift() {
        let d = chip();
        let mut monitor = DriftMonitor::new(MonitorConfig::default(), &d);
        let mut sim = ChipSim::deterministic(d.clone());
        assert_eq!(monitor.probe(&mut sim), 0.0, "calibration point");
        sim.set_drift(DriftModel::new(DriftConfig {
            seed: 3,
            passes_per_tick: 1,
            gamma_walk: 2e-3,
            resp_tilt: 5e-3,
            dark_creep: 2e-4,
            max_ticks: 0,
        }));
        for _ in 0..100 {
            let w = Bcm::new(1, 2, 4, vec![0.5; 8]);
            let x = Tensor::new(&[8, 2], vec![0.5; 16]);
            sim.forward(&w, &x); // traffic advances the drift clock
        }
        let res = monitor.probe(&mut sim);
        assert!(res > 0.01, "drift must show in the probe residual: {res}");
        // rebasing to the drifted point nulls the residual again
        let point = sim.desc.clone();
        monitor.rebase(&point);
        let res2 = monitor.probe(&mut sim);
        assert!(res2 < res * 0.2, "rebase must null the residual: {res2}");
    }

    #[test]
    fn probe_is_one_unsigned_pass_and_sees_dark() {
        let mut d = chip();
        let mut monitor = DriftMonitor::new(MonitorConfig::default(), &d);
        d.dark += 0.1; // a drift the sign-split serving path would cancel
        let mut sim = ChipSim::deterministic(d);
        let before = sim.passes();
        let res = monitor.probe(&mut sim);
        assert_eq!(sim.passes(), before + 1, "a probe costs one pass");
        assert!(res > 0.0, "dark creep must be visible to the probe");
    }
}
