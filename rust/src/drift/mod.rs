//! Drift subsystem: on-line chip monitoring and zero-downtime
//! recalibration in the serving coordinator (DESIGN.md §drift).
//!
//! Hardware-aware training ([`crate::train`]) compensates the chip's
//! nonidealities *as calibrated* — but a deployed photonic tensor core
//! drifts afterwards: thermal crosstalk, PD responsivity and dark current
//! all walk away from the calibration point.  This module makes the
//! serving stack survive a chip that changes underneath it:
//!
//! * [`DriftModel`] ([`model`]) — seeded, deterministic evolution of
//!   [`crate::simulator::ChipDescription`] on the chip's pass-count
//!   clock, attached to a [`crate::simulator::ChipSim`] via `set_drift`
//!   (disabled ⇒ bit-identical simulator);
//! * [`DriftMonitor`] ([`monitor`]) — cheap calibration-probe passes
//!   interleaved with traffic, residual-vs-calibration-point metrics,
//!   and the recalibration trigger policy;
//! * [`Recalibrator`] ([`recal`]) — background chip-in-the-loop
//!   fine-tune + BN recalibration against the drifted operating point,
//!   ending in an engine **hot swap**;
//! * [`EngineSlot`] / [`DriftShared`] / [`DriftBackend`] (here) — the
//!   serving plumbing: a swappable engine handle, the state shared
//!   between workers and the recalibrator, and the
//!   [`InferenceBackend`] that wires monitoring into the worker loop.
//!
//! Requests keep flowing through the whole cycle: workers read the
//! current engine once per drained batch, the recalibrator publishes a
//! new one atomically, and nothing on the request path ever blocks on
//! training (`rust/tests/drift_e2e.rs` pins the zero-drop guarantee).
//!
//! One [`DriftShared`] describes **one chip's** compensation stack.
//! A single-chip deployment shares it across that chip's workers; a
//! multi-chip farm ([`crate::farm`]) instantiates one stack per member
//! — each chip drifts on its own seeded process, probes against its own
//! calibration point, and recalibrates independently, so a sibling's
//! recalibration never rebases or blocks a healthy chip
//! (`rust/tests/farm_e2e.rs`).

pub mod model;
pub mod monitor;
pub mod recal;

pub use model::{DriftConfig, DriftModel};
pub use monitor::{DriftMonitor, MonitorConfig};
pub use recal::{RecalConfig, Recalibrator};

use crate::util::sync::{mpsc, Arc, Mutex, SingleFlight, Slot};

use crate::coordinator::{InferenceBackend, Metrics};
use crate::onn::{Backend, Engine};
use crate::simulator::{ChipDescription, ChipSim};
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::threadpool::WorkCounter;

/// A hot-swappable engine handle: readers grab the current `Arc<Engine>`
/// (one `RwLock` read + one `Arc` clone — cheap enough per batch), the
/// recalibrator publishes a replacement atomically.  Thin wrapper over
/// the generic [`crate::util::sync::Slot`] — the swap-vs-reader protocol
/// is model-checked in `tests/loom_models.rs` against that type.
pub struct EngineSlot {
    inner: Slot<Engine>,
}

impl EngineSlot {
    pub fn new(engine: Engine) -> EngineSlot {
        EngineSlot { inner: Slot::new(engine) }
    }

    /// The engine to use for the next batch.
    pub fn current(&self) -> Arc<Engine> {
        self.inner.current()
    }

    /// Publish a new engine; in-flight batches finish on the old one.
    pub fn swap(&self, engine: Engine) {
        self.inner.swap(engine);
    }
}

/// A recalibration request: the monitor's snapshot of the drifted chip.
pub struct RecalRequest {
    pub desc: ChipDescription,
    /// the probe residual that fired the trigger
    pub residual: f32,
    /// chip pass count at the snapshot
    pub passes: u64,
}

/// State shared between the serving workers and the recalibrator.
pub struct DriftShared {
    pub slot: EngineSlot,
    /// the coordinator's metrics sink (create the [`Metrics`] first and
    /// start the coordinator with
    /// [`crate::coordinator::Coordinator::start_with_metrics`] so drift
    /// and serving metrics land in one place)
    pub metrics: Arc<Metrics>,
    /// a recalibration is queued or running (single-flight gate)
    pub recal_in_flight: SingleFlight,
    /// completed recalibration cycles *of this stack* — the control-plane
    /// generation monitors key their rebase on.  Deliberately separate
    /// from `metrics.recalibrations`: the metrics sink may be shared
    /// across stacks ([`crate::coordinator::Coordinator::start_with_metrics`]),
    /// the generation must not be.
    pub recal_generation: WorkCounter,
    /// the operating point the last completed recalibration was trained
    /// against.  Monitors rebase their probe reference *here* (not to the
    /// live chip), so the residual keeps measuring drift the served
    /// weights have never seen — including drift that accumulated while
    /// the recalibration was running.
    pub recal_point: Mutex<Option<ChipDescription>>,
}

impl DriftShared {
    pub fn new(engine: Engine, metrics: Arc<Metrics>) -> Arc<DriftShared> {
        Arc::new(DriftShared {
            slot: EngineSlot::new(engine),
            metrics,
            recal_in_flight: SingleFlight::new(),
            recal_generation: WorkCounter::new(),
            recal_point: Mutex::new(None),
        })
    }
}

/// Drift-aware serving backend: the photonic engine backend plus the
/// monitor hook.  Each worker owns its own chip (sim + drift process) and
/// its own monitor; the engine and recalibration machinery are shared.
pub struct DriftBackend {
    shared: Arc<DriftShared>,
    /// `Backend::PhotonicSim` over the (drifting) chip
    mode: Backend,
    monitor: DriftMonitor,
    recal_tx: mpsc::Sender<RecalRequest>,
    batches: u64,
}

impl DriftBackend {
    /// `sim` should carry the drift process (`sim.set_drift(..)`) and sit
    /// at the calibration point the monitor was built from.
    pub fn new(
        shared: Arc<DriftShared>,
        sim: ChipSim,
        monitor: DriftMonitor,
        recal_tx: mpsc::Sender<RecalRequest>,
    ) -> DriftBackend {
        DriftBackend {
            shared,
            mode: Backend::PhotonicSim(sim),
            monitor,
            recal_tx,
            batches: 0,
        }
    }
}

/// Drift-monitored *pipelined* serving: the staged twin of
/// [`DriftBackend`] for [`crate::coordinator::Coordinator::start_pipelined`].
/// The monitor runs as the chip-stage hook, after each batch's passes
/// while the chip is quiescent — exactly where the sequential backend
/// runs it — so probe cadence, residuals and recalibration triggers are
/// identical between the two serving loops.
pub fn staged_drift(
    shared: Arc<DriftShared>,
    sim: ChipSim,
    mut monitor: DriftMonitor,
    recal_tx: mpsc::Sender<RecalRequest>,
) -> crate::coordinator::Staged {
    let hook_shared = Arc::clone(&shared);
    let mut batches = 0u64;
    crate::coordinator::Staged::new(
        crate::coordinator::EngineSource::Shared(shared),
        Backend::PhotonicSim(sim),
    )
    .with_hook(Box::new(move |backend: &mut Backend| {
        if let Backend::PhotonicSim(sim) = backend {
            batches += 1;
            // probe residual consumed by the farm supervisor only
            let _ = monitor.after_batch(sim, batches, &hook_shared, &recal_tx);
        }
    }))
}

impl InferenceBackend for DriftBackend {
    fn infer_batch(&mut self, imgs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        // read the slot once per batch: hot swaps land *between* drained
        // batches, never mid-batch
        let engine = self.shared.slot.current();
        let out = engine.forward_batch(imgs, &mut self.mode)?;
        self.batches += 1;
        if let Backend::PhotonicSim(sim) = &mut self.mode {
            let _ = self.monitor.after_batch(
                sim,
                self.batches,
                &self.shared,
                &self.recal_tx,
            );
        }
        Ok(out)
    }

    fn name(&self) -> String {
        "engine/drift-monitored".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Bundle;
    use crate::onn::Manifest;
    use crate::util::rng::Rng;

    /// Tiny in-memory circ engine (same shape as the engine unit tests).
    fn tiny_engine(bias0: f32) -> Engine {
        let manifest = Manifest::parse(
            r#"{
              "dataset": "synth_cxr", "classes": 3,
              "layers": [
                {"kind": "conv", "cin": 1, "cout": 4, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0},
                {"kind": "fc", "cin": 256, "cout": 3, "k": 3, "pool": 2,
                 "arch": "circ", "l": 4, "act_scale": 4.0}
              ]}"#,
        )
        .unwrap();
        let mut bundle = Bundle::default();
        let mut rng = Rng::new(5);
        let mut w0 = vec![0.0f32; 3 * 4];
        rng.fill_uniform(&mut w0);
        bundle.insert_f32("layer0.w", &[1, 3, 4], w0);
        bundle.insert_f32("layer0.b", &[4], vec![bias0; 4]);
        let mut w3 = vec![0.0f32; 64 * 4];
        rng.fill_uniform(&mut w3);
        bundle.insert_f32("layer3.w", &[1, 64, 4], w3);
        bundle.insert_f32("layer3.b", &[3], vec![0.0; 3]);
        Engine::from_parts(manifest, &bundle).unwrap()
    }

    #[test]
    fn engine_slot_swap_is_visible_to_readers() {
        let slot = EngineSlot::new(tiny_engine(0.0));
        let before = slot.current();
        slot.swap(tiny_engine(1.0));
        let after = slot.current();
        assert!(!Arc::ptr_eq(&before, &after), "swap must replace the arc");
        // the old engine stays valid for in-flight batches
        let img = Tensor::zeros(&[1, 8, 8]);
        let y_old = before.forward(&img, &mut Backend::Digital).unwrap();
        let y_new = after.forward(&img, &mut Backend::Digital).unwrap();
        assert!(y_old.iter().all(|v| v.is_finite()));
        assert_ne!(y_old, y_new, "distinct weights must serve distinctly");
    }

    #[test]
    fn drift_backend_serves_probes_and_reports_metrics() {
        let metrics = Arc::new(Metrics::default());
        let shared = DriftShared::new(tiny_engine(0.0), Arc::clone(&metrics));
        let desc = ChipDescription::ideal(4);
        let mut sim = ChipSim::deterministic(desc.clone());
        sim.set_drift(DriftModel::new(DriftConfig {
            seed: 1,
            passes_per_tick: 1,
            gamma_walk: 1e-3,
            resp_tilt: 2e-3,
            dark_creep: 1e-4,
            max_ticks: 0,
        }));
        let monitor = DriftMonitor::new(
            MonitorConfig {
                probe_every: 1,
                residual_trigger: f32::INFINITY,
                cooldown_passes: 0,
                ..MonitorConfig::default()
            },
            &desc,
        );
        let (tx, rx) = mpsc::channel();
        drop(rx); // monitor-only: no recalibrator attached
        let mut be = DriftBackend::new(shared, sim, monitor, tx);
        let imgs: Vec<Tensor> =
            (0..4).map(|_| Tensor::full(&[1, 8, 8], 0.5)).collect();
        for _ in 0..6 {
            let out = be.infer_batch(&imgs).unwrap();
            assert_eq!(out.len(), 4);
        }
        assert_eq!(metrics.probes.get(), 6, "one probe per batch");
        assert_eq!(metrics.probe_residual_ppm.count(), 6);
        assert!(metrics.drift_ticks.get() > 0, "drift clock must advance");
        assert!(metrics.passes_since_recal.get() > 0);
        assert_eq!(metrics.recalibrations.get(), 0);
        // residual grows as the chip walks away from the probe reference
        assert!(metrics.last_probe_residual_ppm.get() > 0);
    }
}
