//! Post-deployment drift models: a seeded, deterministic evolution of the
//! chip's hidden parameters on a **pass-count clock**.
//!
//! Real photonic tensor cores walk away from their calibration point after
//! deployment — thermal crosstalk shifts the coupling operator Γ, PD
//! responsivity tilts per wavelength, and dark current creeps (the
//! butterfly-chip line of work flags post-calibration drift as *the*
//! operational blocker for ONNs).  [`DriftModel`] reproduces the three
//! dominant modes:
//!
//! * **Γ off-diagonal random walk** — every off-diagonal crosstalk entry
//!   takes a small Gaussian step per tick, reflected at zero and capped,
//!   so coupling only ever *grows* in magnitude the way thermal gradients
//!   do;
//! * **per-wavelength responsivity tilt** — each wavelength drifts along a
//!   fixed direction drawn once at model creation (a tilt, not a jitter),
//!   clamped to a physical range;
//! * **dark-current creep** — a monotone additive offset per tick.
//!
//! The clock is the chip pass counter: [`DriftModel::on_pass`] is invoked
//! by [`crate::simulator::ChipSim::forward`] once per crossbar pass and
//! applies one [`DriftModel::tick`] every `passes_per_tick` passes.  With
//! no model attached the simulator is bit-identical to the pre-drift code
//! path; with a model attached the evolution is fully deterministic under
//! a fixed seed (the model owns its own [`Rng`] stream).

use crate::simulator::ChipDescription;
use crate::util::rng::Rng;

/// Drift-rate knobs.  The defaults are "slow": visible over tens of
/// thousands of passes.  Tests and the drift bench accelerate the clock
/// (`passes_per_tick = 1`) and raise the per-tick magnitudes instead of
/// waiting.
#[derive(Clone, Debug)]
pub struct DriftConfig {
    /// seed of the model's private RNG stream
    pub seed: u64,
    /// chip passes per drift tick (the clock granularity; 0 disables
    /// ticking entirely)
    pub passes_per_tick: u64,
    /// σ of the per-tick Gaussian step on each off-diagonal Γ entry
    pub gamma_walk: f32,
    /// per-tick step along each wavelength's fixed tilt direction
    pub resp_tilt: f32,
    /// per-tick additive dark-current creep
    pub dark_creep: f32,
    /// stop drifting after this many ticks (0 = unbounded) — models a
    /// bounded thermal episode and gives tests a deterministic plateau
    pub max_ticks: u64,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig {
            seed: 0xD21F_7001,
            passes_per_tick: 256,
            gamma_walk: 2e-4,
            resp_tilt: 1e-4,
            dark_creep: 1e-5,
            max_ticks: 0,
        }
    }
}

/// Off-diagonal Γ entries never exceed this coupling fraction.
const GAMMA_CAP: f32 = 0.25;

/// A deterministic drift process over a [`ChipDescription`].
#[derive(Clone, Debug)]
pub struct DriftModel {
    cfg: DriftConfig,
    rng: Rng,
    /// per-wavelength responsivity drift direction in (-1, 1), drawn once
    /// (lazily, when the block order is first seen)
    tilt_dir: Vec<f32>,
    passes: u64,
    ticks: u64,
}

impl DriftModel {
    pub fn new(cfg: DriftConfig) -> DriftModel {
        let rng = Rng::new(cfg.seed ^ 0x0D21_F7);
        DriftModel { cfg, rng, tilt_dir: Vec::new(), passes: 0, ticks: 0 }
    }

    /// Drift ticks applied so far (stops growing at `max_ticks`).
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Chip passes observed on the drift clock.
    pub fn passes(&self) -> u64 {
        self.passes
    }

    /// Advance the pass-count clock by one chip pass; applies one
    /// [`DriftModel::tick`] every `passes_per_tick` passes.
    pub fn on_pass(&mut self, desc: &mut ChipDescription) {
        self.passes += 1;
        if self.cfg.passes_per_tick == 0
            || self.passes % self.cfg.passes_per_tick != 0
        {
            return;
        }
        self.tick(desc);
    }

    /// One drift step on the chip's hidden parameters (no-op once
    /// `max_ticks` is reached).
    pub fn tick(&mut self, desc: &mut ChipDescription) {
        if self.cfg.max_ticks > 0 && self.ticks >= self.cfg.max_ticks {
            return;
        }
        self.ticks += 1;
        let l = desc.l;
        if self.tilt_dir.len() != l {
            self.tilt_dir =
                (0..l).map(|_| self.rng.range(-1.0, 1.0) as f32).collect();
        }
        // thermal-crosstalk walk: off-diagonals step, reflect at zero,
        // cap; the diagonal (direct coupling) is left alone
        if self.cfg.gamma_walk > 0.0 {
            for i in 0..l {
                for j in 0..l {
                    if i == j {
                        continue;
                    }
                    let g = &mut desc.gamma[i * l + j];
                    let step =
                        self.cfg.gamma_walk * self.rng.normal() as f32;
                    *g = (*g + step).abs().min(GAMMA_CAP);
                }
            }
        }
        // responsivity tilt: monotone walk along each wavelength's fixed
        // direction, clamped to a physical gain range
        if self.cfg.resp_tilt > 0.0 {
            for (r, t) in desc.resp.iter_mut().zip(&self.tilt_dir) {
                *r = (*r + self.cfg.resp_tilt * t).clamp(0.05, 2.0);
            }
        }
        // PD dark-current creep (cancels in sign-split pairs, but shows
        // up in single-pass calibration probes)
        desc.dark = (desc.dark + self.cfg.dark_creep).min(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accel(seed: u64) -> DriftConfig {
        DriftConfig {
            seed,
            passes_per_tick: 1,
            gamma_walk: 1e-3,
            resp_tilt: 2e-3,
            dark_creep: 1e-4,
            max_ticks: 0,
        }
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        let mut a = DriftModel::new(accel(7));
        let mut b = DriftModel::new(accel(7));
        let mut da = ChipDescription::ideal(4);
        let mut db = ChipDescription::ideal(4);
        for _ in 0..200 {
            a.on_pass(&mut da);
            b.on_pass(&mut db);
        }
        assert_eq!(da.gamma, db.gamma);
        assert_eq!(da.resp, db.resp);
        assert_eq!(da.dark, db.dark);
        assert_eq!(a.ticks(), 200);
    }

    #[test]
    fn seeds_give_different_walks() {
        let mut a = DriftModel::new(accel(1));
        let mut b = DriftModel::new(accel(2));
        let mut da = ChipDescription::ideal(4);
        let mut db = ChipDescription::ideal(4);
        for _ in 0..50 {
            a.tick(&mut da);
            b.tick(&mut db);
        }
        assert_ne!(da.gamma, db.gamma);
    }

    #[test]
    fn gamma_off_diagonals_walk_within_bounds_diagonal_fixed() {
        let mut m = DriftModel::new(accel(3));
        let mut d = ChipDescription::ideal(4);
        for _ in 0..500 {
            m.tick(&mut d);
        }
        let mut moved = 0usize;
        for i in 0..4 {
            for j in 0..4 {
                let g = d.gamma[i * 4 + j];
                if i == j {
                    assert_eq!(g, 1.0, "diagonal must not drift");
                } else {
                    assert!((0.0..=GAMMA_CAP).contains(&g), "Γ[{i}{j}]={g}");
                    if g > 0.0 {
                        moved += 1;
                    }
                }
            }
        }
        assert_eq!(moved, 12, "every off-diagonal entry must walk");
    }

    #[test]
    fn resp_tilts_monotonically_and_dark_creeps() {
        let mut m = DriftModel::new(accel(4));
        let mut d = ChipDescription::ideal(4);
        m.tick(&mut d);
        let after_one = d.resp.clone();
        for _ in 0..99 {
            m.tick(&mut d);
        }
        // tilt, not jitter: each wavelength keeps moving away from its
        // starting point along a fixed direction
        for (r1, r100) in after_one.iter().zip(&d.resp) {
            assert!(
                (r100 - 1.0).abs() >= (r1 - 1.0).abs() - 1e-7,
                "tilt must be monotone: step1 {r1}, step100 {r100}"
            );
        }
        assert!((0.05..=2.0).contains(&d.resp[0]));
        assert!((d.dark - 100.0 * 1e-4).abs() < 1e-6, "dark {}", d.dark);
    }

    #[test]
    fn pass_clock_ticks_at_configured_granularity() {
        let mut cfg = accel(5);
        cfg.passes_per_tick = 8;
        let mut m = DriftModel::new(cfg);
        let mut d = ChipDescription::ideal(4);
        for _ in 0..7 {
            m.on_pass(&mut d);
        }
        assert_eq!(m.ticks(), 0);
        assert_eq!(d.resp, vec![1.0; 4], "no tick before the boundary");
        m.on_pass(&mut d);
        assert_eq!(m.ticks(), 1);
        assert_ne!(d.resp, vec![1.0; 4]);
    }

    #[test]
    fn max_ticks_plateaus_the_walk() {
        let mut cfg = accel(6);
        cfg.max_ticks = 10;
        let mut m = DriftModel::new(cfg);
        let mut d = ChipDescription::ideal(4);
        for _ in 0..10 {
            m.tick(&mut d);
        }
        let frozen = (d.gamma.clone(), d.resp.clone(), d.dark);
        for _ in 0..100 {
            m.tick(&mut d);
        }
        assert_eq!(m.ticks(), 10);
        assert_eq!((d.gamma, d.resp, d.dark), frozen);
    }
}
