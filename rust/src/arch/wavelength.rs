//! WDM channel plan with the circulant wavelength arrangement.
//!
//! The crossbar switch at (row, col) must redirect wavelength
//! `λ_{(col - row) mod l}` — exactly the circulant gather of paper Eq. (1)
//! implemented *in circuit topology* (paper: "the switch array maps the
//! elements of a weighted vector to the outputs, thereby directly
//! implementing the structured configuration").

/// WDM plan: `l` channels spread over one FSR (plus folding replicas).
#[derive(Clone, Debug)]
pub struct WavelengthPlan {
    /// base channel wavelengths (nm), one per circulant index
    pub channels_nm: Vec<f64>,
    /// free spectral range (nm)
    pub fsr_nm: f64,
}

impl WavelengthPlan {
    /// The prototype's four measured channels (paper Fig. 2d).
    pub fn prototype() -> WavelengthPlan {
        WavelengthPlan {
            channels_nm: vec![1545.5, 1551.0, 1560.5, 1563.0],
            fsr_nm: 38.0,
        }
    }

    /// Evenly spaced plan: `l` channels across one FSR starting at `start`.
    pub fn uniform(l: usize, start_nm: f64, fsr_nm: f64) -> WavelengthPlan {
        let spacing = fsr_nm / l as f64;
        WavelengthPlan {
            channels_nm: (0..l).map(|i| start_nm + i as f64 * spacing).collect(),
            fsr_nm,
        }
    }

    pub fn l(&self) -> usize {
        self.channels_nm.len()
    }

    /// Channel spacing (nm) of a uniform plan.
    pub fn spacing_nm(&self) -> f64 {
        self.fsr_nm / self.l() as f64
    }

    /// Circulant assignment: wavelength index the switch at (row, col)
    /// must select, per Eq. (1): (col - row) mod l.
    pub fn switch_channel(&self, row: usize, col: usize) -> usize {
        let l = self.l();
        (col + l - row % l) % l
    }

    /// Wavelength (nm) for fold replica `r` of channel `ch`: the same
    /// physical ring resonates every FSR, so replica r sits one FSR up.
    pub fn folded_wavelength(&self, ch: usize, r: usize) -> f64 {
        self.channels_nm[ch] + r as f64 * self.fsr_nm
    }

    /// Verify the circulant property: every row and every column of an
    /// l×l tile uses each channel exactly once (a Latin square).
    pub fn is_latin_square(&self) -> bool {
        let l = self.l();
        for row in 0..l {
            let mut seen = vec![false; l];
            for col in 0..l {
                let c = self.switch_channel(row, col);
                if seen[c] {
                    return false;
                }
                seen[c] = true;
            }
        }
        for col in 0..l {
            let mut seen = vec![false; l];
            for row in 0..l {
                let c = self.switch_channel(row, col);
                if seen[c] {
                    return false;
                }
                seen[c] = true;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototype_channels_in_band() {
        let p = WavelengthPlan::prototype();
        assert_eq!(p.l(), 4);
        for &c in &p.channels_nm {
            assert!((1530.0..1570.0).contains(&c), "C-band");
        }
    }

    #[test]
    fn circulant_assignment_matches_eq1() {
        let p = WavelengthPlan::uniform(4, 1545.0, 38.0);
        // first row: identity order; second row rotated
        assert_eq!(p.switch_channel(0, 0), 0);
        assert_eq!(p.switch_channel(0, 3), 3);
        assert_eq!(p.switch_channel(1, 0), 3);
        assert_eq!(p.switch_channel(1, 1), 0);
    }

    #[test]
    fn assignment_is_latin_square() {
        for l in [2usize, 4, 8] {
            let p = WavelengthPlan::uniform(l, 1540.0, 36.0);
            assert!(p.is_latin_square(), "l={l}");
        }
    }

    #[test]
    fn folding_steps_one_fsr() {
        let p = WavelengthPlan::uniform(4, 1540.0, 36.0);
        assert!((p.folded_wavelength(0, 1) - 1576.0).abs() < 1e-9);
        assert!((p.folded_wavelength(2, 2) - (1540.0 + 18.0 + 72.0)).abs() < 1e-9);
    }

    #[test]
    fn spacing_uniform() {
        let p = WavelengthPlan::uniform(8, 1540.0, 32.0);
        assert!((p.spacing_nm() - 4.0).abs() < 1e-12);
        assert!((p.channels_nm[1] - p.channels_nm[0] - 4.0).abs() < 1e-12);
    }
}
