//! One-shot calibration (paper: "cascading each building block enables a
//! one-shot calibration mechanism ... while simplifying control
//! complexity"; Supplementary Note 1).
//!
//! Each crossbar switch ring is tuned onto its assigned channel and the
//! per-output gain is normalised so every ring achieves "a uniform maximum
//! output" (grey dotted line in paper Fig. 2f).  After calibration, switch
//! states are frozen; only the M·N/l weight rings are reprogrammed during
//! inference.

use crate::photonic::Mrr;

use super::wavelength::WavelengthPlan;

/// Result of calibrating one CirPTC crossbar.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// per-switch residual detuning after calibration (nm), row-major m×n
    pub residual_nm: Vec<f64>,
    /// per-column gain normalisation factors applied at the output
    pub column_gain: Vec<f64>,
    /// per-switch thermal trim power (mW)
    pub trim_power_mw: Vec<f64>,
    pub n: usize,
    pub m: usize,
}

impl Calibration {
    /// Calibrate an m×n crossbar whose as-fabricated resonances deviate by
    /// `fab_offsets_nm` (row-major) from their target channels.  Heaters
    /// can only red-shift (positive detuning), so rings are trimmed to the
    /// next reachable target; `nm_per_mw` is the heater efficiency and
    /// `dac_step_nm` the tuning granularity (residual quantization).
    pub fn run(
        plan: &WavelengthPlan,
        m: usize,
        n: usize,
        fab_offsets_nm: &[f64],
        nm_per_mw: f64,
        dac_step_nm: f64,
    ) -> Calibration {
        assert_eq!(fab_offsets_nm.len(), m * n);
        let mut residual = vec![0.0; m * n];
        let mut trim = vec![0.0; m * n];
        for row in 0..m {
            for col in 0..n {
                let idx = row * n + col;
                // shift needed to land on the assigned channel
                let mut need = -fab_offsets_nm[idx];
                if need < 0.0 {
                    // red-shift-only heater: go one FSR further
                    need += plan.fsr_nm;
                }
                // quantized heater setting leaves a residual detuning
                let steps = (need / dac_step_nm).round();
                let applied = steps * dac_step_nm;
                residual[idx] = applied - need;
                trim[idx] = Mrr::tuning_power_mw(applied, nm_per_mw);
            }
        }
        // column gain: normalise so each column's worst-case switch peak
        // matches the best (uniform maximum output, Fig. 2f)
        let ring = Mrr::new(2e4, 1550.0);
        let mut column_gain = vec![1.0; n];
        for (col, gain) in column_gain.iter_mut().enumerate() {
            let worst = (0..m)
                .map(|row| ring.drop_transmission(residual[row * n + col]))
                .fold(f64::INFINITY, f64::min);
            *gain = ring.peak / worst.max(1e-12);
        }
        Calibration { residual_nm: residual, column_gain, trim_power_mw: trim, n, m }
    }

    /// Total static trim power (mW) — the paper notes this is "negligible
    /// when using customized MRRs or post-fabrication nonvolatile phase
    /// trimming"; we model it so the power benches can toggle it.
    pub fn total_trim_mw(&self) -> f64 {
        self.trim_power_mw.iter().sum()
    }

    /// Worst-case residual detuning magnitude (nm).
    pub fn worst_residual_nm(&self) -> f64 {
        self.residual_nm.iter().fold(0.0f64, |a, &b| a.max(b.abs()))
    }

    /// Idempotence check: calibrating an already-calibrated array (zero
    /// offsets) must apply no additional trim beyond FSR wrap-arounds.
    pub fn is_idempotent_for_zero_offsets(plan: &WavelengthPlan) -> bool {
        let cal = Calibration::run(plan, 4, 4, &[0.0; 16], 0.25, 1e-4);
        cal.worst_residual_nm() < 1e-3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn offsets(m: usize, n: usize, sigma: f64, seed: u64) -> Vec<f64> {
        let mut r = Rng::new(seed);
        (0..m * n).map(|_| r.normal() * sigma).collect()
    }

    #[test]
    fn residual_bounded_by_dac_step() {
        let plan = WavelengthPlan::uniform(4, 1545.0, 38.0);
        let cal = Calibration::run(&plan, 8, 8, &offsets(8, 8, 0.4, 1), 0.25, 0.01);
        assert!(cal.worst_residual_nm() <= 0.005 + 1e-9);
    }

    #[test]
    fn trim_power_positive_and_finite() {
        let plan = WavelengthPlan::uniform(4, 1545.0, 38.0);
        let cal = Calibration::run(&plan, 4, 4, &offsets(4, 4, 0.4, 2), 0.25, 0.01);
        assert!(cal.total_trim_mw() > 0.0);
        assert!(cal.total_trim_mw().is_finite());
    }

    #[test]
    fn zero_offsets_idempotent() {
        let plan = WavelengthPlan::uniform(4, 1545.0, 38.0);
        assert!(Calibration::is_idempotent_for_zero_offsets(&plan));
    }

    #[test]
    fn column_gains_near_unity_after_good_cal() {
        let plan = WavelengthPlan::uniform(4, 1545.0, 38.0);
        let cal = Calibration::run(&plan, 4, 4, &offsets(4, 4, 0.2, 3), 0.25, 1e-3);
        for g in &cal.column_gain {
            assert!((1.0..1.2).contains(g), "gain {g}");
        }
    }

    #[test]
    fn finer_dac_reduces_residual() {
        let plan = WavelengthPlan::uniform(4, 1545.0, 38.0);
        let off = offsets(6, 6, 0.3, 4);
        let coarse = Calibration::run(&plan, 6, 6, &off, 0.25, 0.05);
        let fine = Calibration::run(&plan, 6, 6, &off, 0.25, 0.005);
        assert!(fine.worst_residual_nm() < coarse.worst_residual_nm());
    }
}
