//! CirPTC architecture model (paper Fig. 1b / Fig. 2): crossbar geometry,
//! circulant wavelength allocation, one-shot calibration, and the spectral
//! folding extension (paper Discussion / Fig. S18).

pub mod calibration;
pub mod folding;
pub mod wavelength;

pub use calibration::Calibration;
pub use wavelength::WavelengthPlan;

/// Static description of one CirPTC instance.
#[derive(Clone, Debug)]
pub struct CirPtcConfig {
    /// crossbar rows (input dimension N of the BCM)
    pub n: usize,
    /// crossbar columns (output dimension M)
    pub m: usize,
    /// circulant block order l
    pub l: usize,
    /// spectral fold count r (1 = no folding)
    pub fold: usize,
    /// operating rate (Hz)
    pub f_op: f64,
}

impl CirPtcConfig {
    /// The fabricated order-4 prototype (paper Fig. 2).
    pub fn prototype() -> CirPtcConfig {
        CirPtcConfig { n: 4, m: 4, l: 4, fold: 1, f_op: 12.5e3 }
    }

    /// The paper's peak-efficiency scaled design: 48×48 @ 10 GHz.
    pub fn scaled_48() -> CirPtcConfig {
        CirPtcConfig { n: 48, m: 48, l: 4, fold: 1, f_op: 10e9 }
    }

    /// 48×48 with r=4 spectral folding (paper Fig. S18).
    pub fn folded_48() -> CirPtcConfig {
        CirPtcConfig { n: 48, m: 48, l: 4, fold: 4, f_op: 10e9 }
    }

    /// Effective BCM input dimension: folding multiplies columns served.
    pub fn effective_n(&self) -> usize {
        self.n * self.fold
    }

    /// Active weight-encoding MRRs: M·N_eff / l (the paper's headline
    /// hardware saving vs M·N_eff for an uncompressed crossbar).
    pub fn active_weight_mrrs(&self) -> usize {
        self.m * self.effective_n() / self.l
    }

    /// Static crossbar switch rings (M·N regardless of folding — folding
    /// reuses each physical ring across r FSRs).
    pub fn switch_mrrs(&self) -> usize {
        self.m * self.n
    }

    /// Input MZMs: one per effective input channel.
    pub fn input_mzms(&self) -> usize {
        self.effective_n()
    }

    /// Output receive chains (PD + TIA + ADC): one per column; folding
    /// does NOT add receivers — the root of its power-efficiency win
    /// (paper: "increased operational throughput without expanding the
    /// number of ADCs and TIAs").
    pub fn receivers(&self) -> usize {
        self.m
    }

    /// MVM operations per second: OPS = 2·M·N_eff·f_op (paper Eq. 3).
    pub fn ops(&self) -> f64 {
        2.0 * (self.m * self.effective_n()) as f64 * self.f_op
    }

    /// DAC channels for weight programming — proportional to active MRRs,
    /// i.e. reduced l-fold vs GEMM designs (paper: "decreases ... the
    /// number of DACs required for weight encoding").
    pub fn weight_dacs(&self) -> usize {
        self.active_weight_mrrs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_matches_eq3() {
        let c = CirPtcConfig::scaled_48();
        assert!((c.ops() - 2.0 * 48.0 * 48.0 * 10e9).abs() < 1.0);
    }

    #[test]
    fn folding_multiplies_ops_not_receivers() {
        let base = CirPtcConfig::scaled_48();
        let folded = CirPtcConfig::folded_48();
        assert!((folded.ops() / base.ops() - 4.0).abs() < 1e-12);
        assert_eq!(folded.receivers(), base.receivers());
        assert_eq!(folded.switch_mrrs(), base.switch_mrrs());
    }

    #[test]
    fn active_mrr_saving_is_l_fold() {
        let c = CirPtcConfig::scaled_48();
        assert_eq!(c.active_weight_mrrs() * c.l, c.m * c.n);
    }

    #[test]
    fn prototype_is_order4() {
        let p = CirPtcConfig::prototype();
        assert_eq!((p.n, p.m, p.l), (4, 4, 4));
        assert_eq!(p.active_weight_mrrs(), 4);
        assert_eq!(p.switch_mrrs(), 16);
    }
}
