//! Spectral folding (paper Discussion + Fig. S18).
//!
//! A single crossbar switch ring resonates every FSR, so `r` input groups
//! launched in `r` adjacent FSRs are all routed by the *same* physical
//! N×M array: an N×M crossbar executes an M×(r·N) BCM against a length-r·N
//! input.  The map below assigns each logical input element its physical
//! (rail, channel, fold) coordinate, and verifies no two logical inputs
//! collide on the same physical wavelength resource.

use super::wavelength::WavelengthPlan;

/// Physical placement of one logical input element under folding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FoldSlot {
    /// physical crossbar row (0..n)
    pub row: usize,
    /// WDM base-channel index (0..l)
    pub channel: usize,
    /// FSR replica index (0..r)
    pub fold: usize,
}

/// Folding map for an n-row crossbar with block order l and fold count r.
#[derive(Clone, Debug)]
pub struct FoldingMap {
    pub n: usize,
    pub l: usize,
    pub r: usize,
}

impl FoldingMap {
    pub fn new(n: usize, l: usize, r: usize) -> FoldingMap {
        assert!(n % l == 0, "rows must be a whole number of blocks");
        assert!(r >= 1);
        FoldingMap { n, l, r }
    }

    /// Logical input length served: r·n.
    pub fn logical_n(&self) -> usize {
        self.r * self.n
    }

    /// Placement of logical input index `i` (0..r·n): fold-major layout —
    /// each consecutive n-chunk of the logical vector rides one FSR
    /// replica of the whole array.
    pub fn slot(&self, i: usize) -> FoldSlot {
        assert!(i < self.logical_n());
        let fold = i / self.n;
        let phys = i % self.n;
        FoldSlot { row: phys, channel: phys % self.l, fold }
    }

    /// Wavelength (nm) carrying logical input `i`.
    pub fn wavelength_nm(&self, plan: &WavelengthPlan, i: usize) -> f64 {
        let s = self.slot(i);
        plan.folded_wavelength(s.channel, s.fold)
    }

    /// True iff no two logical inputs share (row, channel, fold) — i.e.
    /// the physical resource assignment is collision-free.
    pub fn is_collision_free(&self) -> bool {
        let mut seen =
            vec![false; self.n * self.r];
        for i in 0..self.logical_n() {
            let s = self.slot(i);
            let key = s.fold * self.n + s.row;
            if seen[key] {
                return false;
            }
            seen[key] = true;
        }
        true
    }

    /// Laser lines required: l channels × r folds (cost of folding is a
    /// wider comb, not more rings/receivers).
    pub fn laser_lines(&self) -> usize {
        self.l * self.r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_when_unfolded() {
        let f = FoldingMap::new(8, 4, 1);
        assert_eq!(f.logical_n(), 8);
        for i in 0..8 {
            let s = f.slot(i);
            assert_eq!((s.row, s.fold), (i, 0));
        }
    }

    #[test]
    fn fold4_quadruples_capacity() {
        let f = FoldingMap::new(48, 4, 4);
        assert_eq!(f.logical_n(), 192);
        assert_eq!(f.laser_lines(), 16);
    }

    #[test]
    fn collision_free_for_paper_configs() {
        for (n, l, r) in [(4, 4, 1), (48, 4, 1), (48, 4, 4), (64, 4, 2)] {
            assert!(FoldingMap::new(n, l, r).is_collision_free(), "{n},{l},{r}");
        }
    }

    #[test]
    fn wavelengths_distinct_across_folds() {
        let f = FoldingMap::new(8, 4, 3);
        let plan = WavelengthPlan::uniform(4, 1540.0, 36.0);
        let w0 = f.wavelength_nm(&plan, 0);
        let w8 = f.wavelength_nm(&plan, 8);
        let w16 = f.wavelength_nm(&plan, 16);
        assert!((w8 - w0 - 36.0).abs() < 1e-9);
        assert!((w16 - w0 - 72.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_blocks() {
        FoldingMap::new(10, 4, 2);
    }
}
