//! Optimizers over flat f32 parameter slices.
//!
//! The trainer walks its layers in a fixed order and hands each trainable
//! tensor to the optimizer under a stable *slot* index
//! ([`crate::train::TrainModel::apply_grads`]); per-slot state (momentum /
//! Adam moments) is allocated lazily on first touch, so the optimizer
//! needs no up-front registration pass.

/// SGD + momentum or Adam (the hand-rolled Adam of `compile/train.py`).
pub enum Optimizer {
    Sgd {
        lr: f32,
        momentum: f32,
        vel: Vec<Vec<f32>>,
    },
    Adam {
        lr: f32,
        b1: f32,
        b2: f32,
        eps: f32,
        t: u32,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    },
}

fn ensure(store: &mut Vec<Vec<f32>>, slot: usize, len: usize) {
    while store.len() <= slot {
        store.push(Vec::new());
    }
    if store[slot].len() != len {
        store[slot] = vec![0.0; len];
    }
}

impl Optimizer {
    /// Plain SGD with heavy-ball momentum (`momentum = 0.0` is vanilla).
    pub fn sgd(lr: f32, momentum: f32) -> Optimizer {
        Optimizer::Sgd { lr, momentum, vel: Vec::new() }
    }

    /// Adam with the standard (β₁, β₂, ε) = (0.9, 0.999, 1e-8).
    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam {
            lr,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Advance the shared step counter (Adam bias correction); call once
    /// per optimizer step, before the per-slot updates.
    pub fn begin_step(&mut self) {
        if let Optimizer::Adam { t, .. } = self {
            *t += 1;
        }
    }

    /// Apply one update to the parameters of `slot` in place.
    pub fn step(&mut self, slot: usize, p: &mut [f32], g: &[f32]) {
        assert_eq!(p.len(), g.len(), "param/grad length at slot {slot}");
        match self {
            Optimizer::Sgd { lr, momentum, vel } => {
                ensure(vel, slot, p.len());
                let vs = &mut vel[slot];
                for i in 0..p.len() {
                    vs[i] = *momentum * vs[i] + g[i];
                    p[i] -= *lr * vs[i];
                }
            }
            Optimizer::Adam { lr, b1, b2, eps, t, m, v } => {
                ensure(m, slot, p.len());
                ensure(v, slot, p.len());
                // robust to a missing begin_step(): never divide by 1-β⁰=0
                let tt = (*t).max(1) as i32;
                let bc1 = 1.0 - b1.powi(tt);
                let bc2 = 1.0 - b2.powi(tt);
                let ms = &mut m[slot];
                let vs = &mut v[slot];
                for i in 0..p.len() {
                    ms[i] = *b1 * ms[i] + (1.0 - *b1) * g[i];
                    vs[i] = *b2 * vs[i] + (1.0 - *b2) * g[i] * g[i];
                    let mh = ms[i] / bc1;
                    let vh = vs[i] / bc2;
                    p[i] -= *lr * mh / (vh.sqrt() + *eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_matches_hand_rolled_update() {
        let mut opt = Optimizer::sgd(0.1, 0.9);
        let mut p = vec![1.0f32, -2.0];
        let g = vec![0.5f32, -1.0];
        opt.begin_step();
        opt.step(0, &mut p, &g);
        // v = g, p -= lr*v
        assert!((p[0] - 0.95).abs() < 1e-6);
        assert!((p[1] + 1.9).abs() < 1e-6);
        opt.begin_step();
        opt.step(0, &mut p, &g);
        // v = 0.9*g + g = 0.95 / -1.9
        assert!((p[0] - (0.95 - 0.1 * 0.95)).abs() < 1e-6);
        assert!((p[1] - (-1.9 + 0.1 * 1.9)).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_lr_sized_sign_step() {
        // with bias correction, |Δp| of step 1 ≈ lr regardless of |g|
        let mut opt = Optimizer::adam(0.01);
        let mut p = vec![0.0f32, 0.0];
        let g = vec![123.0f32, -0.004];
        opt.begin_step();
        opt.step(0, &mut p, &g);
        assert!((p[0] + 0.01).abs() < 1e-4, "step ≈ -lr, got {}", p[0]);
        assert!((p[1] - 0.01).abs() < 1e-4, "step ≈ +lr, got {}", p[1]);
    }

    #[test]
    fn adam_minimizes_quadratic() {
        // f(p) = Σ (p - c)², gradient 2(p - c)
        let c = [3.0f32, -1.5, 0.25];
        let mut p = vec![0.0f32; 3];
        let mut opt = Optimizer::adam(0.05);
        for _ in 0..500 {
            let g: Vec<f32> =
                p.iter().zip(&c).map(|(a, b)| 2.0 * (a - b)).collect();
            opt.begin_step();
            opt.step(0, &mut p, &g);
        }
        for (a, b) in p.iter().zip(&c) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn slots_are_independent() {
        let mut opt = Optimizer::sgd(1.0, 0.9);
        let mut p0 = vec![0.0f32];
        let mut p1 = vec![0.0f32];
        opt.begin_step();
        opt.step(0, &mut p0, &[1.0]);
        opt.step(1, &mut p1, &[0.0]);
        opt.begin_step();
        opt.step(0, &mut p0, &[0.0]);
        opt.step(1, &mut p1, &[0.0]);
        // slot 0 carries momentum from its own history only
        assert!((p0[0] + 1.9).abs() < 1e-6);
        assert_eq!(p1[0], 0.0);
    }
}
