//! Trainable StrC-ONN: the inference engine's layer stack with explicit
//! parameter storage, per-layer forward caches and manual backprop.
//!
//! Two execution backends mirror the python DPE modes (DESIGN.md §train):
//!
//! * [`TrainBackend::Digital`] — deterministic fp32 circulant math, i.e.
//!   plain digital circulant training (paper Fig. 4e config 2);
//! * [`TrainBackend::Chip`] — **chip-in-the-loop**: the forward pass of
//!   every conv/FC layer runs the (noisy) [`ChipSim`] lookup path —
//!   sign-split positive-only passes, DAC/ADC quantization, Γ crosstalk,
//!   responsivity tilt, dark current, shot/thermal noise — while the
//!   backward pass flows through the deterministic surrogate
//!   `y = s·B(clamp(x/s, 0, 1))` with straight-through-estimator
//!   gradients across the quantizers ([`Quantizer::ste_grad`]) and the
//!   clamp.  Noise and quantization residue perturb the forward values
//!   only, exactly like `jax.lax.stop_gradient` in `python/compile/dpe.py`.
//!
//! Block-circulant gradients never leave the compressed domain: the
//! weight and data adjoints are [`Bcm::backward`] — the FFT-domain
//! adjoint of `Bcm::mmm_fft` past the bench-calibrated crossover order
//! (cached `FftPlan`, one weight-spectra computation shared by both
//! gradient halves), the direct time-domain adjoint below it (the
//! paper's order 4 trains ~3× faster direct — see `benches/mvm_paths`).

use std::path::{Path, PathBuf};

use crate::bail;
use crate::circulant::Bcm;
use crate::data::Bundle;
use crate::onn::engine::{
    add_channel_bias_batch, cols_to_images, pad_rows_pooled,
};
use crate::onn::manifest::{LayerKind, LayerSpec, Manifest};
use crate::quant::Quantizer;
use crate::simulator::ChipSim;
use crate::tensor::{self, BnBatchStats, Tensor};
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

/// Trainable conv/FC layer: full-range compressed BCM + bias.  The BCM is
/// padded to multiples of the block order; `cout`/`n_in` are the logical
/// (unpadded) dimensions.
#[derive(Clone, Debug)]
pub struct CirLinear {
    pub bcm: Bcm,
    pub bias: Vec<f32>,
    pub cout: usize,
    pub n_in: usize,
}

/// Batch-norm affine parameters + running statistics.
#[derive(Clone, Debug)]
pub struct BnParams {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

#[derive(Clone, Debug)]
pub enum TrainLayer {
    Linear(CirLinear),
    Bn(BnParams),
    Stateless,
}

/// Execution backend for the training forward pass.
pub enum TrainBackend {
    /// deterministic fp32 circulant math
    Digital,
    /// chip-in-the-loop: the [`ChipSim`] (noisy, if `sim.noisy`) runs
    /// every linear layer's forward; gradients use the deterministic
    /// surrogate with STE through clamp + quantizer
    Chip(ChipSim),
}

/// Batch-major activation (same convention as the engine).
enum Act {
    /// (b, c, h, w)
    Image(Tensor),
    /// (b, n)
    Matrix(Tensor),
}

impl Act {
    fn image(self) -> Result<Tensor> {
        match self {
            Act::Image(t) => Ok(t),
            Act::Matrix(_) => bail!("expected image activation"),
        }
    }

    fn matrix(self) -> Result<Tensor> {
        match self {
            Act::Matrix(t) => Ok(t),
            Act::Image(t) => {
                let (b, per) = (t.shape[0], t.numel() / t.shape[0]);
                Ok(t.reshape(&[b, per]))
            }
        }
    }
}

/// Per-layer forward cache consumed by [`TrainModel::backward`].
enum Cache {
    Linear {
        /// the operand actually streamed through the BCM (padded rows;
        /// device-domain clamped+quantized in chip mode), for the weight
        /// adjoint
        x_fed: Tensor,
        /// clamp/STE gradient mask in the *input activation* layout
        /// (None on the digital path: gradient passes everywhere)
        mask: Option<Vec<f32>>,
        /// act_scale applied in chip mode (1.0 digital)
        scale: f32,
        /// conv geometry (b, h, w); None for fc
        conv: Option<(usize, usize, usize)>,
        /// shape of the layer's input activation
        in_shape: Vec<usize>,
    },
    Bn {
        xhat: Tensor,
        stats: BnBatchStats,
    },
    Relu {
        mask: Vec<f32>,
    },
    Pool {
        argmax: Vec<u32>,
        in_shape: Vec<usize>,
    },
    Flatten {
        in_shape: Vec<usize>,
    },
    None,
}

/// Everything the backward pass needs from one training forward.
pub struct ForwardPass {
    /// (b, classes) logits
    pub logits: Tensor,
    caches: Vec<Cache>,
}

/// Parameter gradients, aligned with the layer stack.
pub enum LayerGrad {
    Linear { dw: Vec<f32>, db: Vec<f32> },
    Bn { dgamma: Vec<f32>, dbeta: Vec<f32> },
    None,
}

pub struct Grads {
    pub per_layer: Vec<LayerGrad>,
}

/// A trainable StrC-ONN built from (and exported back to) the same
/// manifest + CPT1 contract the serving engine consumes.
#[derive(Clone)]
pub struct TrainModel {
    pub manifest: Manifest,
    pub layers: Vec<TrainLayer>,
    /// worker threads for the direct BCM multiplies (digital path)
    pub threads: usize,
}

impl TrainModel {
    /// Kaiming-init a trainable model from a manifest (mirror of python
    /// `model.init_params`): compressed weights ~ N(0, 2/n_in), zero
    /// biases, identity batch-norm.  Only the circ arch is trainable.
    pub fn init(manifest: Manifest, seed: u64) -> Result<TrainModel> {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(manifest.layers.len());
        for spec in &manifest.layers {
            layers.push(match spec.kind {
                LayerKind::Conv | LayerKind::Fc => {
                    if spec.arch != "circ" {
                        bail!(
                            "trainer supports the circ arch only (got '{}')",
                            spec.arch
                        );
                    }
                    // padding rule shared with the engine loader
                    // ([`LayerSpec::bcm_dims`]), so exported weight
                    // shapes always match what `Engine::from_parts`
                    // expects
                    let n_in = spec.n_in();
                    let (p, q) = spec.bcm_dims();
                    let std = (2.0 / n_in as f32).sqrt();
                    let mut w = vec![0.0f32; p * q * spec.l];
                    rng.fill_normal(&mut w, std);
                    TrainLayer::Linear(CirLinear {
                        bcm: Bcm::new(p, q, spec.l, w),
                        bias: vec![0.0; spec.cout],
                        cout: spec.cout,
                        n_in,
                    })
                }
                LayerKind::Bn => TrainLayer::Bn(BnParams {
                    gamma: vec![1.0; spec.cin],
                    beta: vec![0.0; spec.cin],
                    mean: vec![0.0; spec.cin],
                    var: vec![1.0; spec.cin],
                }),
                _ => TrainLayer::Stateless,
            });
        }
        Ok(TrainModel {
            manifest,
            layers,
            threads: ThreadPool::default_size(),
        })
    }

    /// Build a trainable model from an existing manifest + CPT1 bundle —
    /// the loader twin of [`crate::onn::Engine::from_parts`] and the
    /// inverse of [`TrainModel::export_bundle`].  This is how the drift
    /// subsystem obtains the trainable copy of whatever the serving
    /// engine is currently running, so recalibration fine-tunes the
    /// *live* weights rather than a re-initialized stack.
    pub fn from_parts(manifest: Manifest, bundle: &Bundle) -> Result<TrainModel> {
        crate::verify::validate_artifacts(&manifest, bundle, None)
            .into_result("refusing to build trainable model from invalid artifacts")?;
        TrainModel::from_parts_unchecked(manifest, bundle)
    }

    /// [`TrainModel::from_parts`] without the static validation pass.
    pub fn from_parts_unchecked(
        manifest: Manifest,
        bundle: &Bundle,
    ) -> Result<TrainModel> {
        let mut layers = Vec::with_capacity(manifest.layers.len());
        for (i, spec) in manifest.layers.iter().enumerate() {
            let name = format!("layer{i}");
            layers.push(match spec.kind {
                LayerKind::Conv | LayerKind::Fc => {
                    if spec.arch != "circ" {
                        bail!(
                            "trainer supports the circ arch only (got '{}')",
                            spec.arch
                        );
                    }
                    let (p, q) = spec.bcm_dims();
                    let w = bundle.get(&format!("{name}.w"))?;
                    if w.shape() != [p, q, spec.l] {
                        bail!(
                            "{name}.w shape {:?}, expected [{p},{q},{}]",
                            w.shape(),
                            spec.l
                        );
                    }
                    let bias =
                        bundle.get(&format!("{name}.b"))?.as_f32()?.to_vec();
                    TrainLayer::Linear(CirLinear {
                        bcm: Bcm::new(p, q, spec.l, w.as_f32()?.to_vec()),
                        bias,
                        cout: spec.cout,
                        n_in: spec.n_in(),
                    })
                }
                LayerKind::Bn => TrainLayer::Bn(BnParams {
                    gamma: bundle
                        .get(&format!("{name}.gamma"))?
                        .as_f32()?
                        .to_vec(),
                    beta: bundle
                        .get(&format!("{name}.beta"))?
                        .as_f32()?
                        .to_vec(),
                    mean: bundle
                        .get(&format!("{name}.state.mean"))?
                        .as_f32()?
                        .to_vec(),
                    var: bundle
                        .get(&format!("{name}.state.var"))?
                        .as_f32()?
                        .to_vec(),
                }),
                _ => TrainLayer::Stateless,
            });
        }
        Ok(TrainModel {
            manifest,
            layers,
            threads: ThreadPool::default_size(),
        })
    }

    /// Training-mode forward over an image batch (b, c, h, w): BN uses
    /// batch statistics (running stats EMA-updated in place with momentum
    /// 0.9, as python `model.apply`), every nonlinearity caches what the
    /// manual backward needs.
    pub fn forward_train(
        &mut self,
        imgs: &Tensor,
        backend: &mut TrainBackend,
    ) -> Result<ForwardPass> {
        let (logits, caches, bn_stats) =
            self.forward_inner(imgs, backend, true, true)?;
        for (layer, st) in self.layers.iter_mut().zip(bn_stats) {
            if let (TrainLayer::Bn(bn), Some((mean, var))) = (layer, st) {
                for c in 0..bn.mean.len() {
                    bn.mean[c] = 0.9 * bn.mean[c] + 0.1 * mean[c];
                    bn.var[c] = 0.9 * bn.var[c] + 0.1 * var[c];
                }
            }
        }
        Ok(ForwardPass { logits, caches })
    }

    /// Inference-mode forward: running BN statistics, no caches, no state
    /// mutation.  Returns (b, classes) logits.
    pub fn forward_eval(
        &self,
        imgs: &Tensor,
        backend: &mut TrainBackend,
    ) -> Result<Tensor> {
        let (logits, _, _) = self.forward_inner(imgs, backend, false, false)?;
        Ok(logits)
    }

    /// Recompute the BN running stats exactly with the current weights by
    /// averaging per-batch statistics over `batches` (python
    /// `train.recalibrate_bn`): after few optimizer steps the momentum-0.9
    /// EMA is still dominated by its 0/1 init, wrecking eval accuracy.
    /// Re-run whenever the execution path changes (e.g. evaluating
    /// digitally-trained weights on the chip) — the paper's one-shot
    /// calibration.
    pub fn recalibrate_bn(
        &mut self,
        batches: &[Tensor],
        backend: &mut TrainBackend,
    ) -> Result<()> {
        let mut acc: Vec<Option<(Vec<f32>, Vec<f32>)>> =
            (0..self.layers.len()).map(|_| None).collect();
        for xb in batches {
            // batch-stats mode without backward caches: calibration only
            // consumes the per-layer BN statistics
            let (_, _, stats) = self.forward_inner(xb, backend, true, false)?;
            for (slot, st) in acc.iter_mut().zip(stats) {
                let (m, v) = match st {
                    Some(mv) => mv,
                    None => continue,
                };
                match slot.take() {
                    None => *slot = Some((m, v)),
                    Some((mut am, mut av)) => {
                        for (a, b) in am.iter_mut().zip(&m) {
                            *a += *b;
                        }
                        for (a, b) in av.iter_mut().zip(&v) {
                            *a += *b;
                        }
                        *slot = Some((am, av));
                    }
                }
            }
        }
        let nb = batches.len().max(1) as f32;
        for (layer, st) in self.layers.iter_mut().zip(acc) {
            if let (TrainLayer::Bn(bn), Some((m, v))) = (layer, st) {
                for c in 0..bn.mean.len() {
                    bn.mean[c] = m[c] / nb;
                    bn.var[c] = v[c] / nb;
                }
            }
        }
        Ok(())
    }

    /// `train` selects BN batch-statistics mode; `want_caches` controls
    /// whether the per-layer backward caches are retained (recalibration
    /// runs train-mode statistics without them).
    #[allow(clippy::type_complexity)]
    fn forward_inner(
        &self,
        imgs: &Tensor,
        backend: &mut TrainBackend,
        train: bool,
        want_caches: bool,
    ) -> Result<(Tensor, Vec<Cache>, Vec<Option<(Vec<f32>, Vec<f32>)>>)> {
        if imgs.rank() != 4 {
            bail!("expected a (b, c, h, w) image batch, got {:?}", imgs.shape);
        }
        let mut caches = Vec::with_capacity(self.layers.len());
        let mut bn_stats = Vec::with_capacity(self.layers.len());
        let mut act = Act::Image(imgs.clone());
        for (i, spec) in self.manifest.layers.iter().enumerate() {
            let (next, cache, stats) =
                self.run_layer(i, spec, act, backend, train, want_caches)?;
            act = next;
            caches.push(cache);
            bn_stats.push(stats);
        }
        match act {
            Act::Matrix(t) => Ok((t, caches, bn_stats)),
            Act::Image(_) => bail!("network did not end in a vector"),
        }
    }

    #[allow(clippy::type_complexity)]
    fn run_layer(
        &self,
        idx: usize,
        spec: &LayerSpec,
        act: Act,
        backend: &mut TrainBackend,
        train: bool,
        want_caches: bool,
    ) -> Result<(Act, Cache, Option<(Vec<f32>, Vec<f32>)>)> {
        let out = match (&self.layers[idx], spec.kind) {
            (TrainLayer::Linear(lin), LayerKind::Conv) => {
                let imgs = act.image()?;
                let in_shape = imgs.shape.clone();
                let (b, h, w) = (in_shape[0], in_shape[2], in_shape[3]);
                let (y, x_fed, mask, scale) =
                    linear_multiply(lin, spec, &imgs, true, backend, self.threads);
                let out = cols_to_images(&y, b, lin.cout, h, w);
                let out = add_channel_bias_batch(out, &lin.bias);
                let cache = if want_caches {
                    Cache::Linear {
                        x_fed,
                        mask,
                        scale,
                        conv: Some((b, h, w)),
                        in_shape,
                    }
                } else {
                    Cache::None
                };
                (Act::Image(out), cache, None)
            }
            (TrainLayer::Linear(lin), LayerKind::Fc) => {
                let x = act.matrix()?;
                let in_shape = x.shape.clone();
                let b = in_shape[0];
                if in_shape[1] != lin.n_in {
                    bail!(
                        "layer {idx}: fc input width {} != manifest cin {}",
                        in_shape[1],
                        lin.n_in
                    );
                }
                let (y, x_fed, mask, scale) =
                    linear_multiply(lin, spec, &x, false, backend, self.threads);
                let mut out = Tensor::zeros(&[b, lin.cout]);
                for bi in 0..b {
                    for r in 0..lin.cout {
                        out.data[bi * lin.cout + r] =
                            y.at2(r, bi) + lin.bias[r];
                    }
                }
                let cache = if want_caches {
                    Cache::Linear { x_fed, mask, scale, conv: None, in_shape }
                } else {
                    Cache::None
                };
                (Act::Matrix(out), cache, None)
            }
            (TrainLayer::Bn(bn), LayerKind::Bn) => {
                let x = act.image()?;
                if train {
                    let (y, xhat, stats) =
                        tensor::batchnorm_train(&x, &bn.gamma, &bn.beta, 1e-5);
                    let mv = (stats.mean.clone(), stats.var.clone());
                    let cache = if want_caches {
                        Cache::Bn { xhat, stats }
                    } else {
                        Cache::None
                    };
                    (Act::Image(y), cache, Some(mv))
                } else {
                    let y = tensor::batchnorm_batch(
                        &x, &bn.mean, &bn.var, &bn.gamma, &bn.beta, 1e-5,
                    );
                    (Act::Image(y), Cache::None, None)
                }
            }
            (_, LayerKind::Relu) => {
                let (t, is_img) = match act {
                    Act::Image(t) => (t, true),
                    Act::Matrix(t) => (t, false),
                };
                let y = t.relu();
                let cache = if want_caches {
                    Cache::Relu {
                        mask: t
                            .data
                            .iter()
                            .map(|&v| if v > 0.0 { 1.0 } else { 0.0 })
                            .collect(),
                    }
                } else {
                    Cache::None
                };
                let next = if is_img { Act::Image(y) } else { Act::Matrix(y) };
                (next, cache, None)
            }
            (_, LayerKind::Pool) => {
                let x = act.image()?;
                if want_caches {
                    let (y, argmax) =
                        tensor::maxpool_batch_argmax(&x, spec.pool);
                    let cache =
                        Cache::Pool { argmax, in_shape: x.shape.clone() };
                    (Act::Image(y), cache, None)
                } else {
                    let y = tensor::maxpool_batch(&x, spec.pool);
                    (Act::Image(y), Cache::None, None)
                }
            }
            (_, LayerKind::Flatten) => {
                let t = act.image()?;
                let in_shape = t.shape.clone();
                let (b, per) = (t.shape[0], t.numel() / t.shape[0]);
                let cache = if want_caches {
                    Cache::Flatten { in_shape }
                } else {
                    Cache::None
                };
                (Act::Matrix(t.reshape(&[b, per])), cache, None)
            }
            (st, k) => bail!(
                "layer {idx}: state/kind mismatch ({k:?} vs {})",
                match st {
                    TrainLayer::Linear(_) => "linear",
                    TrainLayer::Bn(_) => "bn",
                    TrainLayer::Stateless => "stateless",
                }
            ),
        };
        Ok(out)
    }

    /// Manual backprop through the cached forward pass.  `dlogits` is the
    /// (b, classes) loss gradient; returns per-layer parameter gradients.
    pub fn backward(
        &self,
        pass: &ForwardPass,
        dlogits: &Tensor,
    ) -> Result<Grads> {
        let n = self.layers.len();
        let mut per_layer: Vec<LayerGrad> =
            (0..n).map(|_| LayerGrad::None).collect();
        let mut grad = Act::Matrix(dlogits.clone());
        for i in (0..n).rev() {
            let spec = &self.manifest.layers[i];
            grad = match (&self.layers[i], &pass.caches[i]) {
                (
                    TrainLayer::Linear(lin),
                    Cache::Linear { x_fed, mask, scale, conv, in_shape },
                ) => {
                    let (dy, db, fc_batch) = match *conv {
                        Some((b, h, w)) => {
                            let dimg = grad.image()?;
                            let hw = h * w;
                            let cols = b * hw;
                            // gather (b, cout, h, w) upstream grads into the
                            // padded (m_pad, b·h·w) column layout
                            let mut dy =
                                Tensor::zeros(&[lin.bcm.m(), cols]);
                            for bi in 0..b {
                                for ch in 0..lin.cout {
                                    let src = &dimg.data[(bi * lin.cout + ch)
                                        * hw
                                        ..(bi * lin.cout + ch + 1) * hw];
                                    dy.data[ch * cols + bi * hw
                                        ..ch * cols + (bi + 1) * hw]
                                        .copy_from_slice(src);
                                }
                            }
                            let mut db = vec![0.0f32; lin.cout];
                            for (ch, dv) in db.iter_mut().enumerate() {
                                *dv = dy.data[ch * cols..(ch + 1) * cols]
                                    .iter()
                                    .sum();
                            }
                            (dy, db, 0usize)
                        }
                        None => {
                            let dmat = grad.matrix()?;
                            let b = dmat.shape[0];
                            let mut dy = Tensor::zeros(&[lin.bcm.m(), b]);
                            let mut db = vec![0.0f32; lin.cout];
                            for bi in 0..b {
                                for r in 0..lin.cout {
                                    let g = dmat.at2(bi, r);
                                    dy.data[r * b + bi] = g;
                                    db[r] += g;
                                }
                            }
                            (dy, db, b)
                        }
                    };
                    // FFT-domain (or direct) adjoint of the BCM multiply;
                    // in chip mode dw picks up the act-scale factor, dx
                    // does not (the s and 1/s of the device encode cancel)
                    let (mut dw, dxp) = lin.bcm.backward(x_fed, &dy);
                    if *scale != 1.0 {
                        for v in dw.iter_mut() {
                            *v *= scale;
                        }
                    }
                    per_layer[i] = LayerGrad::Linear { dw, db };
                    if i == 0 {
                        // the first layer's input gradient has no
                        // consumer: skip the col2im / transpose-gather
                        // and mask application
                        Act::Matrix(Tensor::zeros(&[0, 0]))
                    } else {
                        let mut dx = match *conv {
                            Some((b, h, w)) => {
                                let cols = b * h * w;
                                let dxcols = Tensor::new(
                                    &[lin.n_in, cols],
                                    dxp.data[..lin.n_in * cols].to_vec(),
                                );
                                tensor::col2im_same_batch(
                                    &dxcols, b, in_shape[1], h, w, spec.k,
                                )
                            }
                            None => {
                                let b = fc_batch;
                                let mut dx = Tensor::zeros(&[b, lin.n_in]);
                                for bi in 0..b {
                                    for c in 0..lin.n_in {
                                        dx.data[bi * lin.n_in + c] =
                                            dxp.at2(c, bi);
                                    }
                                }
                                dx
                            }
                        };
                        if let Some(m) = mask {
                            for (v, mv) in dx.data.iter_mut().zip(m) {
                                *v *= mv;
                            }
                        }
                        if conv.is_some() {
                            Act::Image(dx)
                        } else {
                            Act::Matrix(dx)
                        }
                    }
                }
                (TrainLayer::Bn(bn), Cache::Bn { xhat, stats }) => {
                    let dy = grad.image()?;
                    let (dx, dgamma, dbeta) =
                        tensor::batchnorm_backward(&dy, xhat, &bn.gamma, stats);
                    per_layer[i] = LayerGrad::Bn { dgamma, dbeta };
                    Act::Image(dx)
                }
                (_, Cache::Relu { mask }) => match grad {
                    Act::Image(mut t) => {
                        for (v, m) in t.data.iter_mut().zip(mask) {
                            *v *= m;
                        }
                        Act::Image(t)
                    }
                    Act::Matrix(mut t) => {
                        for (v, m) in t.data.iter_mut().zip(mask) {
                            *v *= m;
                        }
                        Act::Matrix(t)
                    }
                },
                (_, Cache::Pool { argmax, in_shape }) => {
                    let dy = grad.image()?;
                    Act::Image(tensor::maxpool_batch_backward(
                        &dy, argmax, in_shape,
                    ))
                }
                (_, Cache::Flatten { in_shape }) => {
                    let dy = grad.matrix()?;
                    Act::Image(dy.reshape(in_shape))
                }
                (_, Cache::None) => bail!(
                    "layer {i}: no cache — backward() needs a \
                     forward_train() pass"
                ),
                _ => bail!("layer {i}: cache/state mismatch in backward"),
            };
        }
        Ok(Grads { per_layer })
    }

    /// Apply one optimizer step to every trainable tensor; the slot order
    /// (layer order, weight-then-bias / gamma-then-beta) is stable across
    /// steps, which is what keys the optimizer's per-slot state.
    pub fn apply_grads(
        &mut self,
        grads: &Grads,
        opt: &mut super::optim::Optimizer,
    ) {
        opt.begin_step();
        let mut slot = 0usize;
        for (layer, g) in self.layers.iter_mut().zip(&grads.per_layer) {
            match (layer, g) {
                (TrainLayer::Linear(lin), LayerGrad::Linear { dw, db }) => {
                    opt.step(slot, &mut lin.bcm.w, dw);
                    opt.step(slot + 1, &mut lin.bias, db);
                    slot += 2;
                }
                (TrainLayer::Bn(bn), LayerGrad::Bn { dgamma, dbeta }) => {
                    opt.step(slot, &mut bn.gamma, dgamma);
                    opt.step(slot + 1, &mut bn.beta, dbeta);
                    slot += 2;
                }
                (TrainLayer::Linear(_), _) | (TrainLayer::Bn(_), _) => {
                    // parameterized layer without a gradient this step
                    // (shouldn't happen from backward()); keep slots stable
                    slot += 2;
                }
                _ => {}
            }
        }
    }

    /// Flatten params/state into the CPT1 names [`crate::onn::Engine`]
    /// loads (mirror of python `export.model_tensors`).
    pub fn export_bundle(&self) -> Bundle {
        let mut bundle = Bundle::default();
        for (i, layer) in self.layers.iter().enumerate() {
            let name = format!("layer{i}");
            match layer {
                TrainLayer::Linear(lin) => {
                    bundle.insert_f32(
                        &format!("{name}.w"),
                        &[lin.bcm.p, lin.bcm.q, lin.bcm.l],
                        lin.bcm.w.clone(),
                    );
                    bundle.insert_f32(
                        &format!("{name}.b"),
                        &[lin.bias.len()],
                        lin.bias.clone(),
                    );
                }
                TrainLayer::Bn(bn) => {
                    bundle.insert_f32(
                        &format!("{name}.gamma"),
                        &[bn.gamma.len()],
                        bn.gamma.clone(),
                    );
                    bundle.insert_f32(
                        &format!("{name}.beta"),
                        &[bn.beta.len()],
                        bn.beta.clone(),
                    );
                    bundle.insert_f32(
                        &format!("{name}.state.mean"),
                        &[bn.mean.len()],
                        bn.mean.clone(),
                    );
                    bundle.insert_f32(
                        &format!("{name}.state.var"),
                        &[bn.var.len()],
                        bn.var.clone(),
                    );
                }
                TrainLayer::Stateless => {}
            }
        }
        bundle
    }

    /// Write the serving artifacts — `<dir>/models/<name>.json` manifest +
    /// `<dir>/models/<name>_dpe.cpt` CPT1 weights — exactly where
    /// `compile.train` puts them, so the engine, serving benches and
    /// examples load rust-trained models unchanged.  Returns the two paths.
    pub fn save_artifacts(
        &self,
        dir: &Path,
        name: &str,
    ) -> Result<(PathBuf, PathBuf)> {
        let mdir = dir.join("models");
        let mpath = mdir.join(format!("{name}.json"));
        let wpath = mdir.join(format!("{name}_dpe.cpt"));
        self.manifest.save(&mpath)?;
        self.export_bundle().save(&wpath)?;
        Ok((mpath, wpath))
    }
}

/// One BCM multiply on the chosen backend over the layer's (padded)
/// column-major operand block.  Returns `(y, x_fed, mask, scale)`:
///
/// * digital — `y = B·x` via the threaded direct kernel, no clamp;
/// * chip — device-domain encode `xd = clamp(x/s, 0, 1)`, noisy
///   sign-split lookup-mode forward, rescale by `s`; `x_fed` caches the
///   *quantized* device operand (what the chip actually multiplied, up to
///   noise) and `mask` the inclusive clamp/STE gradient gate in the input
///   activation's layout.
fn linear_multiply(
    lin: &CirLinear,
    spec: &LayerSpec,
    x: &Tensor,
    is_conv: bool,
    backend: &mut TrainBackend,
    threads: usize,
) -> (Tensor, Tensor, Option<Vec<f32>>, f32) {
    let to_cols = |t: &Tensor| -> Tensor {
        if is_conv {
            tensor::im2col_same_batch(t, spec.k)
        } else {
            t.transpose2()
        }
    };
    match backend {
        TrainBackend::Digital => {
            // consume the column block instead of clone-if-unpadded
            let xp = pad_rows_pooled(to_cols(x), lin.bcm.n());
            let y = lin.bcm.mmm(&xp, threads);
            (y, xp, None, 1.0)
        }
        TrainBackend::Chip(sim) => {
            let s = spec.act_scale;
            let xq = Quantizer::new(sim.desc.x_bits);
            let mask: Vec<f32> = x
                .data
                .iter()
                .map(|&v| {
                    // STE gate of the device encode clamp(x/s, 0, 1):
                    // inclusive inside (jnp.clip convention), zero
                    // outside.  [`Quantizer::ste_grad`] is the same rule
                    // for the DAC's own [0, 1] range; pre-clamping the
                    // operand into that range subsumes it here, including
                    // for 0-bit (identity) quantizers.
                    let t = v / s;
                    if (0.0..=1.0).contains(&t) {
                        1.0
                    } else {
                        0.0
                    }
                })
                .collect();
            let xd = x.map(|v| (v / s).clamp(0.0, 1.0));
            let xp = pad_rows_pooled(to_cols(&xd), lin.bcm.n());
            // propagate the trainer's worker count into the sim's
            // crossbar/encode kernels (bit-identical for any value)
            sim.threads = threads;
            let y = sim.forward_signed(&lin.bcm, &xp).scale(s);
            (y, xp.map(|v| xq.q(v)), Some(mask), s)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::ChipDescription;

    const TINY: &str = r#"{
      "dataset": "synth_shapes", "classes": 3,
      "layers": [
        {"kind": "conv", "cin": 1, "cout": 8, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "bn", "cin": 8, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "fc", "cin": 512, "cout": 3, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0}
      ]}"#;

    fn tiny_model(seed: u64) -> TrainModel {
        TrainModel::init(Manifest::parse(TINY).unwrap(), seed).unwrap()
    }

    fn batch(n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut d = vec![0.0f32; n * 16 * 16];
        rng.fill_uniform(&mut d);
        Tensor::new(&[n, 1, 16, 16], d)
    }

    #[test]
    fn init_pads_bcm_dims_to_block_order() {
        let m = tiny_model(1);
        match &m.layers[0] {
            TrainLayer::Linear(lin) => {
                // conv: cout 8 -> P=2; n_in 9 -> Q=3 (padded to 12)
                assert_eq!((lin.bcm.p, lin.bcm.q, lin.bcm.l), (2, 3, 4));
                assert_eq!((lin.cout, lin.n_in), (8, 9));
            }
            other => panic!("layer0 should be linear, got {other:?}"),
        }
        match &m.layers[5] {
            TrainLayer::Linear(lin) => {
                // fc: cout 3 -> P=1 (padded to 4); n_in 512 -> Q=128
                assert_eq!((lin.bcm.p, lin.bcm.q, lin.bcm.l), (1, 128, 4));
            }
            other => panic!("layer5 should be linear, got {other:?}"),
        }
    }

    #[test]
    fn forward_backward_shapes_and_grads() {
        let mut m = tiny_model(2);
        let xb = batch(3, 3);
        let pass = m
            .forward_train(&xb, &mut TrainBackend::Digital)
            .unwrap();
        assert_eq!(pass.logits.shape, vec![3, 3]);
        assert!(pass.logits.data.iter().all(|v| v.is_finite()));
        let (_, dl) = crate::train::softmax_cross_entropy(
            &pass.logits,
            &[0, 1, 2],
        );
        let grads = m.backward(&pass, &dl).unwrap();
        // every parameterized layer produced finite gradients
        for (layer, g) in m.layers.iter().zip(&grads.per_layer) {
            match (layer, g) {
                (TrainLayer::Linear(lin), LayerGrad::Linear { dw, db }) => {
                    assert_eq!(dw.len(), lin.bcm.w.len());
                    assert_eq!(db.len(), lin.bias.len());
                    assert!(dw.iter().all(|v| v.is_finite()));
                    assert!(dw.iter().any(|v| *v != 0.0), "dw all-zero");
                }
                (TrainLayer::Bn(bn), LayerGrad::Bn { dgamma, dbeta }) => {
                    assert_eq!(dgamma.len(), bn.gamma.len());
                    assert_eq!(dbeta.len(), bn.beta.len());
                }
                (TrainLayer::Stateless, LayerGrad::None) => {}
                _ => panic!("layer/grad mismatch"),
            }
        }
    }

    #[test]
    fn chip_ideal_forward_matches_digital() {
        // 0-bit quantizers + identity Γ + no noise: the chip path reduces
        // to the clamp/rescale identity on in-range activations.  A large
        // act_scale keeps every activation of the untrained net strictly
        // inside the clamp window.
        let txt = TINY.replace("4.0", "16.0");
        let m = TrainModel::init(Manifest::parse(&txt).unwrap(), 4).unwrap();
        let xb = batch(2, 5);
        let y_dig = m
            .forward_eval(&xb, &mut TrainBackend::Digital)
            .unwrap();
        let sim = ChipSim::deterministic(ChipDescription::ideal(4));
        let y_chip = m
            .forward_eval(&xb, &mut TrainBackend::Chip(sim))
            .unwrap();
        // post-relu activations are in [0, act_scale) for this init, so
        // only fp rounding of the encode/decode differs
        for (a, b) in y_dig.data.iter().zip(&y_chip.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn bn_running_stats_move_during_training() {
        let mut m = tiny_model(6);
        let before = match &m.layers[1] {
            TrainLayer::Bn(bn) => bn.mean.clone(),
            _ => unreachable!(),
        };
        let xb = batch(4, 7);
        let _ = m.forward_train(&xb, &mut TrainBackend::Digital).unwrap();
        let after = match &m.layers[1] {
            TrainLayer::Bn(bn) => bn.mean.clone(),
            _ => unreachable!(),
        };
        assert_ne!(before, after, "EMA must move");
        // eval must not mutate
        let _ = m.forward_eval(&xb, &mut TrainBackend::Digital).unwrap();
        let after2 = match &m.layers[1] {
            TrainLayer::Bn(bn) => bn.mean.clone(),
            _ => unreachable!(),
        };
        assert_eq!(after, after2);
    }

    #[test]
    fn export_bundle_carries_engine_names() {
        let m = tiny_model(8);
        let b = m.export_bundle();
        for name in [
            "layer0.w", "layer0.b", "layer1.gamma", "layer1.beta",
            "layer1.state.mean", "layer1.state.var", "layer5.w", "layer5.b",
        ] {
            assert!(b.get(name).is_ok(), "missing {name}");
        }
        assert_eq!(b.get("layer0.w").unwrap().shape(), &[2, 3, 4]);
    }

    #[test]
    fn from_parts_roundtrips_export_bundle() {
        let m = tiny_model(9);
        let bundle = m.export_bundle();
        let back = TrainModel::from_parts(m.manifest.clone(), &bundle).unwrap();
        assert_eq!(m.layers.len(), back.layers.len());
        for (a, b) in m.layers.iter().zip(&back.layers) {
            match (a, b) {
                (TrainLayer::Linear(x), TrainLayer::Linear(y)) => {
                    assert_eq!(x.bcm.w, y.bcm.w);
                    assert_eq!(x.bias, y.bias);
                    assert_eq!((x.cout, x.n_in), (y.cout, y.n_in));
                }
                (TrainLayer::Bn(x), TrainLayer::Bn(y)) => {
                    assert_eq!(x.gamma, y.gamma);
                    assert_eq!(x.beta, y.beta);
                    assert_eq!(x.mean, y.mean);
                    assert_eq!(x.var, y.var);
                }
                (TrainLayer::Stateless, TrainLayer::Stateless) => {}
                _ => panic!("layer kind mismatch after from_parts"),
            }
        }
    }

    #[test]
    fn gemm_arch_is_rejected() {
        let txt = TINY.replace("\"circ\"", "\"gemm\"");
        let res = TrainModel::init(Manifest::parse(&txt).unwrap(), 1);
        assert!(res.is_err());
    }
}

