//! Hardware-aware training (HAT) subsystem — the paper's Fig. 1d loop,
//! natively in rust (DESIGN.md §train).
//!
//! The compile side of the repo used to live exclusively in python
//! (`compile/train.py`); this module closes the loop inside cargo:
//!
//! * [`TrainModel`] — the engine's StrC layer stack with trainable
//!   compressed block-circulant weights, manual backprop, and FFT-domain
//!   circulant gradients ([`crate::circulant::Bcm::backward`]);
//! * [`TrainBackend::Chip`] — chip-in-the-loop training: the noisy
//!   [`crate::simulator::ChipSim`] lookup path runs the forward while
//!   gradients flow through the deterministic surrogate with
//!   straight-through-estimator quantizer gradients;
//! * [`Optimizer`] — SGD+momentum and Adam over the flat parameter slots;
//! * [`fit`] / [`evaluate`] — minibatch loop over [`crate::data::datasets`]
//!   splits with per-epoch shuffling;
//! * [`TrainModel::save_artifacts`] — rust-written manifest + CPT1
//!   weights that [`crate::onn::Engine`] and the serving benches load
//!   directly (`make train` / `make train-smoke`).

pub mod model;
pub mod optim;

pub use model::{ForwardPass, Grads, LayerGrad, TrainBackend, TrainModel};
pub use optim::Optimizer;

use crate::data::datasets::Split;
use crate::tensor::{self, Tensor};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Mean softmax cross-entropy over (b, k) logits with integer labels;
/// returns the loss and `dL/dlogits = (softmax − onehot)/b`.
pub fn softmax_cross_entropy(logits: &Tensor, labels: &[u8]) -> (f32, Tensor) {
    assert_eq!(logits.rank(), 2);
    let (b, k) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), b);
    let mut dl = Tensor::zeros(&[b, k]);
    let mut loss = 0.0f64;
    for bi in 0..b {
        let row = &logits.data[bi * k..(bi + 1) * k];
        let p = tensor::softmax(row);
        let y = labels[bi] as usize;
        loss -= (p[y].max(1e-12) as f64).ln();
        for c in 0..k {
            let onehot = if c == y { 1.0 } else { 0.0 };
            dl.data[bi * k + c] = (p[c] - onehot) / b as f32;
        }
    }
    ((loss / b as f64) as f32, dl)
}

/// Minibatch-loop knobs (learning rate lives in the [`Optimizer`]).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch: usize,
    /// stop after this many optimizer steps regardless of epochs
    /// (0 = no cap) — the `make train-smoke` lever
    pub max_steps: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig { epochs: 8, batch: 16, max_steps: 0, seed: 0x51AC }
    }
}

/// Gather `idx` rows of a split into a (b, c, h, w) batch + labels.
pub fn gather_batch(split: &Split, idx: &[usize]) -> (Tensor, Vec<u8>) {
    let per = split.c * split.h * split.w;
    let mut data = Vec::with_capacity(idx.len() * per);
    let mut labels = Vec::with_capacity(idx.len());
    for &i in idx {
        data.extend_from_slice(&split.images[i * per..(i + 1) * per]);
        labels.push(split.labels[i]);
    }
    (
        Tensor::new(&[idx.len(), split.c, split.h, split.w], data),
        labels,
    )
}

/// Run the minibatch training loop: shuffle each epoch
/// ([`Rng::permutation`]), training-mode forward → cross-entropy →
/// manual backward → optimizer step.  Returns the mean loss per epoch
/// (the last entry may cover a partial epoch when `max_steps` hits).
pub fn fit(
    model: &mut TrainModel,
    backend: &mut TrainBackend,
    opt: &mut Optimizer,
    split: &Split,
    cfg: &TrainConfig,
) -> Result<Vec<f32>> {
    if cfg.batch == 0 || split.n < cfg.batch {
        crate::bail!(
            "batch size {} invalid for a {}-sample split",
            cfg.batch,
            split.n
        );
    }
    let mut rng = Rng::new(cfg.seed ^ 0x7A17_0001);
    let steps_per_epoch = split.n / cfg.batch;
    let mut remaining =
        if cfg.max_steps == 0 { usize::MAX } else { cfg.max_steps };
    let mut history = Vec::new();
    for _ep in 0..cfg.epochs {
        if remaining == 0 {
            break;
        }
        let perm = rng.permutation(split.n);
        let mut sum = 0.0f64;
        let mut cnt = 0usize;
        for s in 0..steps_per_epoch {
            if remaining == 0 {
                break;
            }
            let idx = &perm[s * cfg.batch..(s + 1) * cfg.batch];
            let (xb, yb) = gather_batch(split, idx);
            let pass = model.forward_train(&xb, backend)?;
            let (loss, dlogits) = softmax_cross_entropy(&pass.logits, &yb);
            let grads = model.backward(&pass, &dlogits)?;
            model.apply_grads(&grads, opt);
            sum += loss as f64;
            cnt += 1;
            remaining -= 1;
        }
        if cnt > 0 {
            history.push((sum / cnt as f64) as f32);
        }
    }
    Ok(history)
}

/// Top-1 accuracy of the model over a split (inference-mode forward).
pub fn evaluate(
    model: &TrainModel,
    backend: &mut TrainBackend,
    split: &Split,
    batch: usize,
) -> Result<f32> {
    let batch = batch.max(1);
    let mut correct = 0usize;
    let mut s = 0usize;
    while s < split.n {
        let e = (s + batch).min(split.n);
        let idx: Vec<usize> = (s..e).collect();
        let (xb, yb) = gather_batch(split, &idx);
        let logits = model.forward_eval(&xb, backend)?;
        let k = logits.shape[1];
        for (bi, &y) in yb.iter().enumerate() {
            if tensor::argmax(&logits.data[bi * k..(bi + 1) * k]) == y as usize
            {
                correct += 1;
            }
        }
        s = e;
    }
    Ok(correct as f32 / split.n.max(1) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_entropy_uniform_logits_is_ln_k() {
        let logits = Tensor::zeros(&[4, 3]);
        let (loss, dl) = softmax_cross_entropy(&logits, &[0, 1, 2, 0]);
        assert!((loss - 3.0f32.ln()).abs() < 1e-5, "got {loss}");
        // gradient rows sum to zero and the label entry is negative
        for bi in 0..4 {
            let row = &dl.data[bi * 3..(bi + 1) * 3];
            assert!(row.iter().sum::<f32>().abs() < 1e-6);
        }
        assert!(dl.data[0] < 0.0);
    }

    #[test]
    fn cross_entropy_gradient_matches_finite_differences() {
        let logits = Tensor::new(
            &[2, 3],
            vec![0.5, -1.0, 0.25, 2.0, 0.1, -0.6],
        );
        let labels = [2u8, 0];
        let (_, dl) = softmax_cross_entropy(&logits, &labels);
        let h = 1e-3f32;
        for i in 0..logits.numel() {
            let mut lp = logits.clone();
            lp.data[i] += h;
            let mut lm = logits.clone();
            lm.data[i] -= h;
            let (fp, _) = softmax_cross_entropy(&lp, &labels);
            let (fm, _) = softmax_cross_entropy(&lm, &labels);
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (dl.data[i] - fd).abs() < 1e-3,
                "dlogits[{i}]: {} vs {fd}",
                dl.data[i]
            );
        }
    }

    #[test]
    fn gather_batch_picks_rows() {
        let split = crate::data::datasets::synth_shapes(8, 3);
        let (xb, yb) = gather_batch(&split, &[5, 0, 2]);
        assert_eq!(xb.shape, vec![3, 1, 16, 16]);
        assert_eq!(yb, vec![split.labels[5], split.labels[0], split.labels[2]]);
        let per = 16 * 16;
        assert_eq!(&xb.data[..per], &split.images[5 * per..6 * per]);
    }
}
