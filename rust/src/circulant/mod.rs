//! Block-circulant matrix (BCM) algebra — paper Eq. (1)/(2).
//!
//! A `Bcm` stores an `M×N` block-circulant weight *compressed* as
//! `(P, Q, l)` primary row vectors (`M = P·l`, `N = Q·l`): the same
//! `MN/l`-parameter representation the paper programs onto the CirPTC's
//! `M·N/l` active MRRs.  Three multiply paths are provided:
//!
//! * [`Bcm::mvm`] — direct dense-free multiply (hot path; no expansion,
//!   weight traffic is `MN/l`, mirroring the photonic advantage);
//! * [`Bcm::mvm_fft`] — the paper's Eq. (2) FFT route, O(n log n) per
//!   block-row, wins only at large block order `l`;
//! * [`Bcm::expand`] — dense expansion, the obviously-correct oracle.

use crate::tensor::Tensor;

pub mod fft;

#[derive(Clone, Debug)]
pub struct Bcm {
    /// compressed primary vectors, layout [p][q][s] row-major, len P*Q*l
    pub w: Vec<f32>,
    pub p: usize,
    pub q: usize,
    pub l: usize,
}

impl Bcm {
    pub fn new(p: usize, q: usize, l: usize, w: Vec<f32>) -> Bcm {
        assert_eq!(w.len(), p * q * l, "compressed weight size");
        Bcm { w, p, q, l }
    }

    pub fn zeros(p: usize, q: usize, l: usize) -> Bcm {
        Bcm { w: vec![0.0; p * q * l], p, q, l }
    }

    /// Build from a dense (m, n) matrix by *projection*: each circulant
    /// diagonal takes the mean of the dense entries it would tie together.
    /// (Training embeds the constraint instead — paper: "there is no direct
    /// correspondence or conversion between the two architectures" — but
    /// the projection is useful for tests and for arbitrary-kernel mapping.)
    pub fn project_dense(dense: &Tensor, l: usize) -> Bcm {
        let (m, n) = (dense.shape[0], dense.shape[1]);
        assert!(m % l == 0 && n % l == 0);
        let (p, q) = (m / l, n / l);
        let mut w = vec![0.0f32; p * q * l];
        for bp in 0..p {
            for bq in 0..q {
                for s in 0..l {
                    // average over the diagonal (c - r) mod l == s
                    let mut acc = 0.0f32;
                    for r in 0..l {
                        let c = (r + s) % l;
                        acc += dense.at2(bp * l + r, bq * l + c);
                    }
                    w[(bp * q + bq) * l + s] = acc / l as f32;
                }
            }
        }
        Bcm { w, p, q, l }
    }

    /// Rows (M) and cols (N) of the dense equivalent.
    pub fn m(&self) -> usize {
        self.p * self.l
    }

    pub fn n(&self) -> usize {
        self.q * self.l
    }

    /// Number of independent (stored) parameters = MN/l.
    pub fn params(&self) -> usize {
        self.w.len()
    }

    /// Compression ratio vs dense: always exactly 1/l.
    pub fn compression(&self) -> f64 {
        self.params() as f64 / (self.m() * self.n()) as f64
    }

    #[inline]
    fn block(&self, bp: usize, bq: usize) -> &[f32] {
        let off = (bp * self.q + bq) * self.l;
        &self.w[off..off + self.l]
    }

    /// Dense expansion (oracle path): W[p*l+r, q*l+c] = w[p,q,(c-r) mod l].
    pub fn expand(&self) -> Tensor {
        let (m, n, l) = (self.m(), self.n(), self.l);
        let mut out = vec![0.0f32; m * n];
        for bp in 0..self.p {
            for bq in 0..self.q {
                let blk = self.block(bp, bq);
                for r in 0..l {
                    let row = (bp * l + r) * n + bq * l;
                    for c in 0..l {
                        // (c - r) mod l without branching on negatives
                        out[row + c] = blk[(c + l - r) % l];
                    }
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// Matrix-vector multiply, direct compressed form (no expansion).
    ///
    /// y[p·l + r] = Σ_q Σ_c w[p,q,(c-r) mod l] · x[q·l + c]
    pub fn mvm(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n());
        let l = self.l;
        let mut y = vec![0.0f32; self.m()];
        for bp in 0..self.p {
            let yblk = &mut y[bp * l..(bp + 1) * l];
            for bq in 0..self.q {
                let blk = self.block(bp, bq);
                let xblk = &x[bq * l..(bq + 1) * l];
                for (r, yv) in yblk.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    // split the wrap to keep inner loops branch-free
                    for c in r..l {
                        acc += blk[c - r] * xblk[c];
                    }
                    for c in 0..r {
                        acc += blk[c + l - r] * xblk[c];
                    }
                    *yv += acc;
                }
            }
        }
        y
    }

    /// Matrix-matrix multiply against (N, B) columns -> (M, B).
    ///
    /// Hot path of the photonic simulator and the serving engine.  Works
    /// directly on the compressed representation with batch-contiguous
    /// SAXPY inner loops (EXPERIMENTS.md §Perf: the original
    /// transpose + per-column `mvm` formulation was ~25× slower than a
    /// dense matmul at 48×48/B16; this form matches dense speed while
    /// keeping the l× weight-traffic saving).
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        self.mmm(x, 1)
    }

    /// Multi-column matrix–matrix multiply with block-rows distributed
    /// across up to `threads` scoped workers
    /// ([`crate::util::threadpool::scoped_chunks`]).  Each block-row's
    /// `l×B` output tile is written by exactly one thread with the same
    /// inner-loop order as the serial path, so results are bit-identical
    /// for any thread count.  Small tiles stay serial (spawn overhead
    /// beats the win below ~1M madds).
    pub fn mmm(&self, x: &Tensor, threads: usize) -> Tensor {
        let mut out = vec![0.0f32; self.m() * x.shape[1]];
        self.mmm_into(x, threads, &mut out);
        Tensor::new(&[self.m(), x.shape[1]], out)
    }

    /// [`Bcm::mmm`] writing into a caller-provided **zeroed** output
    /// buffer of `M·B` elements — the zero-alloc form the planned
    /// execution path feeds from its scratch arena.  Identical op order,
    /// so results match `mmm` bit for bit.
    pub fn mmm_into(&self, x: &Tensor, threads: usize, out: &mut [f32]) {
        assert_eq!(x.shape[0], self.n());
        let b = x.shape[1];
        let l = self.l;
        assert_eq!(out.len(), self.m() * b, "output buffer size");
        let madds = self.p * self.q * l * l * b;
        let threads = if self.p >= 2 && madds >= (1 << 20) {
            threads.min(self.p)
        } else {
            1
        };
        if b > 0 {
            crate::util::threadpool::scoped_chunks(
                threads,
                out,
                l * b,
                |bp, ytile| {
                    for bq in 0..self.q {
                        let blk = self.block(bp, bq);
                        for r in 0..l {
                            let yrow = &mut ytile[r * b..(r + 1) * b];
                            for c in 0..l {
                                let coef = blk[(c + l - r) % l];
                                if coef == 0.0 {
                                    continue;
                                }
                                let xrow = &x.data
                                    [(bq * l + c) * b..(bq * l + c + 1) * b];
                                for (y, &xv) in yrow.iter_mut().zip(xrow) {
                                    *y += coef * xv;
                                }
                            }
                        }
                    }
                },
            );
        }
    }

    /// FFT multiply path (paper Eq. 2); numerically ~1e-4 of the direct
    /// path, asymptotically faster for large `l`.
    pub fn mvm_fft(&self, x: &[f32]) -> Vec<f32> {
        fft::bcm_mvm_fft(self, x)
    }

    /// Batched FFT path (paper Eq. 2 over an (N, B) operand block): the
    /// twiddle tables and per-block weight spectra are computed once and
    /// reused across all B columns — the software analogue of programming
    /// the BCM once and streaming the whole batch through it.
    pub fn mmm_fft(&self, x: &Tensor) -> Tensor {
        fft::bcm_mmm_fft(self, x)
    }

    /// Transpose as a BCM: blocks swap position (p ↔ q) and every primary
    /// vector is index-reversed — `circ(w)ᵀ = circ(w')` with
    /// `w'[s] = w[(l − s) mod l]`.  The data-gradient of a BCM multiply is
    /// a multiply by the transpose, so the backward pass stays in the
    /// compressed representation.
    pub fn transpose(&self) -> Bcm {
        let l = self.l;
        let mut w = vec![0.0f32; self.w.len()];
        for bp in 0..self.p {
            for bq in 0..self.q {
                let src = self.block(bp, bq);
                let dst = (bq * self.p + bp) * l;
                w[dst] = src[0];
                for s in 1..l {
                    w[dst + s] = src[l - s];
                }
            }
        }
        Bcm { w, p: self.q, q: self.p, l }
    }

    /// Adjoint (backward pass) of [`Bcm::mmm`], direct time-domain form:
    /// given the forward operand `x` (N, B) and the upstream gradient `dy`
    /// (M, B), returns the gradient w.r.t. the compressed primary vectors
    /// (layout of `self.w`) and w.r.t. `x`.  The oracle for the FFT route.
    ///
    /// dw[p,q,s] = Σ_b Σ_r dy[p·l+r, b] · x[q·l+(r+s) mod l, b]
    /// dx        = Bᵀ · dy
    pub fn mmm_backward(&self, x: &Tensor, dy: &Tensor) -> (Vec<f32>, Tensor) {
        assert_eq!(x.shape[0], self.n());
        assert_eq!(dy.shape[0], self.m());
        assert_eq!(x.shape[1], dy.shape[1], "operand/upstream batch width");
        let (l, b) = (self.l, x.shape[1]);
        let mut dw = vec![0.0f32; self.w.len()];
        for bp in 0..self.p {
            for bq in 0..self.q {
                let off = (bp * self.q + bq) * l;
                for s in 0..l {
                    let mut acc = 0.0f32;
                    for r in 0..l {
                        let c = (r + s) % l;
                        let dyrow =
                            &dy.data[(bp * l + r) * b..(bp * l + r + 1) * b];
                        let xrow =
                            &x.data[(bq * l + c) * b..(bq * l + c + 1) * b];
                        for (dv, xv) in dyrow.iter().zip(xrow) {
                            acc += dv * xv;
                        }
                    }
                    dw[off + s] = acc;
                }
            }
        }
        let dx = self.transpose().mmm(dy, 1);
        (dw, dx)
    }

    /// Adjoint of [`Bcm::mmm_fft`] — the Eq. (2) gradients computed in the
    /// frequency domain with one [`fft::FftPlan`] shared across every
    /// block and column (see [`fft::bcm_mmm_fft_backward`]).
    pub fn mmm_fft_backward(&self, x: &Tensor, dy: &Tensor) -> (Vec<f32>, Tensor) {
        fft::bcm_mmm_fft_backward(self, x, dy)
    }

    /// Backward dispatch through the bench-calibrated crossover
    /// ([`fft::use_fft_path`]): the Eq. (2) adjoint past the crossover
    /// order (shared cached [`fft::FftPlan`], weight spectra computed
    /// once per call and reused by both gradient halves), the direct
    /// time-domain adjoint below it — `benches/mvm_paths.rs` shows direct
    /// winning ~3× at the paper's order 4, where the old hard-coded
    /// power-of-two rule still paid for FFTs.  Override with
    /// `CIRPTC_FFT_CROSSOVER_L`.
    pub fn backward(&self, x: &Tensor, dy: &Tensor) -> (Vec<f32>, Tensor) {
        if fft::use_fft_path(self.l) {
            let plan = fft::plan_for(self.l);
            let spec = fft::WeightSpectra::new(self, &plan);
            fft::bcm_mmm_fft_backward_planned(self, x, dy, &plan, &spec, 1)
        } else {
            self.mmm_backward(x, dy)
        }
    }

    /// Contiguous block-row slice `[r0, r1)` as its own BCM — the unit a
    /// farm partition assigns to one chip ([`crate::farm::partition`]).
    /// The `[p][q][l]` layout keeps whole block-rows contiguous in `w`,
    /// so the slice is a straight copy; every multiply path computes
    /// output rows independently per block-row in the same inner-loop
    /// order, so a shard's product equals rows `[r0·l, r1·l)` of the full
    /// product bit for bit (pinned by `rust/tests/farm_e2e.rs`).
    pub fn block_rows(&self, r0: usize, r1: usize) -> Bcm {
        assert!(r0 <= r1 && r1 <= self.p, "block-row range out of bounds");
        let stride = self.q * self.l;
        Bcm::new(r1 - r0, self.q, self.l, self.w[r0 * stride..r1 * stride].to_vec())
    }

    /// Split a full-range BCM into positive-only halves and a scale, the
    /// paper's time-domain-multiplexed sign handling.  The split depends
    /// only on the weights, so the planned execution path computes it
    /// once per layer ([`SignSplit`]) instead of per chip pass.
    pub fn split_signed(&self) -> (Bcm, Bcm, f32) {
        let scale = self.w.iter().fold(1e-6f32, |m, &v| m.max(v.abs()));
        let pos = self.w.iter().map(|&v| v.max(0.0) / scale).collect();
        let neg = self.w.iter().map(|&v| (-v).max(0.0) / scale).collect();
        (
            Bcm::new(self.p, self.q, self.l, pos),
            Bcm::new(self.p, self.q, self.l, neg),
            scale,
        )
    }
}

/// A [`Bcm::split_signed`] result held as a value: the positive-only
/// halves the chip actually programs plus the rescale factor.  Built once
/// per layer by the planned execution path (`onn::plan`) so serving
/// batches stop re-splitting static weights on every pass pair.
pub struct SignSplit {
    pub pos: Bcm,
    pub neg: Bcm,
    pub scale: f32,
}

impl SignSplit {
    pub fn of(b: &Bcm) -> SignSplit {
        let (pos, neg, scale) = b.split_signed();
        SignSplit { pos, neg, scale }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck::{self, assert_close};
    use crate::util::rng::Rng;

    fn rand_bcm(p: usize, q: usize, l: usize, seed: u64) -> Bcm {
        let mut r = Rng::new(seed);
        let mut w = vec![0.0f32; p * q * l];
        r.fill_uniform(&mut w);
        Bcm::new(p, q, l, w)
    }

    #[test]
    fn expand_order2_matches_eq1() {
        // primary row [w1, w2] -> [[w1, w2], [w2, w1]]
        let b = Bcm::new(1, 1, 2, vec![1.0, 2.0]);
        assert_eq!(b.expand().data, vec![1.0, 2.0, 2.0, 1.0]);
    }

    #[test]
    fn expand_rows_are_right_rotations() {
        let b = rand_bcm(1, 1, 4, 3);
        let d = b.expand();
        for r in 1..4 {
            for c in 0..4 {
                assert_eq!(d.at2(r, c), d.at2(0, (c + 4 - r) % 4));
            }
        }
    }

    #[test]
    fn block_rows_slices_contiguous_rows() {
        let b = rand_bcm(4, 3, 4, 29);
        let s = b.block_rows(1, 3);
        assert_eq!((s.p, s.q, s.l), (2, 3, 4));
        assert_eq!(s.w[..], b.w[1 * 3 * 4..3 * 3 * 4]);
        // the shard's dense expansion is rows [l, 3l) of the full one
        let full = b.expand();
        let shard = s.expand();
        for r in 0..s.m() {
            for c in 0..s.n() {
                assert_eq!(shard.at2(r, c), full.at2(r + 4, c));
            }
        }
        // degenerate shard (a chip assigned zero rows) is legal
        let empty = b.block_rows(2, 2);
        assert_eq!((empty.p, empty.m()), (0, 0));
    }

    #[test]
    fn mvm_matches_expansion() {
        propcheck::check("mvm == expand@x", 100, |g| {
            let (p, q) = (g.usize_in(1, 5), g.usize_in(1, 5));
            let l = *g.choose(&[2usize, 4, 8]);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let x = g.vec_f32(b.n(), -1.0, 1.0);
            let direct = b.mvm(&x);
            let xt = Tensor::new(&[b.n(), 1], x.clone());
            let dense = b.expand().matmul(&xt);
            assert_close(&direct, &dense.data, 1e-4)
        });
    }

    #[test]
    fn matmul_matches_mvm_per_column() {
        let b = rand_bcm(3, 2, 4, 5);
        let mut r = Rng::new(6);
        let mut x = vec![0.0f32; b.n() * 3];
        r.fill_uniform(&mut x);
        let xt = Tensor::new(&[b.n(), 3], x);
        let y = b.matmul(&xt);
        for col in 0..3 {
            let xcol: Vec<f32> =
                (0..b.n()).map(|i| xt.at2(i, col)).collect();
            let ycol = b.mvm(&xcol);
            for (r_, v) in ycol.iter().enumerate() {
                assert!((y.at2(r_, col) - v).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn mmm_threaded_matches_serial() {
        // large enough to clear the parallel threshold: p*q*l*l*b
        // = 8*8*16*16*64 = 1M madds, p >= 2
        let b = rand_bcm(8, 8, 16, 11);
        let mut r = Rng::new(12);
        let mut x = vec![0.0f32; b.n() * 64];
        r.fill_uniform(&mut x);
        let xt = Tensor::new(&[b.n(), 64], x);
        let serial = b.mmm(&xt, 1);
        let par = b.mmm(&xt, 4);
        assert_eq!(serial.data, par.data, "threaded mmm must be bit-identical");
    }

    #[test]
    fn mmm_fft_matches_direct() {
        propcheck::check("mmm_fft == mmm", 60, |g| {
            let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
            let l = *g.choose(&[2usize, 4, 8, 16]);
            let cols = g.usize_in(1, 6);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let x = Tensor::new(&[b.n(), cols], g.vec_f32(b.n() * cols, -1.0, 1.0));
            let direct = b.matmul(&x);
            let fft = b.mmm_fft(&x);
            assert_close(&fft.data, &direct.data, 1e-4)
        });
    }

    #[test]
    fn mmm_fft_single_column_matches_mvm_fft() {
        // both paths share the cached plan tables now, so the agreement
        // is exact, not approximate
        let b = rand_bcm(2, 3, 8, 13);
        let mut r = Rng::new(14);
        let mut x = vec![0.0f32; b.n()];
        r.fill_uniform(&mut x);
        let batched = b.mmm_fft(&Tensor::new(&[b.n(), 1], x.clone()));
        let single = b.mvm_fft(&x);
        assert_eq!(batched.data, single);
    }

    #[test]
    fn mmm_into_matches_mmm() {
        let b = rand_bcm(2, 3, 4, 19);
        let mut r = Rng::new(20);
        let mut xd = vec![0.0f32; b.n() * 5];
        r.fill_uniform(&mut xd);
        let x = Tensor::new(&[b.n(), 5], xd);
        let y = b.mmm(&x, 1);
        let mut out = vec![0.0f32; b.m() * 5];
        b.mmm_into(&x, 4, &mut out);
        assert_eq!(y.data, out);
    }

    #[test]
    fn sign_split_struct_matches_split_signed() {
        let b = rand_bcm(2, 2, 4, 23);
        let (pos, neg, scale) = b.split_signed();
        let s = SignSplit::of(&b);
        assert_eq!(s.pos.w, pos.w);
        assert_eq!(s.neg.w, neg.w);
        assert_eq!(s.scale, scale);
    }

    #[test]
    fn identity_bcm() {
        let mut b = Bcm::zeros(3, 3, 4);
        for i in 0..3 {
            b.w[(i * 3 + i) * 4] = 1.0;
        }
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert_eq!(b.mvm(&x), x);
    }

    #[test]
    fn params_is_mn_over_l() {
        let b = Bcm::zeros(5, 7, 4);
        assert_eq!(b.params(), 5 * 7 * 4);
        assert_eq!(b.params(), b.m() * b.n() / b.l);
        assert!((b.compression() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn project_dense_roundtrips_circulant() {
        // projecting an already-circulant dense matrix is lossless
        let b = rand_bcm(2, 3, 4, 7);
        let back = Bcm::project_dense(&b.expand(), 4);
        assert_close(&b.w, &back.w, 1e-6).unwrap();
    }

    #[test]
    fn split_signed_reconstructs() {
        propcheck::check("sign split reconstructs", 50, |g| {
            let b = {
                let mut w = g.vec_f32(2 * 2 * 4, -3.0, 3.0);
                // ensure at least one negative + one positive
                w[0] = -2.0;
                w[1] = 2.0;
                Bcm::new(2, 2, 4, w)
            };
            let (bp, bn, s) = b.split_signed();
            for (i, &v) in b.w.iter().enumerate() {
                let rec = (bp.w[i] - bn.w[i]) * s;
                prop_assert!((rec - v).abs() < 1e-5, "elem {i}: {rec} vs {v}");
                prop_assert!((0.0..=1.0).contains(&bp.w[i]));
                prop_assert!((0.0..=1.0).contains(&bn.w[i]));
            }
            Ok(())
        });
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        propcheck::check("bcm transpose == dense transpose", 60, |g| {
            let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
            let l = *g.choose(&[2usize, 3, 4, 8]);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let bt = b.transpose();
            assert_close(&bt.expand().data, &b.expand().transpose2().data, 0.0)
        });
    }

    #[test]
    fn backward_satisfies_adjoint_identity() {
        // <B x, dy> == <x, Bᵀ dy> for the dx half of the backward pass
        propcheck::check("mmm_backward adjoint identity", 60, |g| {
            let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
            let l = *g.choose(&[2usize, 4, 8]);
            let cols = g.usize_in(1, 5);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let x = Tensor::new(&[b.n(), cols], g.vec_f32(b.n() * cols, -1.0, 1.0));
            let dy = Tensor::new(&[b.m(), cols], g.vec_f32(b.m() * cols, -1.0, 1.0));
            let y = b.mmm(&x, 1);
            let (_, dx) = b.mmm_backward(&x, &dy);
            let lhs: f64 = y
                .data
                .iter()
                .zip(&dy.data)
                .map(|(a, c)| (*a as f64) * (*c as f64))
                .sum();
            let rhs: f64 = x
                .data
                .iter()
                .zip(&dx.data)
                .map(|(a, c)| (*a as f64) * (*c as f64))
                .sum();
            prop_assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "<Bx,dy>={lhs} vs <x,Btdy>={rhs}"
            );
            Ok(())
        });
    }

    #[test]
    fn backward_dw_matches_loss_perturbation() {
        // y is linear in w, so a central difference of L = Σ y⊙dy along
        // each stored parameter recovers dw exactly (up to f32 rounding)
        let b = rand_bcm(2, 2, 4, 17);
        let mut r = Rng::new(18);
        let mut xd = vec![0.0f32; b.n() * 3];
        r.fill_uniform(&mut xd);
        let x = Tensor::new(&[b.n(), 3], xd);
        let mut dyd = vec![0.0f32; b.m() * 3];
        r.fill_uniform(&mut dyd);
        let dy = Tensor::new(&[b.m(), 3], dyd);
        let loss = |bcm: &Bcm| -> f64 {
            bcm.mmm(&x, 1)
                .data
                .iter()
                .zip(&dy.data)
                .map(|(a, c)| (*a as f64) * (*c as f64))
                .sum()
        };
        let (dw, _) = b.mmm_backward(&x, &dy);
        // y is exactly linear in w, so a large step loses no accuracy and
        // keeps the f32 forward's rounding noise well below the tolerance
        let h = 0.1f32;
        for i in 0..b.w.len() {
            let mut bp = b.clone();
            bp.w[i] += h;
            let mut bm = b.clone();
            bm.w[i] -= h;
            let fd = ((loss(&bp) - loss(&bm)) / (2.0 * h as f64)) as f32;
            assert!(
                (dw[i] - fd).abs() <= 1e-3 * dw[i].abs().max(1.0),
                "param {i}: analytic {} vs fd {fd}",
                dw[i]
            );
        }
    }

    #[test]
    fn fft_backward_matches_direct_backward() {
        propcheck::check("mmm_fft_backward == mmm_backward", 60, |g| {
            let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
            let l = *g.choose(&[2usize, 4, 8, 16]);
            let cols = g.usize_in(1, 5);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let x = Tensor::new(&[b.n(), cols], g.vec_f32(b.n() * cols, -1.0, 1.0));
            let dy = Tensor::new(&[b.m(), cols], g.vec_f32(b.m() * cols, -1.0, 1.0));
            let (dw_d, dx_d) = b.mmm_backward(&x, &dy);
            let (dw_f, dx_f) = b.mmm_fft_backward(&x, &dy);
            assert_close(&dw_f, &dw_d, 1e-3)?;
            assert_close(&dx_f.data, &dx_d.data, 1e-3)
        });
    }

    #[test]
    fn linearity() {
        let b = rand_bcm(2, 2, 4, 9);
        let mut r = Rng::new(10);
        let mut x1 = vec![0.0f32; 8];
        let mut x2 = vec![0.0f32; 8];
        r.fill_uniform(&mut x1);
        r.fill_uniform(&mut x2);
        let sum: Vec<f32> = x1.iter().zip(&x2).map(|(a, b)| a + 2.0 * b).collect();
        let lhs = b.mvm(&sum);
        let y1 = b.mvm(&x1);
        let y2 = b.mvm(&x2);
        for i in 0..lhs.len() {
            assert!((lhs[i] - (y1[i] + 2.0 * y2[i])).abs() < 1e-4);
        }
    }
}
