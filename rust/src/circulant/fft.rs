//! Radix-2 complex FFT + the Eq. (2) circulant multiply path.
//!
//! `y = IFFT(FFT(first_column) ⊙ FFT(x))` per circulant block, summed over
//! block-columns.  Block order must be a power of two for the radix-2
//! transform; the paper's order-4 qualifies (the direct path is still
//! faster at such tiny orders — see benches/ablation — but Eq. (2) is part
//! of the paper's formal story, so both routes ship and cross-validate).

use super::Bcm;
use crate::tensor::Tensor;

/// Precomputed radix-2 FFT plan: the bit-reversal permutation and the
/// per-stage twiddle tables (derived in f64, stored f32), shared across
/// every transform of the same length.  The batched Eq. (2) path
/// ([`bcm_mmm_fft`]) builds one plan per multiply and streams all weight
/// blocks and all B input columns through it, instead of re-deriving the
/// twiddle recurrence once per transform as [`fft_inplace`] does.
pub struct FftPlan {
    n: usize,
    /// permutation target for each index (swap applied when i < rev[i])
    rev: Vec<u32>,
    /// forward twiddles concatenated per stage (len = 2, 4, …, n), k in
    /// 0..len/2 each; the inverse transform conjugates on the fly
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        assert!(n.is_power_of_two(), "radix-2 fft needs power-of-two length");
        let mut rev = vec![0u32; n];
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            rev[i] = j as u32;
        }
        let mut tw_re = Vec::new();
        let mut tw_im = Vec::new();
        let mut len = 2;
        while len <= n {
            for k in 0..len / 2 {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / len as f64;
                tw_re.push(ang.cos() as f32);
                tw_im.push(ang.sin() as f32);
            }
            len <<= 1;
        }
        FftPlan { n, rev, tw_re, tw_im }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    fn run(&self, re: &mut [f32], im: &mut [f32], invert: bool) {
        let n = self.n;
        assert_eq!(re.len(), n);
        assert_eq!(im.len(), n);
        for i in 1..n {
            let j = self.rev[i] as usize;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        let mut tw_off = 0usize;
        let mut len = 2;
        while len <= n {
            for start in (0..n).step_by(len) {
                for k in 0..len / 2 {
                    let wr = self.tw_re[tw_off + k];
                    let wi = if invert {
                        -self.tw_im[tw_off + k]
                    } else {
                        self.tw_im[tw_off + k]
                    };
                    let a = start + k;
                    let b = a + len / 2;
                    let (tr, ti) =
                        (re[b] * wr - im[b] * wi, re[b] * wi + im[b] * wr);
                    re[b] = re[a] - tr;
                    im[b] = im[a] - ti;
                    re[a] += tr;
                    im[a] += ti;
                }
            }
            tw_off += len / 2;
            len <<= 1;
        }
        if invert {
            let inv = 1.0 / n as f32;
            for v in re.iter_mut() {
                *v *= inv;
            }
            for v in im.iter_mut() {
                *v *= inv;
            }
        }
    }

    pub fn forward(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, false);
    }

    pub fn inverse(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, true);
    }
}

/// In-place iterative radix-2 Cooley-Tukey FFT over interleaved (re, im).
pub fn fft_inplace(re: &mut [f32], im: &mut [f32], invert: bool) {
    let n = re.len();
    assert_eq!(im.len(), n);
    assert!(n.is_power_of_two(), "radix-2 fft needs power-of-two length");
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if invert { 1.0f64 } else { -1.0f64 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        for start in (0..n).step_by(len) {
            // twiddle recurrence in f64: an f32 recurrence accumulates
            // visible error across the long stages of larger block orders
            // (each step compounds one rounding of cos/sin products)
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let a = start + k;
                let b = a + len / 2;
                let (crf, cif) = (cr as f32, ci as f32);
                let (tr, ti) = (
                    re[b] * crf - im[b] * cif,
                    re[b] * cif + im[b] * crf,
                );
                re[b] = re[a] - tr;
                im[b] = im[a] - ti;
                re[a] += tr;
                im[a] += ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
        }
        len <<= 1;
    }
    if invert {
        let inv = 1.0 / n as f32;
        for v in re.iter_mut() {
            *v *= inv;
        }
        for v in im.iter_mut() {
            *v *= inv;
        }
    }
}

/// BCM · x via per-block FFTs (paper Eq. 2 generalised to blocks).
pub fn bcm_mvm_fft(b: &Bcm, x: &[f32]) -> Vec<f32> {
    let l = b.l;
    assert!(l.is_power_of_two(), "fft path requires power-of-two order");
    assert_eq!(x.len(), b.n());

    // FFT of every input block once: (Q, l) spectra
    let mut fx_re = vec![0.0f32; b.q * l];
    let mut fx_im = vec![0.0f32; b.q * l];
    for bq in 0..b.q {
        fx_re[bq * l..(bq + 1) * l].copy_from_slice(&x[bq * l..(bq + 1) * l]);
        let (re, im) = (
            &mut fx_re[bq * l..(bq + 1) * l],
            &mut fx_im[bq * l..(bq + 1) * l],
        );
        fft_inplace(re, im, false);
    }

    let mut y = vec![0.0f32; b.m()];
    let mut col_re = vec![0.0f32; l];
    let mut col_im = vec![0.0f32; l];
    let mut acc_re = vec![0.0f32; l];
    let mut acc_im = vec![0.0f32; l];
    for bp in 0..b.p {
        acc_re.iter_mut().for_each(|v| *v = 0.0);
        acc_im.iter_mut().for_each(|v| *v = 0.0);
        for bq in 0..b.q {
            // first column of circulant with primary row w: col[r] = w[(-r) mod l]
            let blk = &b.w[(bp * b.q + bq) * l..(bp * b.q + bq + 1) * l];
            col_re[0] = blk[0];
            for r in 1..l {
                col_re[r] = blk[l - r];
            }
            col_im.iter_mut().for_each(|v| *v = 0.0);
            fft_inplace(&mut col_re, &mut col_im, false);
            // accumulate FFT(col) ⊙ FFT(x_block)
            let (xr, xi) = (&fx_re[bq * l..(bq + 1) * l], &fx_im[bq * l..(bq + 1) * l]);
            for k in 0..l {
                acc_re[k] += col_re[k] * xr[k] - col_im[k] * xi[k];
                acc_im[k] += col_re[k] * xi[k] + col_im[k] * xr[k];
            }
        }
        fft_inplace(&mut acc_re, &mut acc_im, true);
        y[bp * l..(bp + 1) * l].copy_from_slice(&acc_re);
    }
    y
}

/// Batched Eq. (2): `BCM · X` for `X` of shape (N, B).
///
/// Weight spectra (one FFT of each block's first column) and the twiddle
/// tables are computed **once** and reused across all B columns — the
/// lookup-mode amortisation the paper gets from programming the MRR bank
/// once and streaming operand columns through it.
pub fn bcm_mmm_fft(bcm: &Bcm, x: &Tensor) -> Tensor {
    let l = bcm.l;
    assert!(l.is_power_of_two(), "fft path requires power-of-two order");
    assert_eq!(x.shape[0], bcm.n());
    let b = x.shape[1];
    let plan = FftPlan::new(l);

    // weight spectra: (P·Q, l) complex — independent of the batch width
    let n_blocks = bcm.p * bcm.q;
    let mut w_re = vec![0.0f32; n_blocks * l];
    let mut w_im = vec![0.0f32; n_blocks * l];
    for blk_i in 0..n_blocks {
        let blk = &bcm.w[blk_i * l..(blk_i + 1) * l];
        let re = &mut w_re[blk_i * l..(blk_i + 1) * l];
        // first column of the circulant with primary row w:
        // col[r] = w[(-r) mod l]
        re[0] = blk[0];
        for r in 1..l {
            re[r] = blk[l - r];
        }
        plan.forward(re, &mut w_im[blk_i * l..(blk_i + 1) * l]);
    }

    // input spectra: (Q, B, l) complex — one FFT per (block, column)
    let mut x_re = vec![0.0f32; bcm.q * b * l];
    let mut x_im = vec![0.0f32; bcm.q * b * l];
    for bq in 0..bcm.q {
        for col in 0..b {
            let off = (bq * b + col) * l;
            for i in 0..l {
                x_re[off + i] = x.data[(bq * l + i) * b + col];
            }
            plan.forward(&mut x_re[off..off + l], &mut x_im[off..off + l]);
        }
    }

    // per (block-row, column): accumulate ⊙ products in frequency space,
    // one inverse transform each
    let mut out = vec![0.0f32; bcm.m() * b];
    let mut acc_re = vec![0.0f32; l];
    let mut acc_im = vec![0.0f32; l];
    for bp in 0..bcm.p {
        for col in 0..b {
            acc_re.iter_mut().for_each(|v| *v = 0.0);
            acc_im.iter_mut().for_each(|v| *v = 0.0);
            for bq in 0..bcm.q {
                let wo = (bp * bcm.q + bq) * l;
                let xo = (bq * b + col) * l;
                for k in 0..l {
                    let (wr, wi) = (w_re[wo + k], w_im[wo + k]);
                    let (xr, xi) = (x_re[xo + k], x_im[xo + k]);
                    acc_re[k] += wr * xr - wi * xi;
                    acc_im[k] += wr * xi + wi * xr;
                }
            }
            plan.inverse(&mut acc_re, &mut acc_im);
            for r in 0..l {
                out[(bp * l + r) * b + col] = acc_re[r];
            }
        }
    }
    Tensor::new(&[bcm.m(), b], out)
}

/// Adjoint of [`bcm_mmm_fft`]: FFT-domain gradients of `Y = BCM · X`.
///
/// Given the forward operand `x` (N, B) and upstream gradient `dy` (M, B),
/// returns (dw, dx) with `dw` in the compressed primary-vector layout of
/// `bcm.w` and `dx` of shape (N, B).  Both halves stay in the frequency
/// domain (one [`FftPlan`] shared by every block and column, as in the
/// forward pass):
///
/// * `dX_f[q] = Σ_p conj(W_f[p,q]) ⊙ dY_f[p]` — a real circulant is
///   `F⁻¹·diag(W_f)·F`, so its transpose is the circulant with the
///   conjugate spectrum;
/// * `dW_f[p,q] = Σ_cols conj(dY_f[p]) ⊙ X_f[q]` — the circular
///   cross-correlation theorem applied to
///   `dw[s] = Σ_b Σ_r dy[r]·x[(r+s) mod l]`, which lands on the primary
///   row directly (no first-column remap needed).
pub fn bcm_mmm_fft_backward(
    bcm: &Bcm,
    x: &Tensor,
    dy: &Tensor,
) -> (Vec<f32>, Tensor) {
    let l = bcm.l;
    assert!(l.is_power_of_two(), "fft path requires power-of-two order");
    assert_eq!(x.shape[0], bcm.n());
    assert_eq!(dy.shape[0], bcm.m());
    assert_eq!(x.shape[1], dy.shape[1], "operand/upstream batch width");
    let b = x.shape[1];
    let plan = FftPlan::new(l);

    // weight spectra (first-column FFTs), identical to the forward pass
    let n_blocks = bcm.p * bcm.q;
    let mut w_re = vec![0.0f32; n_blocks * l];
    let mut w_im = vec![0.0f32; n_blocks * l];
    for blk_i in 0..n_blocks {
        let blk = &bcm.w[blk_i * l..(blk_i + 1) * l];
        let re = &mut w_re[blk_i * l..(blk_i + 1) * l];
        re[0] = blk[0];
        for r in 1..l {
            re[r] = blk[l - r];
        }
        plan.forward(re, &mut w_im[blk_i * l..(blk_i + 1) * l]);
    }

    // operand spectra (Q, B, l) and upstream spectra (P, B, l)
    let spectra = |t: &Tensor, blocks: usize| -> (Vec<f32>, Vec<f32>) {
        let mut re = vec![0.0f32; blocks * b * l];
        let mut im = vec![0.0f32; blocks * b * l];
        for bi in 0..blocks {
            for col in 0..b {
                let off = (bi * b + col) * l;
                for i in 0..l {
                    re[off + i] = t.data[(bi * l + i) * b + col];
                }
                plan.forward(&mut re[off..off + l], &mut im[off..off + l]);
            }
        }
        (re, im)
    };
    let (x_re, x_im) = spectra(x, bcm.q);
    let (dy_re, dy_im) = spectra(dy, bcm.p);

    let mut acc_re = vec![0.0f32; l];
    let mut acc_im = vec![0.0f32; l];

    // dx: accumulate conj(W_f) ⊙ dY_f over block-rows, one inverse
    // transform per (block-column, column)
    let mut dx = vec![0.0f32; bcm.n() * b];
    for bq in 0..bcm.q {
        for col in 0..b {
            acc_re.iter_mut().for_each(|v| *v = 0.0);
            acc_im.iter_mut().for_each(|v| *v = 0.0);
            for bp in 0..bcm.p {
                let wo = (bp * bcm.q + bq) * l;
                let go = (bp * b + col) * l;
                for k in 0..l {
                    let (wr, wi) = (w_re[wo + k], -w_im[wo + k]);
                    let (gr, gi) = (dy_re[go + k], dy_im[go + k]);
                    acc_re[k] += wr * gr - wi * gi;
                    acc_im[k] += wr * gi + wi * gr;
                }
            }
            plan.inverse(&mut acc_re, &mut acc_im);
            for i in 0..l {
                dx[(bq * l + i) * b + col] = acc_re[i];
            }
        }
    }

    // dw: accumulate conj(dY_f) ⊙ X_f over columns, one inverse transform
    // per block — the result is real (x, dy real), acc_im only carries
    // rounding noise
    let mut dw = vec![0.0f32; bcm.w.len()];
    for bp in 0..bcm.p {
        for bq in 0..bcm.q {
            acc_re.iter_mut().for_each(|v| *v = 0.0);
            acc_im.iter_mut().for_each(|v| *v = 0.0);
            for col in 0..b {
                let go = (bp * b + col) * l;
                let xo = (bq * b + col) * l;
                for k in 0..l {
                    let (gr, gi) = (dy_re[go + k], -dy_im[go + k]);
                    let (xr, xi) = (x_re[xo + k], x_im[xo + k]);
                    acc_re[k] += gr * xr - gi * xi;
                    acc_im[k] += gr * xi + gi * xr;
                }
            }
            plan.inverse(&mut acc_re, &mut acc_im);
            let off = (bp * bcm.q + bq) * l;
            dw[off..off + l].copy_from_slice(&acc_re);
        }
    }
    (dw, Tensor::new(&[bcm.n(), b], dx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, assert_close};
    use crate::util::rng::Rng;

    #[test]
    fn fft_roundtrip() {
        let mut r = Rng::new(1);
        for n in [2usize, 4, 8, 16, 64, 256] {
            let orig: Vec<f32> = (0..n).map(|_| r.f32() - 0.5).collect();
            let mut re = orig.clone();
            let mut im = vec![0.0f32; n];
            fft_inplace(&mut re, &mut im, false);
            fft_inplace(&mut re, &mut im, true);
            assert_close(&re, &orig, 1e-5).unwrap();
            assert!(im.iter().all(|v| v.abs() < 1e-5));
        }
    }

    #[test]
    fn fft_of_delta_is_flat() {
        let mut re = vec![1.0, 0.0, 0.0, 0.0];
        let mut im = vec![0.0; 4];
        fft_inplace(&mut re, &mut im, false);
        assert_close(&re, &[1.0; 4], 1e-6).unwrap();
        assert_close(&im, &[0.0; 4], 1e-6).unwrap();
    }

    #[test]
    fn fft_parseval() {
        let mut r = Rng::new(2);
        let x: Vec<f32> = (0..16).map(|_| r.f32() - 0.5).collect();
        let e_time: f32 = x.iter().map(|v| v * v).sum();
        let mut re = x.clone();
        let mut im = vec![0.0f32; 16];
        fft_inplace(&mut re, &mut im, false);
        let e_freq: f32 =
            re.iter().zip(&im).map(|(a, b)| a * a + b * b).sum::<f32>() / 16.0;
        assert!((e_time - e_freq).abs() < 1e-4);
    }

    #[test]
    fn fft_mvm_matches_direct() {
        propcheck::check("fft mvm == direct mvm", 80, |g| {
            let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
            let l = *g.choose(&[2usize, 4, 8, 16]);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let x = g.vec_f32(b.n(), -1.0, 1.0);
            // f64 twiddle recurrence keeps the paths within 1e-4 even at
            // the larger block orders (was 1e-3 with f32 twiddles)
            assert_close(&b.mvm_fft(&x), &b.mvm(&x), 1e-4)
        });
    }

    #[test]
    fn plan_matches_fft_inplace() {
        let mut r = Rng::new(5);
        for n in [1usize, 2, 4, 8, 32, 128] {
            let plan = FftPlan::new(n);
            assert_eq!(plan.len(), n);
            let orig: Vec<f32> = (0..n).map(|_| r.f32() - 0.5).collect();
            let (mut re_a, mut im_a) = (orig.clone(), vec![0.0f32; n]);
            let (mut re_b, mut im_b) = (orig.clone(), vec![0.0f32; n]);
            fft_inplace(&mut re_a, &mut im_a, false);
            plan.forward(&mut re_b, &mut im_b);
            assert_close(&re_a, &re_b, 1e-5).unwrap();
            assert_close(&im_a, &im_b, 1e-5).unwrap();
            plan.inverse(&mut re_b, &mut im_b);
            assert_close(&re_b, &orig, 1e-5).unwrap();
        }
    }

    #[test]
    fn mmm_fft_columns_are_independent() {
        // column j of the batched transform == the single-column transform
        // of column j (the property the engine's one-pass-per-layer
        // batching rests on)
        let mut r = Rng::new(6);
        let mut w = vec![0.0f32; 2 * 3 * 8];
        r.fill_uniform(&mut w);
        let b = Bcm::new(2, 3, 8, w);
        let cols = 5;
        let mut xd = vec![0.0f32; b.n() * cols];
        r.fill_uniform(&mut xd);
        let x = Tensor::new(&[b.n(), cols], xd);
        let y = bcm_mmm_fft(&b, &x);
        for col in 0..cols {
            let xcol: Vec<f32> = (0..b.n()).map(|i| x.at2(i, col)).collect();
            let ycol =
                bcm_mmm_fft(&b, &Tensor::new(&[b.n(), 1], xcol));
            for row in 0..b.m() {
                assert_eq!(
                    y.at2(row, col),
                    ycol.data[row],
                    "row {row} col {col}"
                );
            }
        }
    }

    #[test]
    fn fft_backward_identity_bcm_passes_gradient_through() {
        // identity weights: dx == dy and dw == Σ_b dy ⊙ rotated x
        let mut b = Bcm::zeros(2, 2, 4);
        for i in 0..2 {
            b.w[(i * 2 + i) * 4] = 1.0;
        }
        let mut r = Rng::new(7);
        let mut xd = vec![0.0f32; 8 * 3];
        let mut dyd = vec![0.0f32; 8 * 3];
        r.fill_uniform(&mut xd);
        r.fill_uniform(&mut dyd);
        let x = Tensor::new(&[8, 3], xd);
        let dy = Tensor::new(&[8, 3], dyd);
        let (_, dx) = bcm_mmm_fft_backward(&b, &x, &dy);
        assert_close(&dx.data, &dy.data, 1e-5).unwrap();
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn fft_rejects_non_power_of_two_order() {
        let b = Bcm::zeros(1, 1, 3);
        b.mvm_fft(&[0.0, 0.0, 0.0]);
    }
}
