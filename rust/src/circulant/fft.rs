//! Radix-2 complex FFT + the Eq. (2) circulant multiply path.
//!
//! `y = IFFT(FFT(first_column) ⊙ FFT(x))` per circulant block, summed over
//! block-columns.  Block order must be a power of two for the radix-2
//! transform; the paper's order-4 qualifies (the direct path is still
//! faster at such tiny orders — see benches/ablation — but Eq. (2) is part
//! of the paper's formal story, so both routes ship and cross-validate).
//!
//! Two tiers of the batched Eq. (2) kernel ship (DESIGN.md §perf):
//!
//! * the **unplanned reference** ([`bcm_mmm_fft`] /
//!   [`bcm_mmm_fft_backward`]) rebuilds the [`FftPlan`] and every weight
//!   block's first-column spectrum on each call and runs serially — the
//!   obviously-correct oracle, and the perf baseline the planned path is
//!   benchmarked against;
//! * the **planned path** ([`bcm_mmm_fft_planned`] /
//!   [`bcm_mmm_fft_backward_planned`]) takes a cached plan ([`plan_for`])
//!   and precomputed [`WeightSpectra`], draws its operand-spectrum
//!   buffers from the thread-local scratch arena
//!   ([`crate::util::scratch`]) and spreads block-rows across scoped
//!   threads.  It is **bit-identical** to the reference for any thread
//!   count (per-(block, column) op order is unchanged; the propcheck
//!   suite in `rust/tests/planned_path.rs` pins this).

use crate::util::sync::{Arc, Mutex, OnceLock, PoisonError};

use super::Bcm;
use crate::tensor::Tensor;
use crate::util::scratch;
use crate::util::threadpool::scoped_chunks;

/// Precomputed radix-2 FFT plan: the bit-reversal permutation and the
/// per-stage twiddle tables (derived in f64, stored f32), shared across
/// every transform of the same length.  The batched Eq. (2) path
/// ([`bcm_mmm_fft`]) builds one plan per multiply and streams all weight
/// blocks and all B input columns through it, instead of re-deriving the
/// twiddle recurrence once per transform as [`fft_inplace`] does.
pub struct FftPlan {
    n: usize,
    /// permutation target for each index (swap applied when i < rev[i])
    rev: Vec<u32>,
    /// forward twiddles concatenated per stage (len = 2, 4, …, n), k in
    /// 0..len/2 each; the inverse transform conjugates on the fly
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        assert!(n.is_power_of_two(), "radix-2 fft needs power-of-two length");
        let mut rev = vec![0u32; n];
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            rev[i] = j as u32;
        }
        let mut tw_re = Vec::new();
        let mut tw_im = Vec::new();
        let mut len = 2;
        while len <= n {
            for k in 0..len / 2 {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / len as f64;
                tw_re.push(ang.cos() as f32);
                tw_im.push(ang.sin() as f32);
            }
            len <<= 1;
        }
        FftPlan { n, rev, tw_re, tw_im }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    fn run(&self, re: &mut [f32], im: &mut [f32], invert: bool) {
        let n = self.n;
        assert_eq!(re.len(), n);
        assert_eq!(im.len(), n);
        for i in 1..n {
            let j = self.rev[i] as usize;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        let mut tw_off = 0usize;
        let mut len = 2;
        while len <= n {
            for start in (0..n).step_by(len) {
                for k in 0..len / 2 {
                    let wr = self.tw_re[tw_off + k];
                    let wi = if invert {
                        -self.tw_im[tw_off + k]
                    } else {
                        self.tw_im[tw_off + k]
                    };
                    let a = start + k;
                    let b = a + len / 2;
                    let (tr, ti) =
                        (re[b] * wr - im[b] * wi, re[b] * wi + im[b] * wr);
                    re[b] = re[a] - tr;
                    im[b] = im[a] - ti;
                    re[a] += tr;
                    im[a] += ti;
                }
            }
            tw_off += len / 2;
            len <<= 1;
        }
        if invert {
            let inv = 1.0 / n as f32;
            for v in re.iter_mut() {
                *v *= inv;
            }
            for v in im.iter_mut() {
                *v *= inv;
            }
        }
    }

    pub fn forward(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, false);
    }

    pub fn inverse(&self, re: &mut [f32], im: &mut [f32]) {
        self.run(re, im, true);
    }
}

/// A cache of [`FftPlan`]s keyed by transform length.  Plans are
/// immutable once built, so one `Arc` per length serves every layer,
/// every worker and every probe pass — nothing on the hot path re-derives
/// a bit-reversal table or twiddle stage again.
///
/// Const-constructible (a plain `Vec` behind one mutex, no lazy-init
/// cell), so the process-wide instance below is a `static` and the
/// model-checked tests in `rust/tests/loom_models.rs` can drive fresh
/// instances through every lock interleaving.  The handful of distinct
/// block orders in any model makes linear lookup the right structure.
pub struct PlanCache {
    plans: Mutex<Vec<Arc<FftPlan>>>,
}

impl PlanCache {
    pub const fn new() -> PlanCache {
        PlanCache { plans: Mutex::new(Vec::new()) }
    }

    /// The shared plan for power-of-two length `n` (built on first use).
    /// A poisoned cache lock recovers: plans already inserted are
    /// complete (insertion is the last step under the lock).
    pub fn get(&self, n: usize) -> Arc<FftPlan> {
        let mut plans = self.plans.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(p) = plans.iter().find(|p| p.len() == n) {
            return Arc::clone(p);
        }
        let p = Arc::new(FftPlan::new(n));
        plans.push(Arc::clone(&p));
        p
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

/// Process-wide plan cache.
static PLAN_CACHE: PlanCache = PlanCache::new();

/// The shared plan for power-of-two length `n` (building it on first use).
pub fn plan_for(n: usize) -> Arc<FftPlan> {
    PLAN_CACHE.get(n)
}

/// Block order at which the Eq. (2) route overtakes the direct compressed
/// kernel.  Calibrated by `benches/mvm_paths.rs` (direct wins clearly at
/// the paper's order 4, the FFT route wins from order ~16 up on serving
/// batch widths); override with `CIRPTC_FFT_CROSSOVER_L` (`0` forces the
/// direct route everywhere, `1` forces FFT for every power-of-two order).
pub fn fft_crossover_l() -> usize {
    static CROSSOVER: OnceLock<usize> = OnceLock::new();
    *CROSSOVER.get_or_init(|| {
        std::env::var("CIRPTC_FFT_CROSSOVER_L")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(16)
    })
}

/// Auto-select: should a BCM of block order `l` take the Eq. (2) route?
pub fn use_fft_path(l: usize) -> bool {
    let crossover = fft_crossover_l();
    crossover != 0 && l.is_power_of_two() && l >= crossover
}

/// Precomputed first-column spectra of every block of a [`Bcm`] — the
/// `(P·Q, l)` complex array [`bcm_mmm_fft`] and [`bcm_mmm_fft_backward`]
/// otherwise recompute per call.  Stored interleaved (`[re; l][im; l]`
/// per block) so one slice feeds both halves of the accumulate kernel.
/// Valid for exactly the weight values it was built from; the engine
/// rebuilds it wherever the weights change (training steps, hot swaps).
pub struct WeightSpectra {
    l: usize,
    n_blocks: usize,
    data: Vec<f32>,
}

impl WeightSpectra {
    /// FFT every block's first column once (identical op order to the
    /// in-call loop of [`bcm_mmm_fft`], so planned results stay
    /// bit-identical to the reference).
    pub fn new(bcm: &Bcm, plan: &FftPlan) -> WeightSpectra {
        let l = bcm.l;
        assert_eq!(plan.len(), l, "plan length must match block order");
        let n_blocks = bcm.p * bcm.q;
        let l2 = 2 * l;
        let mut data = vec![0.0f32; n_blocks * l2];
        for blk_i in 0..n_blocks {
            let blk = &bcm.w[blk_i * l..(blk_i + 1) * l];
            let (re, im) = data[blk_i * l2..(blk_i + 1) * l2].split_at_mut(l);
            // first column of the circulant with primary row w:
            // col[r] = w[(-r) mod l]
            re[0] = blk[0];
            for r in 1..l {
                re[r] = blk[l - r];
            }
            plan.forward(re, im);
        }
        WeightSpectra { l, n_blocks, data }
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Block order the spectra were built at.
    pub fn block_order(&self) -> usize {
        self.l
    }

    /// The interleaved spectra buffer (`[re; l][im; l]` per block) — read
    /// by the static validator's conjugate-symmetry pass.
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// (re, im) spectrum of block `i` (row-major over `[p][q]`).
    #[inline]
    fn block(&self, i: usize) -> (&[f32], &[f32]) {
        let l2 = 2 * self.l;
        self.data[i * l2..(i + 1) * l2].split_at(self.l)
    }
}

/// Accumulate volume (`P·Q·l·B` complex madds) below which the planned
/// kernels stay serial — scoped-spawn overhead beats the win on tiny
/// tiles, and the paper's order-4 layers at small batch stay under it.
const FFT_PAR_MIN_MADDS: usize = 1 << 16;

fn fft_threads(bcm: &Bcm, b: usize, threads: usize) -> usize {
    if threads > 1 && bcm.p >= 2 && bcm.p * bcm.q * bcm.l * b >= FFT_PAR_MIN_MADDS {
        threads
    } else {
        1
    }
}

/// Forward-transform the columns of `t` (shape `(blocks·l, b)`) into an
/// interleaved spectrum buffer from the scratch arena: entry
/// `(blk·b + col)` holds `[re; l][im; l]` at offset `(blk·b + col)·2l`.
/// Per-(block, column) op order matches the reference loops exactly.
fn column_spectra(
    t: &Tensor,
    blocks: usize,
    l: usize,
    plan: &FftPlan,
    threads: usize,
) -> Vec<f32> {
    let b = t.shape[1];
    let l2 = 2 * l;
    let mut spec = scratch::take(blocks * b * l2);
    if b > 0 {
        scoped_chunks(threads, &mut spec, l2, |idx, chunk| {
            let (re, im) = chunk.split_at_mut(l);
            let (blk, col) = (idx / b, idx % b);
            for i in 0..l {
                re[i] = t.data[(blk * l + i) * b + col];
            }
            // `im` is zeroed by the arena
            plan.forward(re, im);
        });
    }
    spec
}

/// In-place iterative radix-2 Cooley-Tukey FFT over interleaved (re, im).
pub fn fft_inplace(re: &mut [f32], im: &mut [f32], invert: bool) {
    let n = re.len();
    assert_eq!(im.len(), n);
    assert!(n.is_power_of_two(), "radix-2 fft needs power-of-two length");
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if invert { 1.0f64 } else { -1.0f64 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        for start in (0..n).step_by(len) {
            // twiddle recurrence in f64: an f32 recurrence accumulates
            // visible error across the long stages of larger block orders
            // (each step compounds one rounding of cos/sin products)
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let a = start + k;
                let b = a + len / 2;
                let (crf, cif) = (cr as f32, ci as f32);
                let (tr, ti) = (
                    re[b] * crf - im[b] * cif,
                    re[b] * cif + im[b] * crf,
                );
                re[b] = re[a] - tr;
                im[b] = im[a] - ti;
                re[a] += tr;
                im[a] += ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
        }
        len <<= 1;
    }
    if invert {
        let inv = 1.0 / n as f32;
        for v in re.iter_mut() {
            *v *= inv;
        }
        for v in im.iter_mut() {
            *v *= inv;
        }
    }
}

/// BCM · x via per-block FFTs (paper Eq. 2 generalised to blocks).
///
/// Single-vector path used by calibration probes and examples.  Runs off
/// the shared plan cache ([`plan_for`]) and the thread-local scratch
/// arena, so a probe pass no longer pays FFT setup or allocation — and
/// its transforms are bit-identical to the batched [`bcm_mmm_fft`] at
/// `B = 1` (both use the same plan tables).
pub fn bcm_mvm_fft(b: &Bcm, x: &[f32]) -> Vec<f32> {
    let l = b.l;
    assert!(l.is_power_of_two(), "fft path requires power-of-two order");
    assert_eq!(x.len(), b.n());
    let plan = plan_for(l);
    let l2 = 2 * l;

    // FFT of every input block once: interleaved (Q, [re l][im l]) spectra
    let mut fx = scratch::take(b.q * l2);
    for bq in 0..b.q {
        let (re, im) = fx[bq * l2..(bq + 1) * l2].split_at_mut(l);
        re.copy_from_slice(&x[bq * l..(bq + 1) * l]);
        plan.forward(re, im);
    }

    let mut y = scratch::take(b.m());
    let mut col = scratch::take(l2);
    let mut acc = scratch::take(l2);
    for bp in 0..b.p {
        let (acc_re, acc_im) = acc.split_at_mut(l);
        acc_re.fill(0.0);
        acc_im.fill(0.0);
        for bq in 0..b.q {
            // first column of circulant with primary row w: col[r] = w[(-r) mod l]
            let blk = &b.w[(bp * b.q + bq) * l..(bp * b.q + bq + 1) * l];
            let (col_re, col_im) = col.split_at_mut(l);
            col_re[0] = blk[0];
            for r in 1..l {
                col_re[r] = blk[l - r];
            }
            col_im.fill(0.0);
            plan.forward(col_re, col_im);
            // accumulate FFT(col) ⊙ FFT(x_block)
            let (xr, xi) = fx[bq * l2..(bq + 1) * l2].split_at(l);
            for k in 0..l {
                acc_re[k] += col_re[k] * xr[k] - col_im[k] * xi[k];
                acc_im[k] += col_re[k] * xi[k] + col_im[k] * xr[k];
            }
        }
        plan.inverse(acc_re, acc_im);
        y[bp * l..(bp + 1) * l].copy_from_slice(acc_re);
    }
    scratch::put(acc);
    scratch::put(col);
    scratch::put(fx);
    y
}

/// Batched Eq. (2): `BCM · X` for `X` of shape (N, B).
///
/// Weight spectra (one FFT of each block's first column) and the twiddle
/// tables are computed **once** and reused across all B columns — the
/// lookup-mode amortisation the paper gets from programming the MRR bank
/// once and streaming operand columns through it.
pub fn bcm_mmm_fft(bcm: &Bcm, x: &Tensor) -> Tensor {
    let l = bcm.l;
    assert!(l.is_power_of_two(), "fft path requires power-of-two order");
    assert_eq!(x.shape[0], bcm.n());
    let b = x.shape[1];
    let plan = FftPlan::new(l);

    // weight spectra: (P·Q, l) complex — independent of the batch width
    let n_blocks = bcm.p * bcm.q;
    let mut w_re = vec![0.0f32; n_blocks * l];
    let mut w_im = vec![0.0f32; n_blocks * l];
    for blk_i in 0..n_blocks {
        let blk = &bcm.w[blk_i * l..(blk_i + 1) * l];
        let re = &mut w_re[blk_i * l..(blk_i + 1) * l];
        // first column of the circulant with primary row w:
        // col[r] = w[(-r) mod l]
        re[0] = blk[0];
        for r in 1..l {
            re[r] = blk[l - r];
        }
        plan.forward(re, &mut w_im[blk_i * l..(blk_i + 1) * l]);
    }

    // input spectra: (Q, B, l) complex — one FFT per (block, column)
    let mut x_re = vec![0.0f32; bcm.q * b * l];
    let mut x_im = vec![0.0f32; bcm.q * b * l];
    for bq in 0..bcm.q {
        for col in 0..b {
            let off = (bq * b + col) * l;
            for i in 0..l {
                x_re[off + i] = x.data[(bq * l + i) * b + col];
            }
            plan.forward(&mut x_re[off..off + l], &mut x_im[off..off + l]);
        }
    }

    // per (block-row, column): accumulate ⊙ products in frequency space,
    // one inverse transform each
    let mut out = vec![0.0f32; bcm.m() * b];
    let mut acc_re = vec![0.0f32; l];
    let mut acc_im = vec![0.0f32; l];
    for bp in 0..bcm.p {
        for col in 0..b {
            acc_re.iter_mut().for_each(|v| *v = 0.0);
            acc_im.iter_mut().for_each(|v| *v = 0.0);
            for bq in 0..bcm.q {
                let wo = (bp * bcm.q + bq) * l;
                let xo = (bq * b + col) * l;
                for k in 0..l {
                    let (wr, wi) = (w_re[wo + k], w_im[wo + k]);
                    let (xr, xi) = (x_re[xo + k], x_im[xo + k]);
                    acc_re[k] += wr * xr - wi * xi;
                    acc_im[k] += wr * xi + wi * xr;
                }
            }
            plan.inverse(&mut acc_re, &mut acc_im);
            for r in 0..l {
                out[(bp * l + r) * b + col] = acc_re[r];
            }
        }
    }
    Tensor::new(&[bcm.m(), b], out)
}

/// Planned batched Eq. (2): [`bcm_mmm_fft`] with the per-call invariants
/// hoisted out — `plan` from the shared cache, `wspec` precomputed when
/// the weights last changed — operand-spectrum buffers from the scratch
/// arena, and block-rows spread over up to `threads` scoped workers.
///
/// **Bit-identical** to [`bcm_mmm_fft`] for any `threads`: every
/// (block, column) tile runs the same op sequence on the same spectra,
/// and each output tile is written by exactly one thread.
pub fn bcm_mmm_fft_planned(
    bcm: &Bcm,
    x: &Tensor,
    plan: &FftPlan,
    wspec: &WeightSpectra,
    threads: usize,
) -> Tensor {
    let l = bcm.l;
    assert!(l.is_power_of_two(), "fft path requires power-of-two order");
    assert_eq!(plan.len(), l, "plan length must match block order");
    assert_eq!(wspec.n_blocks(), bcm.p * bcm.q, "stale weight spectra");
    assert_eq!(x.shape[0], bcm.n());
    let b = x.shape[1];
    let l2 = 2 * l;
    let workers = fft_threads(bcm, b, threads);

    // input spectra: (Q, B, [re l][im l]) — one FFT per (block, column)
    let xs = column_spectra(x, bcm.q, l, plan, workers);

    // per (block-row, column): accumulate ⊙ products in frequency space,
    // one inverse transform each; chunk bp owns output rows
    // [bp·l, (bp+1)·l), so any thread split is bit-identical
    let mut out = scratch::take(bcm.m() * b);
    if b > 0 {
        scoped_chunks(workers, &mut out, l * b, |bp, ytile| {
            // lint:allow(scratch-alloc): scoped threads are fresh per call, their arenas never warm
            let mut acc_re = vec![0.0f32; l];
            // lint:allow(scratch-alloc): scoped threads are fresh per call, their arenas never warm
            let mut acc_im = vec![0.0f32; l];
            for col in 0..b {
                acc_re.fill(0.0);
                acc_im.fill(0.0);
                for bq in 0..bcm.q {
                    let (wr, wi) = wspec.block(bp * bcm.q + bq);
                    let (xr, xi) =
                        xs[(bq * b + col) * l2..(bq * b + col + 1) * l2].split_at(l);
                    for k in 0..l {
                        acc_re[k] += wr[k] * xr[k] - wi[k] * xi[k];
                        acc_im[k] += wr[k] * xi[k] + wi[k] * xr[k];
                    }
                }
                plan.inverse(&mut acc_re, &mut acc_im);
                for r in 0..l {
                    ytile[r * b + col] = acc_re[r];
                }
            }
        });
    }
    scratch::put(xs);
    Tensor::new(&[bcm.m(), b], out)
}

/// Adjoint of [`bcm_mmm_fft`]: FFT-domain gradients of `Y = BCM · X`.
///
/// Given the forward operand `x` (N, B) and upstream gradient `dy` (M, B),
/// returns (dw, dx) with `dw` in the compressed primary-vector layout of
/// `bcm.w` and `dx` of shape (N, B).  Both halves stay in the frequency
/// domain (one [`FftPlan`] shared by every block and column, as in the
/// forward pass):
///
/// * `dX_f[q] = Σ_p conj(W_f[p,q]) ⊙ dY_f[p]` — a real circulant is
///   `F⁻¹·diag(W_f)·F`, so its transpose is the circulant with the
///   conjugate spectrum;
/// * `dW_f[p,q] = Σ_cols conj(dY_f[p]) ⊙ X_f[q]` — the circular
///   cross-correlation theorem applied to
///   `dw[s] = Σ_b Σ_r dy[r]·x[(r+s) mod l]`, which lands on the primary
///   row directly (no first-column remap needed).
pub fn bcm_mmm_fft_backward(
    bcm: &Bcm,
    x: &Tensor,
    dy: &Tensor,
) -> (Vec<f32>, Tensor) {
    let l = bcm.l;
    assert!(l.is_power_of_two(), "fft path requires power-of-two order");
    assert_eq!(x.shape[0], bcm.n());
    assert_eq!(dy.shape[0], bcm.m());
    assert_eq!(x.shape[1], dy.shape[1], "operand/upstream batch width");
    let b = x.shape[1];
    let plan = FftPlan::new(l);

    // weight spectra (first-column FFTs), identical to the forward pass
    let n_blocks = bcm.p * bcm.q;
    let mut w_re = vec![0.0f32; n_blocks * l];
    let mut w_im = vec![0.0f32; n_blocks * l];
    for blk_i in 0..n_blocks {
        let blk = &bcm.w[blk_i * l..(blk_i + 1) * l];
        let re = &mut w_re[blk_i * l..(blk_i + 1) * l];
        re[0] = blk[0];
        for r in 1..l {
            re[r] = blk[l - r];
        }
        plan.forward(re, &mut w_im[blk_i * l..(blk_i + 1) * l]);
    }

    // operand spectra (Q, B, l) and upstream spectra (P, B, l)
    let spectra = |t: &Tensor, blocks: usize| -> (Vec<f32>, Vec<f32>) {
        let mut re = vec![0.0f32; blocks * b * l];
        let mut im = vec![0.0f32; blocks * b * l];
        for bi in 0..blocks {
            for col in 0..b {
                let off = (bi * b + col) * l;
                for i in 0..l {
                    re[off + i] = t.data[(bi * l + i) * b + col];
                }
                plan.forward(&mut re[off..off + l], &mut im[off..off + l]);
            }
        }
        (re, im)
    };
    let (x_re, x_im) = spectra(x, bcm.q);
    let (dy_re, dy_im) = spectra(dy, bcm.p);

    let mut acc_re = vec![0.0f32; l];
    let mut acc_im = vec![0.0f32; l];

    // dx: accumulate conj(W_f) ⊙ dY_f over block-rows, one inverse
    // transform per (block-column, column)
    let mut dx = vec![0.0f32; bcm.n() * b];
    for bq in 0..bcm.q {
        for col in 0..b {
            acc_re.iter_mut().for_each(|v| *v = 0.0);
            acc_im.iter_mut().for_each(|v| *v = 0.0);
            for bp in 0..bcm.p {
                let wo = (bp * bcm.q + bq) * l;
                let go = (bp * b + col) * l;
                for k in 0..l {
                    let (wr, wi) = (w_re[wo + k], -w_im[wo + k]);
                    let (gr, gi) = (dy_re[go + k], dy_im[go + k]);
                    acc_re[k] += wr * gr - wi * gi;
                    acc_im[k] += wr * gi + wi * gr;
                }
            }
            plan.inverse(&mut acc_re, &mut acc_im);
            for i in 0..l {
                dx[(bq * l + i) * b + col] = acc_re[i];
            }
        }
    }

    // dw: accumulate conj(dY_f) ⊙ X_f over columns, one inverse transform
    // per block — the result is real (x, dy real), acc_im only carries
    // rounding noise
    let mut dw = vec![0.0f32; bcm.w.len()];
    for bp in 0..bcm.p {
        for bq in 0..bcm.q {
            acc_re.iter_mut().for_each(|v| *v = 0.0);
            acc_im.iter_mut().for_each(|v| *v = 0.0);
            for col in 0..b {
                let go = (bp * b + col) * l;
                let xo = (bq * b + col) * l;
                for k in 0..l {
                    let (gr, gi) = (dy_re[go + k], -dy_im[go + k]);
                    let (xr, xi) = (x_re[xo + k], x_im[xo + k]);
                    acc_re[k] += gr * xr - gi * xi;
                    acc_im[k] += gr * xi + gi * xr;
                }
            }
            plan.inverse(&mut acc_re, &mut acc_im);
            let off = (bp * bcm.q + bq) * l;
            dw[off..off + l].copy_from_slice(&acc_re);
        }
    }
    (dw, Tensor::new(&[bcm.n(), b], dx))
}

/// Planned adjoint: [`bcm_mmm_fft_backward`] reusing the cached `plan` +
/// forward [`WeightSpectra`] (the weight spectra are identical in the
/// forward and backward passes, so training's backward no longer re-FFTs
/// every block column), scratch-arena operand spectra, and scoped-thread
/// block distribution.  Bit-identical to the reference for any `threads`.
pub fn bcm_mmm_fft_backward_planned(
    bcm: &Bcm,
    x: &Tensor,
    dy: &Tensor,
    plan: &FftPlan,
    wspec: &WeightSpectra,
    threads: usize,
) -> (Vec<f32>, Tensor) {
    let l = bcm.l;
    assert!(l.is_power_of_two(), "fft path requires power-of-two order");
    assert_eq!(plan.len(), l, "plan length must match block order");
    assert_eq!(wspec.n_blocks(), bcm.p * bcm.q, "stale weight spectra");
    assert_eq!(x.shape[0], bcm.n());
    assert_eq!(dy.shape[0], bcm.m());
    assert_eq!(x.shape[1], dy.shape[1], "operand/upstream batch width");
    let b = x.shape[1];
    let l2 = 2 * l;
    let workers = fft_threads(bcm, b, threads);

    let xs = column_spectra(x, bcm.q, l, plan, workers);
    let gs = column_spectra(dy, bcm.p, l, plan, workers);

    // dx: accumulate conj(W_f) ⊙ dY_f over block-rows; chunk bq owns
    // rows [bq·l, (bq+1)·l) of dx
    let mut dx = vec![0.0f32; bcm.n() * b];
    if b > 0 {
        scoped_chunks(workers, &mut dx, l * b, |bq, dxtile| {
            let mut acc_re = vec![0.0f32; l];
            let mut acc_im = vec![0.0f32; l];
            for col in 0..b {
                acc_re.fill(0.0);
                acc_im.fill(0.0);
                for bp in 0..bcm.p {
                    let (wre, wim) = wspec.block(bp * bcm.q + bq);
                    let (gre, gim) =
                        gs[(bp * b + col) * l2..(bp * b + col + 1) * l2].split_at(l);
                    for k in 0..l {
                        let (wr, wi) = (wre[k], -wim[k]);
                        let (gr, gi) = (gre[k], gim[k]);
                        acc_re[k] += wr * gr - wi * gi;
                        acc_im[k] += wr * gi + wi * gr;
                    }
                }
                plan.inverse(&mut acc_re, &mut acc_im);
                for i in 0..l {
                    dxtile[i * b + col] = acc_re[i];
                }
            }
        });
    }

    // dw: accumulate conj(dY_f) ⊙ X_f over columns; chunk bp owns the
    // contiguous (Q, l) slab of dw belonging to block-row bp
    let mut dw = vec![0.0f32; bcm.w.len()];
    if b > 0 {
        scoped_chunks(workers, &mut dw, bcm.q * l, |bp, dwtile| {
            let mut acc_re = vec![0.0f32; l];
            let mut acc_im = vec![0.0f32; l];
            for bq in 0..bcm.q {
                acc_re.fill(0.0);
                acc_im.fill(0.0);
                for col in 0..b {
                    let (gre, gim) =
                        gs[(bp * b + col) * l2..(bp * b + col + 1) * l2].split_at(l);
                    let (xr, xi) =
                        xs[(bq * b + col) * l2..(bq * b + col + 1) * l2].split_at(l);
                    for k in 0..l {
                        let (gr, gi) = (gre[k], -gim[k]);
                        acc_re[k] += gr * xr[k] - gi * xi[k];
                        acc_im[k] += gr * xi[k] + gi * xr[k];
                    }
                }
                plan.inverse(&mut acc_re, &mut acc_im);
                dwtile[bq * l..(bq + 1) * l].copy_from_slice(&acc_re);
            }
        });
    }
    scratch::put(gs);
    scratch::put(xs);
    (dw, Tensor::new(&[bcm.n(), b], dx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, assert_close};
    use crate::util::rng::Rng;

    #[test]
    fn fft_roundtrip() {
        let mut r = Rng::new(1);
        for n in [2usize, 4, 8, 16, 64, 256] {
            let orig: Vec<f32> = (0..n).map(|_| r.f32() - 0.5).collect();
            let mut re = orig.clone();
            let mut im = vec![0.0f32; n];
            fft_inplace(&mut re, &mut im, false);
            fft_inplace(&mut re, &mut im, true);
            assert_close(&re, &orig, 1e-5).unwrap();
            assert!(im.iter().all(|v| v.abs() < 1e-5));
        }
    }

    #[test]
    fn fft_of_delta_is_flat() {
        let mut re = vec![1.0, 0.0, 0.0, 0.0];
        let mut im = vec![0.0; 4];
        fft_inplace(&mut re, &mut im, false);
        assert_close(&re, &[1.0; 4], 1e-6).unwrap();
        assert_close(&im, &[0.0; 4], 1e-6).unwrap();
    }

    #[test]
    fn fft_parseval() {
        let mut r = Rng::new(2);
        let x: Vec<f32> = (0..16).map(|_| r.f32() - 0.5).collect();
        let e_time: f32 = x.iter().map(|v| v * v).sum();
        let mut re = x.clone();
        let mut im = vec![0.0f32; 16];
        fft_inplace(&mut re, &mut im, false);
        let e_freq: f32 =
            re.iter().zip(&im).map(|(a, b)| a * a + b * b).sum::<f32>() / 16.0;
        assert!((e_time - e_freq).abs() < 1e-4);
    }

    #[test]
    fn fft_mvm_matches_direct() {
        propcheck::check("fft mvm == direct mvm", 80, |g| {
            let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
            let l = *g.choose(&[2usize, 4, 8, 16]);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let x = g.vec_f32(b.n(), -1.0, 1.0);
            // f64 twiddle recurrence keeps the paths within 1e-4 even at
            // the larger block orders (was 1e-3 with f32 twiddles)
            assert_close(&b.mvm_fft(&x), &b.mvm(&x), 1e-4)
        });
    }

    #[test]
    fn plan_matches_fft_inplace() {
        let mut r = Rng::new(5);
        for n in [1usize, 2, 4, 8, 32, 128] {
            let plan = FftPlan::new(n);
            assert_eq!(plan.len(), n);
            let orig: Vec<f32> = (0..n).map(|_| r.f32() - 0.5).collect();
            let (mut re_a, mut im_a) = (orig.clone(), vec![0.0f32; n]);
            let (mut re_b, mut im_b) = (orig.clone(), vec![0.0f32; n]);
            fft_inplace(&mut re_a, &mut im_a, false);
            plan.forward(&mut re_b, &mut im_b);
            assert_close(&re_a, &re_b, 1e-5).unwrap();
            assert_close(&im_a, &im_b, 1e-5).unwrap();
            plan.inverse(&mut re_b, &mut im_b);
            assert_close(&re_b, &orig, 1e-5).unwrap();
        }
    }

    #[test]
    fn mmm_fft_columns_are_independent() {
        // column j of the batched transform == the single-column transform
        // of column j (the property the engine's one-pass-per-layer
        // batching rests on)
        let mut r = Rng::new(6);
        let mut w = vec![0.0f32; 2 * 3 * 8];
        r.fill_uniform(&mut w);
        let b = Bcm::new(2, 3, 8, w);
        let cols = 5;
        let mut xd = vec![0.0f32; b.n() * cols];
        r.fill_uniform(&mut xd);
        let x = Tensor::new(&[b.n(), cols], xd);
        let y = bcm_mmm_fft(&b, &x);
        for col in 0..cols {
            let xcol: Vec<f32> = (0..b.n()).map(|i| x.at2(i, col)).collect();
            let ycol =
                bcm_mmm_fft(&b, &Tensor::new(&[b.n(), 1], xcol));
            for row in 0..b.m() {
                assert_eq!(
                    y.at2(row, col),
                    ycol.data[row],
                    "row {row} col {col}"
                );
            }
        }
    }

    #[test]
    fn fft_backward_identity_bcm_passes_gradient_through() {
        // identity weights: dx == dy and dw == Σ_b dy ⊙ rotated x
        let mut b = Bcm::zeros(2, 2, 4);
        for i in 0..2 {
            b.w[(i * 2 + i) * 4] = 1.0;
        }
        let mut r = Rng::new(7);
        let mut xd = vec![0.0f32; 8 * 3];
        let mut dyd = vec![0.0f32; 8 * 3];
        r.fill_uniform(&mut xd);
        r.fill_uniform(&mut dyd);
        let x = Tensor::new(&[8, 3], xd);
        let dy = Tensor::new(&[8, 3], dyd);
        let (_, dx) = bcm_mmm_fft_backward(&b, &x, &dy);
        assert_close(&dx.data, &dy.data, 1e-5).unwrap();
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn fft_rejects_non_power_of_two_order() {
        let b = Bcm::zeros(1, 1, 3);
        b.mvm_fft(&[0.0, 0.0, 0.0]);
    }

    #[test]
    fn plan_cache_shares_one_plan_per_length() {
        let a = plan_for(16);
        let b = plan_for(16);
        assert!(std::sync::Arc::ptr_eq(&a, &b), "same length, same plan");
        assert_eq!(plan_for(8).len(), 8);
    }

    #[test]
    fn crossover_dispatch_defaults() {
        // default crossover 16: the paper's order 4 stays on the direct
        // kernel, serving orders 16+ take Eq. (2)
        assert!(!use_fft_path(4));
        assert!(use_fft_path(16));
        assert!(use_fft_path(64));
        assert!(!use_fft_path(24), "non-power-of-two cannot take the fft");
    }

    #[test]
    fn mvm_fft_is_exactly_the_single_column_of_mmm_fft() {
        // both run off the same plan tables now, so agreement is exact
        let mut r = Rng::new(21);
        let mut w = vec![0.0f32; 2 * 3 * 8];
        r.fill_uniform(&mut w);
        let b = Bcm::new(2, 3, 8, w);
        let mut x = vec![0.0f32; b.n()];
        r.fill_uniform(&mut x);
        let batched = bcm_mmm_fft(&b, &Tensor::new(&[b.n(), 1], x.clone()));
        assert_eq!(batched.data, bcm_mvm_fft(&b, &x));
    }

    #[test]
    fn planned_forward_is_bit_identical_to_reference() {
        propcheck::check("planned mmm_fft == unplanned", 40, |g| {
            let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
            let l = *g.choose(&[2usize, 4, 8, 16]);
            let cols = g.usize_in(1, 6);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let x =
                Tensor::new(&[b.n(), cols], g.vec_f32(b.n() * cols, -1.0, 1.0));
            let plan = plan_for(l);
            let spec = WeightSpectra::new(&b, &plan);
            let reference = bcm_mmm_fft(&b, &x);
            for threads in [1usize, 4] {
                let planned =
                    bcm_mmm_fft_planned(&b, &x, &plan, &spec, threads);
                crate::prop_assert!(
                    planned.data == reference.data,
                    "planned path diverged at threads={threads}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn planned_backward_is_bit_identical_to_reference() {
        propcheck::check("planned fft backward == unplanned", 40, |g| {
            let (p, q) = (g.usize_in(1, 4), g.usize_in(1, 4));
            let l = *g.choose(&[2usize, 4, 8, 16]);
            let cols = g.usize_in(1, 5);
            let mut w = vec![0.0f32; p * q * l];
            g.rng.fill_uniform(&mut w);
            let b = Bcm::new(p, q, l, w);
            let x =
                Tensor::new(&[b.n(), cols], g.vec_f32(b.n() * cols, -1.0, 1.0));
            let dy =
                Tensor::new(&[b.m(), cols], g.vec_f32(b.m() * cols, -1.0, 1.0));
            let (dw_r, dx_r) = bcm_mmm_fft_backward(&b, &x, &dy);
            let plan = plan_for(l);
            let spec = WeightSpectra::new(&b, &plan);
            for threads in [1usize, 4] {
                let (dw_p, dx_p) = bcm_mmm_fft_backward_planned(
                    &b, &x, &dy, &plan, &spec, threads,
                );
                crate::prop_assert!(
                    dw_p == dw_r && dx_p.data == dx_r.data,
                    "planned backward diverged at threads={threads}"
                );
            }
            Ok(())
        });
    }
}
