//! `cirptc` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   info                       artifact + chip inventory
//!   serve  [--model M]         serve the exported test set, print metrics
//!          [--chips N]         farm width: N=1 (default) is the plain
//!                              coordinator; N>1 serves through the
//!                              health-routed farm, partitioning the
//!                              model across chips when its tile demand
//!                              exceeds --chip-capacity
//!          [--chip-capacity T] per-chip MRR bank in resident tiles
//!                              (default: chip.json's mrr_capacity;
//!                              0 = unlimited)
//!   mvm    [--size S]          one BCM matmul through sim (+ XLA with
//!                              `--features pjrt`)
//!   analyze                    print the benchmark-analysis summary
//!
//! Everything here is also exercised by examples/ and benches/; the binary
//! is the operational front door.  The default build is pure rust; the
//! `pjrt` cargo feature re-enables the XLA artifact paths.

use std::path::PathBuf;

use cirptc::util::sync::Arc;

use cirptc::analysis::{AreaModel, PowerModel, WeightTech};
use cirptc::arch::CirPtcConfig;
use cirptc::circulant::Bcm;
use cirptc::coordinator::worker::EngineBackend;
use cirptc::coordinator::{BatcherConfig, Coordinator, Metrics};
use cirptc::data::Bundle;
use cirptc::farm::{
    tile_demand, Farm, FarmConfig, FarmMember, PartitionPlan, PartitionedBackend,
    PartitionedEngine,
};
use cirptc::onn::{Backend, Engine};
use cirptc::runtime::available_artifacts;
#[cfg(feature = "pjrt")]
use cirptc::runtime::Runtime;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{argmax, Tensor};
use cirptc::util::cli::Args;
use cirptc::util::error::{Error, Result};
use cirptc::util::rng::Rng;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn main() -> Result<()> {
    let args = Args::parse();
    match args.positional().first().map(String::as_str) {
        Some("info") => info(&args),
        Some("serve") => serve(&args),
        Some("mvm") => mvm(&args),
        Some("analyze") => analyze(),
        _ => {
            eprintln!(
                "usage: cirptc <info|serve|mvm|analyze> [--artifacts DIR] \
                 [--model NAME] [--backend digital|photonic] [--size S] \
                 [--batch N] [--wait-us US] [--queue-cap N] [--chips N] \
                 [--chip-capacity TILES]"
            );
            Ok(())
        }
    }
}

fn info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    #[cfg(feature = "pjrt")]
    let mut rt = Runtime::new(&dir)?;
    #[cfg(feature = "pjrt")]
    println!("platform: {}", rt.platform());
    #[cfg(not(feature = "pjrt"))]
    println!("platform: rust-native (pjrt feature disabled)");
    println!("artifacts in {}:", dir.display());
    for name in available_artifacts(&dir)? {
        println!("  {name}");
    }
    let chip = ChipDescription::load(&dir.join("chip.json"))?;
    println!(
        "chip: order-{} eps-derived Γ, dark={}, σ_rel={}, w/x bits={}/{}",
        chip.l, chip.dark, chip.sigma_rel, chip.w_bits, chip.x_bits
    );
    // verify one artifact compiles (needs the PJRT client)
    #[cfg(feature = "pjrt")]
    {
        let _ = rt.load("bcm_16x16_b8")?;
        println!("bcm_16x16_b8 compiled OK");
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let model = args.str_or("model", "synth_cxr");
    let backend = args.str_or("backend", "photonic");
    let workers = args.usize_or("workers", 2);

    // substrate-specific weights: DPE bundle for the photonic path, the
    // digitally-trained baseline for the digital path (see
    // python/compile/recalib.py for why BN calibration follows substrate)
    let variant = if backend == "digital" { "digital" } else { "dpe" };
    let mut bundle = dir.join(format!("models/{model}_{variant}.cpt"));
    if !bundle.exists() {
        bundle = dir.join(format!("models/{model}_dpe.cpt"));
    }
    let engine = Arc::new(Engine::load(
        &dir.join(format!("models/{model}.json")),
        &bundle,
    )?);
    let chip = ChipDescription::load(&dir.join("chip.json"))?;
    let test = Bundle::load(&dir.join(format!("models/{model}_testset.cpt")))?;
    let (c, h) = engine.manifest.input_shape();
    let xs = test.get("x")?.as_f32()?;
    let ys = test.get("y")?.as_i32()?;
    let n = ys.len();
    let images: Vec<Tensor> = (0..n)
        .map(|i| {
            Tensor::new(&[c, h, h], xs[i * c * h * h..(i + 1) * c * h * h].to_vec())
        })
        .collect();

    let chips_n = args.usize_or("chips", 1).max(1);
    let capacity = args.usize_or("chip-capacity", chip.mrr_capacity);
    let bcfg = BatcherConfig {
        max_batch: args.usize_or("batch", 8),
        max_wait_us: args.usize_or("wait-us", 2000) as u64,
        queue_cap: args.usize_or("queue-cap", 0),
    };

    let coord = if chips_n == 1 {
        let backends: Vec<cirptc::coordinator::BackendFactory> = (0..workers)
            .map(|i| {
                let engine = Arc::clone(&engine);
                let backend = backend.clone();
                let mut d = chip.clone();
                d.seed ^= i as u64; // independent chip instances
                Box::new(move || {
                    let mode = match backend.as_str() {
                        "digital" => Backend::Digital,
                        _ => Backend::PhotonicSim(ChipSim::new(d)),
                    };
                    Box::new(EngineBackend { engine, mode })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as cirptc::coordinator::BackendFactory
            })
            .collect();
        Coordinator::start(backends, bcfg)
    } else if capacity > 0 && tile_demand(&engine.manifest) > capacity {
        // the model's resident tiles exceed one chip's MRR bank: shard
        // its circulant block-rows across the farm, every worker driving
        // all N chips of the partition per batch
        let demand = tile_demand(&engine.manifest);
        let plan = PartitionPlan::plan(&engine.manifest, chips_n);
        if let Some(d) = plan.capacity_diags(capacity).first() {
            let hint = match PartitionPlan::required_chips(&engine.manifest, capacity)
            {
                Some(n) => format!(" (need --chips {n})"),
                None => " (no farm width fits: a single block-row exceeds \
                         the bank)"
                    .to_string(),
            };
            return Err(Error::msg(format!(
                "--chips {chips_n} cannot hold {model}: {}{hint}",
                d.render()
            )));
        }
        println!(
            "partitioning {model} across {chips_n} chips \
             (demand {demand} tiles, bank {capacity} tiles/chip)"
        );
        let part = Arc::new(PartitionedEngine::new(Arc::clone(&engine), plan)?);
        let backends: Vec<cirptc::coordinator::BackendFactory> = (0..workers)
            .map(|i| {
                let part = Arc::clone(&part);
                let backend = backend.clone();
                let chip = chip.clone();
                Box::new(move || {
                    let chips: Vec<Backend> = (0..part.plan.chips)
                        .map(|k| match backend.as_str() {
                            "digital" => Backend::Digital,
                            _ => {
                                let mut d = chip.clone();
                                d.seed ^= (i * part.plan.chips + k) as u64;
                                Backend::PhotonicSim(ChipSim::new(d))
                            }
                        })
                        .collect();
                    Box::new(PartitionedBackend { part, chips })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as cirptc::coordinator::BackendFactory
            })
            .collect();
        Coordinator::start(backends, bcfg)
    } else {
        // the model fits each chip: serve N independent replicas behind
        // the health-routed farm (failover + per-chip accounting)
        let members: Vec<FarmMember> = (0..chips_n)
            .map(|k| {
                let mode = match backend.as_str() {
                    "digital" => Backend::Digital,
                    _ => {
                        let mut d = chip.clone();
                        d.seed ^= k as u64; // independent chip instances
                        Backend::PhotonicSim(ChipSim::new(d))
                    }
                };
                FarmMember::fixed(Arc::clone(&engine), mode)
            })
            .collect();
        println!("serving {model} on a {chips_n}-chip replica farm");
        let farm = Farm::start(
            members,
            FarmConfig { batcher: bcfg, ..FarmConfig::default() },
            Arc::new(Metrics::default()),
        );
        let Farm { coord, status: _ } = farm;
        coord
    };
    let t0 = std::time::Instant::now();
    let responses = coord.classify_all(&images)?;
    let wall = t0.elapsed();
    let correct = responses
        .iter()
        .zip(ys)
        .filter(|(r, &y)| argmax(&r.logits) == y as usize)
        .count();
    println!(
        "served {n} requests on {model} [{backend}] in {:.2}s  \
         acc={:.4}  throughput={:.1} req/s",
        wall.as_secs_f64(),
        correct as f64 / n as f64,
        n as f64 / wall.as_secs_f64()
    );
    println!("metrics: {}", coord.metrics.summary());
    Ok(())
}

fn mvm(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let size = args.usize_or("size", 48);
    let (p, q, l, b) = (size / 4, size / 4, 4usize, 16usize);
    let mut rng = Rng::new(1);
    let mut w = vec![0.0f32; p * q * l];
    rng.fill_uniform(&mut w);
    let bcm = Bcm::new(p, q, l, w.clone());
    let mut x = vec![0.0f32; size * b];
    rng.fill_uniform(&mut x);
    let xt = Tensor::new(&[size, b], x);

    // rust photonic-sim path vs the direct compressed reference
    let chip = ChipDescription::load(&dir.join("chip.json"))
        .unwrap_or_else(|_| ChipDescription::ideal(4));
    let mut sim = ChipSim::deterministic(chip);
    let y_sim = sim.forward(&bcm, &xt);
    let y_ref = bcm.matmul(&xt);
    println!(
        "mvm {size}x{size}: sim vs digital max |Δ| = {:.2e} ({} outputs)",
        y_sim.max_abs_diff(&y_ref),
        y_ref.numel()
    );

    // XLA AOT path (if the pjrt feature is on and the artifact exists)
    #[cfg(feature = "pjrt")]
    {
        let mut rt = Runtime::new(&dir)?;
        let name = format!("crossbar_{size}x{size}_b{b}");
        match rt.load(&name) {
            Ok(exe) => {
                let wt = Tensor::new(&[p, q, l], w);
                let y_xla = exe.run(&[&wt, &xt])?;
                let diff = y_sim
                    .data
                    .iter()
                    .zip(&y_xla)
                    .fold(0.0f32, |m, (a, c)| m.max((a - c).abs()));
                println!(
                    "mvm {size}x{size}: sim vs XLA max |Δ| = {diff:.2e} \
                     ({} outputs)",
                    y_xla.len()
                );
            }
            Err(e) => println!("mvm {size}x{size}: sim OK; XLA artifact: {e:#}"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("mvm {size}x{size}: XLA path disabled (build with --features pjrt)");
    Ok(())
}

fn analyze() -> Result<()> {
    let area = AreaModel::paper();
    let power = PowerModel::paper();
    for (label, cfg, tech) in [
        ("48x48 thermo", CirPtcConfig::scaled_48(), WeightTech::ThermoOptic),
        ("48x48 r=4 thermo", CirPtcConfig::folded_48(), WeightTech::ThermoOptic),
        ("48x48 r=4 MOSCAP", CirPtcConfig::folded_48(), WeightTech::Moscap),
    ] {
        println!(
            "{label:<18} density={:.2} TOPS/mm²  efficiency={:.2} TOPS/W  \
             (vs uncompressed ×{:.2})",
            area.computing_density_tops_mm2(&cfg),
            power.efficiency_tops_w(&cfg, tech),
            power.efficiency_tops_w(&cfg, tech)
                / power.uncompressed_efficiency_tops_w(&cfg, tech),
        );
    }
    Ok(())
}
