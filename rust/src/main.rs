//! `cirptc` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   info                       artifact + chip inventory
//!   serve  [--model M]         serve the exported test set, print metrics
//!          [--chips N]         farm width: N=1 (default) is the plain
//!                              coordinator; N>1 serves through the
//!                              health-routed farm, partitioning the
//!                              model across chips when its tile demand
//!                              exceeds --chip-capacity
//!          [--chip-capacity T] per-chip MRR bank in resident tiles
//!                              (default: chip.json's mrr_capacity;
//!                              0 = unlimited)
//!          [--trace OUT.json]  record serving spans, write a Chrome
//!                              trace-event file on exit (DESIGN.md §obs)
//!          [--metrics-addr A]  serve Prometheus text on http://A/metrics
//!                              while requests flow (A like 127.0.0.1:0)
//!          [--sample OUT.jsonl] periodic full-resolution telemetry
//!          [--sample-ms MS]     stream, one JSON object per interval
//!          [--json]            end-of-run report as JSON, not text
//!          [--smoke]           artifact-free synthetic run: monitored
//!                              farm + forced recalibration + partition
//!                              shard pass (the `make trace-smoke` body)
//!          [--chaos PLAN.json] chaos smoke instead: a supervised farm
//!                              with a digital fallback lane serves while
//!                              every member runs the seeded fault plan
//!                              (`builtin` for the pinned default) — the
//!                              run fails unless the self-healing loop
//!                              closes with zero dropped requests
//!                              (the `make chaos-smoke` body)
//!   chaos  [--seed S]          print a seeded random fault plan as JSON
//!          [--out PLAN.json]   (or write it to a file) for `--chaos`
//!   mvm    [--size S]          one BCM matmul through sim (+ XLA with
//!                              `--features pjrt`)
//!   analyze                    print the benchmark-analysis summary
//!
//! Everything here is also exercised by examples/ and benches/; the binary
//! is the operational front door.  The default build is pure rust; the
//! `pjrt` cargo feature re-enables the XLA artifact paths.

use std::path::{Path, PathBuf};
use std::time::Duration;

use cirptc::util::sync::Arc;

use cirptc::analysis::{AreaModel, PowerModel, WeightTech};
use cirptc::arch::CirPtcConfig;
use cirptc::circulant::Bcm;
use cirptc::coordinator::worker::EngineBackend;
use cirptc::coordinator::{BatcherConfig, Coordinator, Metrics};
use cirptc::data::datasets;
use cirptc::data::Bundle;
use cirptc::drift::{
    DriftConfig, DriftModel, DriftMonitor, MonitorConfig, RecalConfig,
    Recalibrator,
};
use cirptc::farm::{
    tile_demand, ChipHealth, ChipStatus, Farm, FarmConfig, FarmMember,
    PartitionPlan, PartitionedBackend, PartitionedEngine,
    DEFAULT_DRIFTING_PPM,
};
use cirptc::fault::{
    ChipSupervisor, Episode, FaultKind, FaultPlan, SupervisorConfig,
};
use cirptc::obs::{self, prom, sampler::Sampler, trace};
use cirptc::onn::{Backend, Engine, Manifest};
use cirptc::runtime::available_artifacts;
#[cfg(feature = "pjrt")]
use cirptc::runtime::Runtime;
use cirptc::simulator::{ChipDescription, ChipSim};
use cirptc::tensor::{argmax, Tensor};
use cirptc::train::{fit, Optimizer, TrainBackend, TrainConfig, TrainModel};
use cirptc::util::cli::Args;
use cirptc::util::error::{Error, Result};
use cirptc::util::rng::Rng;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn main() -> Result<()> {
    let args = Args::parse();
    match args.positional().first().map(String::as_str) {
        Some("info") => info(&args),
        Some("serve") => serve(&args),
        Some("chaos") => chaos(&args),
        Some("mvm") => mvm(&args),
        Some("analyze") => analyze(),
        _ => {
            eprintln!(
                "usage: cirptc <info|serve|chaos|mvm|analyze> [--artifacts DIR] \
                 [--model NAME] [--backend digital|photonic] [--size S] \
                 [--batch N] [--wait-us US] [--queue-cap N] [--chips N] \
                 [--chip-capacity TILES] [--trace OUT.json] \
                 [--metrics-addr HOST:PORT] [--sample OUT.jsonl] \
                 [--sample-ms MS] [--json] [--smoke] \
                 [--chaos PLAN.json|builtin] [--seed S] [--out PLAN.json]"
            );
            Ok(())
        }
    }
}

fn info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    #[cfg(feature = "pjrt")]
    let mut rt = Runtime::new(&dir)?;
    #[cfg(feature = "pjrt")]
    println!("platform: {}", rt.platform());
    #[cfg(not(feature = "pjrt"))]
    println!("platform: rust-native (pjrt feature disabled)");
    println!("artifacts in {}:", dir.display());
    for name in available_artifacts(&dir)? {
        println!("  {name}");
    }
    let chip = ChipDescription::load(&dir.join("chip.json"))?;
    println!(
        "chip: order-{} eps-derived Γ, dark={}, σ_rel={}, w/x bits={}/{}",
        chip.l, chip.dark, chip.sigma_rel, chip.w_bits, chip.x_bits
    );
    // verify one artifact compiles (needs the PJRT client)
    #[cfg(feature = "pjrt")]
    {
        let _ = rt.load("bcm_16x16_b8")?;
        println!("bcm_16x16_b8 compiled OK");
    }
    Ok(())
}

/// `serve` front door: installs the trace recorder when asked, dispatches
/// to the artifact-backed server or the synthetic smoke run, and writes
/// the Chrome trace-event file on the way out.
fn serve(args: &Args) -> Result<()> {
    let trace_path = args.get("trace").map(PathBuf::from);
    if trace_path.is_some() {
        trace::install(trace::TraceRecorder::new(1 << 16));
        trace::set_enabled(true);
    }
    let dir = artifacts_dir(args);
    let model = args.str_or("model", "synth_cxr");
    if args.get("chaos").is_some() {
        serve_chaos(args)?;
    } else if args.has("smoke") || !dir.join(format!("models/{model}.json")).exists()
    {
        if !args.has("smoke") {
            println!("artifacts missing — running the synthetic serve smoke");
        }
        serve_smoke(args)?;
    } else {
        serve_artifacts(args, &dir, &model)?;
    }
    if let Some(path) = trace_path {
        let rec = trace::global().expect("recorder installed above");
        rec.write_chrome_trace(&path)?;
        println!(
            "chrome trace: {} ({} events, {} dropped)",
            path.display(),
            rec.snapshot().len(),
            rec.dropped()
        );
    }
    Ok(())
}

/// Start the `/metrics` endpoint and the JSONL sampler inside `scope`
/// when the flags ask for them.  Both handles shut their threads down on
/// drop, so a `?`-return from the caller cannot wedge the scope's
/// implicit join.
fn start_obs<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    args: &Args,
    metrics: &Arc<Metrics>,
    chips: &[Arc<ChipStatus>],
    default_sample_ms: usize,
) -> Result<(Option<prom::MetricsEndpoint>, Option<Sampler>)> {
    let endpoint = match args.get("metrics-addr") {
        Some(addr) => {
            let ep = prom::serve_scoped(
                scope,
                addr,
                Arc::clone(metrics),
                chips.to_vec(),
            )?;
            println!("metrics endpoint: http://{}/metrics", ep.addr());
            Some(ep)
        }
        None => None,
    };
    let smp = match args.get("sample") {
        Some(p) => Some(Sampler::start(
            Path::new(p),
            Duration::from_millis(
                args.usize_or("sample-ms", default_sample_ms) as u64
            ),
            Arc::clone(metrics),
            chips.to_vec(),
        )?),
        None => None,
    };
    Ok((endpoint, smp))
}

/// Serve the exported test set from trained artifacts, with the optional
/// telemetry endpoint / sampler attached for the duration of the run.
fn serve_artifacts(args: &Args, dir: &Path, model: &str) -> Result<()> {
    let backend = args.str_or("backend", "photonic");
    let workers = args.usize_or("workers", 2);

    // substrate-specific weights: DPE bundle for the photonic path, the
    // digitally-trained baseline for the digital path (see
    // python/compile/recalib.py for why BN calibration follows substrate)
    let variant = if backend == "digital" { "digital" } else { "dpe" };
    let mut bundle = dir.join(format!("models/{model}_{variant}.cpt"));
    if !bundle.exists() {
        bundle = dir.join(format!("models/{model}_dpe.cpt"));
    }
    let engine = Arc::new(Engine::load(
        &dir.join(format!("models/{model}.json")),
        &bundle,
    )?);
    let chip = ChipDescription::load(&dir.join("chip.json"))?;
    let test = Bundle::load(&dir.join(format!("models/{model}_testset.cpt")))?;
    let (c, h) = engine.manifest.input_shape();
    let xs = test.get("x")?.as_f32()?;
    let ys = test.get("y")?.as_i32()?;
    let n = ys.len();
    let images: Vec<Tensor> = (0..n)
        .map(|i| {
            Tensor::new(&[c, h, h], xs[i * c * h * h..(i + 1) * c * h * h].to_vec())
        })
        .collect();

    let chips_n = args.usize_or("chips", 1).max(1);
    let capacity = args.usize_or("chip-capacity", chip.mrr_capacity);
    let bcfg = BatcherConfig {
        max_batch: args.usize_or("batch", 8),
        max_wait_us: args.usize_or("wait-us", 2000) as u64,
        queue_cap: args.usize_or("queue-cap", 0),
    };

    let (coord, chip_status) = if chips_n == 1 {
        let backends: Vec<cirptc::coordinator::BackendFactory> = (0..workers)
            .map(|i| {
                let engine = Arc::clone(&engine);
                let backend = backend.clone();
                let mut d = chip.clone();
                d.seed ^= i as u64; // independent chip instances
                Box::new(move || {
                    let mode = match backend.as_str() {
                        "digital" => Backend::Digital,
                        _ => Backend::PhotonicSim(ChipSim::new(d)),
                    };
                    Box::new(EngineBackend { engine, mode })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as cirptc::coordinator::BackendFactory
            })
            .collect();
        (Coordinator::start(backends, bcfg), Vec::new())
    } else if capacity > 0 && tile_demand(&engine.manifest) > capacity {
        // the model's resident tiles exceed one chip's MRR bank: shard
        // its circulant block-rows across the farm, every worker driving
        // all N chips of the partition per batch
        let demand = tile_demand(&engine.manifest);
        let plan = PartitionPlan::plan(&engine.manifest, chips_n);
        if let Some(d) = plan.capacity_diags(capacity).first() {
            let hint = match PartitionPlan::required_chips(&engine.manifest, capacity)
            {
                Some(n) => format!(" (need --chips {n})"),
                None => " (no farm width fits: a single block-row exceeds \
                         the bank)"
                    .to_string(),
            };
            return Err(Error::msg(format!(
                "--chips {chips_n} cannot hold {model}: {}{hint}",
                d.render()
            )));
        }
        println!(
            "partitioning {model} across {chips_n} chips \
             (demand {demand} tiles, bank {capacity} tiles/chip)"
        );
        let part = Arc::new(PartitionedEngine::new(Arc::clone(&engine), plan)?);
        let backends: Vec<cirptc::coordinator::BackendFactory> = (0..workers)
            .map(|i| {
                let part = Arc::clone(&part);
                let backend = backend.clone();
                let chip = chip.clone();
                Box::new(move || {
                    let chips: Vec<Backend> = (0..part.plan.chips)
                        .map(|k| match backend.as_str() {
                            "digital" => Backend::Digital,
                            _ => {
                                let mut d = chip.clone();
                                d.seed ^= (i * part.plan.chips + k) as u64;
                                Backend::PhotonicSim(ChipSim::new(d))
                            }
                        })
                        .collect();
                    Box::new(PartitionedBackend { part, chips })
                        as Box<dyn cirptc::coordinator::InferenceBackend>
                }) as cirptc::coordinator::BackendFactory
            })
            .collect();
        (Coordinator::start(backends, bcfg), Vec::new())
    } else {
        // the model fits each chip: serve N independent replicas behind
        // the health-routed farm (failover + per-chip accounting)
        let members: Vec<FarmMember> = (0..chips_n)
            .map(|k| {
                let mode = match backend.as_str() {
                    "digital" => Backend::Digital,
                    _ => {
                        let mut d = chip.clone();
                        d.seed ^= k as u64; // independent chip instances
                        Backend::PhotonicSim(ChipSim::new(d))
                    }
                };
                FarmMember::fixed(Arc::clone(&engine), mode)
            })
            .collect();
        println!("serving {model} on a {chips_n}-chip replica farm");
        let farm = Farm::start(
            members,
            FarmConfig { batcher: bcfg, ..FarmConfig::default() },
            Arc::new(Metrics::default()),
        );
        let Farm { coord, status } = farm;
        (coord, status)
    };
    std::thread::scope(|s| -> Result<()> {
        let (_endpoint, smp) =
            start_obs(s, args, &coord.metrics, &chip_status, 250)?;
        let t0 = std::time::Instant::now();
        let responses = coord.classify_all(&images)?;
        let wall = t0.elapsed();
        let correct = responses
            .iter()
            .zip(ys)
            .filter(|(r, &y)| argmax(&r.logits) == y as usize)
            .count();
        println!(
            "served {n} requests on {model} [{backend}] in {:.2}s  \
             acc={:.4}  throughput={:.1} req/s",
            wall.as_secs_f64(),
            correct as f64 / n as f64,
            n as f64 / wall.as_secs_f64()
        );
        obs::report(
            &coord.metrics,
            &[("rps", n as f64 / wall.as_secs_f64())],
            args.has("json"),
        );
        if let Some(smp) = smp {
            smp.stop();
        }
        Ok(())
    })
}

/// Artifact-free smoke run (the body of `make trace-smoke`): a monitored
/// replica farm trained in-process serves until a forced recalibration
/// lands, one member is failed and restored to exercise health routing,
/// and a partitioned shard pass runs at the end — together covering
/// every span family the tracer records (request, stage, farm, drift).
fn serve_smoke(args: &Args) -> Result<()> {
    let chips_n = args.usize_or("chips", 3).max(1);
    println!("serve smoke: {chips_n}-chip monitored farm, forced recal");

    // tiny in-process model: a short digital fit on the shapes set is
    // enough — the smoke pins plumbing, not accuracy
    let manifest = Manifest::parse(datasets::SHAPES_MANIFEST_JSON)?;
    let train_split = datasets::synth_shapes(96, 0xC1);
    let calib_split = datasets::synth_shapes(64, 0xC2);
    let eval_split = datasets::synth_shapes(32, 0xC3);
    let mut model = TrainModel::init(manifest.clone(), 0xC4)?;
    let mut opt = Optimizer::adam(5e-3);
    let tcfg = TrainConfig { epochs: 2, batch: 16, max_steps: 0, seed: 0xC5 };
    fit(&mut model, &mut TrainBackend::Digital, &mut opt, &train_split, &tcfg)?;
    let bundle = model.export_bundle();

    let metrics = Arc::new(Metrics::default());
    let mut members = Vec::with_capacity(chips_n);
    let mut recals = Vec::with_capacity(chips_n);
    for k in 0..chips_n {
        let engine = Engine::from_parts(manifest.clone(), &bundle)?;
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.01;
        desc.seed = 0xD0 ^ k as u64;
        let mut sim = ChipSim::deterministic(desc.clone());
        sim.set_drift(DriftModel::new(DriftConfig {
            seed: 0xE0 ^ k as u64,
            passes_per_tick: 1,
            gamma_walk: 2e-3,
            resp_tilt: 4e-3,
            dark_creep: 2e-4,
            max_ticks: 60,
        }));
        let mcfg = MonitorConfig {
            probe_every: 1,
            // so low the first cooled-down probe forces a recalibration
            residual_trigger: 1e-6,
            cooldown_passes: 8,
            ..MonitorConfig::default()
        };
        let monitor = DriftMonitor::new(mcfg, &desc);
        let (member, recal_rx) = FarmMember::monitored(
            engine,
            sim,
            monitor,
            DEFAULT_DRIFTING_PPM,
            Arc::clone(&metrics),
        );
        let shared =
            Arc::clone(member.shared.as_ref().expect("monitored member"));
        let rcfg = RecalConfig {
            fine_tune_steps: 2,
            lr: 2e-3,
            batch: 16,
            bn_batches: 2,
            seed: 0xF0 ^ k as u64,
            noisy: false,
            snapshot_dir: None,
        };
        recals.push(
            Recalibrator::new(model.clone(), calib_split.clone(), rcfg, shared)
                .spawn(recal_rx),
        );
        members.push(member);
    }
    let Farm { coord, status } = Farm::start(
        members,
        FarmConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait_us: 2_000,
                queue_cap: 0,
            },
            ..FarmConfig::default()
        },
        Arc::clone(&metrics),
    );

    let images: Vec<Tensor> =
        (0..eval_split.n).map(|i| eval_split.image(i)).collect();
    std::thread::scope(|s| -> Result<()> {
        let (endpoint, smp) = start_obs(s, args, &metrics, &status, 50)?;
        // serve until a recalibration + hot swap lands; fail loudly if
        // none does (the CI contract of `make trace-smoke`)
        let deadline = std::time::Instant::now() + Duration::from_secs(120);
        loop {
            coord.classify_all(&images)?;
            if metrics.recalibrations.get() >= 1 {
                break;
            }
            if std::time::Instant::now() >= deadline {
                return Err(Error::msg(format!(
                    "serve smoke: no recalibration landed: {}",
                    metrics.summary()
                )));
            }
        }
        // exercise health-routing edges: fail one member, serve, restore
        if status.len() > 1 {
            status[0].fail();
            coord.classify_all(&images)?;
            status[0].restore();
        }
        if let Some(ep) = &endpoint {
            let scrape = self_scrape(ep.addr())?;
            if !scrape.contains("cirptc_chip_health") {
                return Err(Error::msg(
                    "metrics scrape is missing the chip health series",
                ));
            }
            println!("scraped {} bytes of metrics exposition", scrape.len());
        }
        if let Some(smp) = smp {
            smp.stop();
        }
        Ok(())
    })?;
    obs::report(&metrics, &[], args.has("json"));
    // the recalibrators' request senders live in the farm pipelines:
    // drop the farm first so the join-on-drop handles can exit
    drop(coord);
    drop(status);
    drop(recals);
    smoke_partitioned(chips_n)
}

/// `cirptc chaos --seed S [--out PLAN.json]` — print (or write) a seeded
/// random fault plan for `cirptc serve --chaos`.
fn chaos(args: &Args) -> Result<()> {
    let seed = args.usize_or("seed", 1) as u64;
    let plan = FaultPlan::generate(seed);
    let text = plan.dump();
    match args.get("out") {
        Some(p) => {
            std::fs::write(p, &text)
                .map_err(|e| Error::msg(format!("write {p}: {e}")))?;
            println!(
                "chaos plan (seed {seed}, {} episodes) -> {p}",
                plan.episodes().len()
            );
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// The pinned default chaos schedule (`--chaos builtin`): one silent
/// hard fault (DeadChip — probe-detected, quarantines) overlapping one
/// detectable transient episode (retried).  Because every member rides
/// the same schedule, the DeadChip window is a total-loss window and the
/// router must degrade to the fallback lane.
fn builtin_chaos_plan() -> FaultPlan {
    FaultPlan::new(
        0xC4A05,
        vec![
            Episode {
                start_pass: 8,
                duration: 50,
                kind: FaultKind::DeadChip,
            },
            Episode {
                start_pass: 4,
                duration: 40,
                kind: FaultKind::TransientPassError { p: 0.5 },
            },
        ],
    )
}

/// Chaos smoke (the body of `make chaos-smoke`): a supervised replica
/// farm with a digital fallback lane serves while every member runs the
/// same seeded fault plan on its own noise stream.  The run only passes
/// when the whole self-healing loop has closed — detectable faults
/// retried, silent faults auto-quarantined off probes, total loss
/// degraded to the fallback, probation auto-restoring members once the
/// episodes end — with `completed == submitted` and zero rejections
/// throughout (DESIGN.md §fault).
fn serve_chaos(args: &Args) -> Result<()> {
    let chips_n = args.usize_or("chips", 3).max(1);
    let plan = match args.get("chaos") {
        Some(path) if path != "builtin" => {
            let text = std::fs::read_to_string(path).map_err(|e| {
                Error::msg(format!("read fault plan {path}: {e}"))
            })?;
            FaultPlan::parse(&text)?
        }
        _ => builtin_chaos_plan(),
    };
    // which signal families this plan can be held to: detectable kinds
    // must produce retries; hard kinds must quarantine every member
    // (same schedule farm-wide), degrade to the fallback, and restore
    let wants_retry = plan.episodes().iter().any(|e| {
        matches!(
            e.kind,
            FaultKind::TransientPassError { .. } | FaultKind::NaNReadout
        )
    });
    let wants_hard = plan.episodes().iter().any(|e| {
        matches!(e.kind, FaultKind::DeadChip | FaultKind::NaNReadout)
    });
    println!(
        "chaos smoke: {chips_n}-chip supervised farm + digital fallback, \
         plan seed {} ({} episodes)",
        plan.seed(),
        plan.episodes().len()
    );

    // the same tiny in-process model the serve smoke trains
    let manifest = Manifest::parse(datasets::SHAPES_MANIFEST_JSON)?;
    let train_split = datasets::synth_shapes(96, 0xC1);
    let eval_split = datasets::synth_shapes(32, 0xC3);
    let mut model = TrainModel::init(manifest.clone(), 0xC4)?;
    let mut opt = Optimizer::adam(5e-3);
    let tcfg = TrainConfig { epochs: 2, batch: 16, max_steps: 0, seed: 0xC5 };
    fit(&mut model, &mut TrainBackend::Digital, &mut opt, &train_split, &tcfg)?;
    let bundle = model.export_bundle();

    let metrics = Arc::new(Metrics::default());
    let mut members = Vec::with_capacity(chips_n);
    let mut recal_rxs = Vec::new();
    for k in 0..chips_n {
        let engine = Engine::from_parts(manifest.clone(), &bundle)?;
        let mut desc = ChipDescription::ideal(4);
        desc.w_bits = 6;
        desc.x_bits = 4;
        desc.dark = 0.01;
        desc.seed = 0xD0 ^ k as u64;
        let mut sim = ChipSim::deterministic(desc.clone());
        sim.set_fault(FaultPlan::new(
            plan.seed() ^ k as u64,
            plan.episodes().to_vec(),
        ));
        // monitor-only: probe every batch for the supervisor, never
        // request a recalibration (no recalibrator is attached here)
        let monitor = DriftMonitor::new(
            MonitorConfig {
                probe_every: 1,
                residual_trigger: f32::INFINITY,
                ..MonitorConfig::default()
            },
            &desc,
        );
        let supervisor = ChipSupervisor::new(SupervisorConfig {
            residual_ceiling: 0.05,
            consecutive_failures: 2,
            probation_probes: 2,
            // the smoke pins auto-restore; the latched-quarantine
            // escalation is pinned by unit tests and chaos_e2e instead
            max_probations: 10_000,
        });
        let (member, recal_rx) = FarmMember::supervised(
            engine,
            sim,
            monitor,
            supervisor,
            DEFAULT_DRIFTING_PPM,
            Duration::from_millis(2),
            Arc::clone(&metrics),
        );
        recal_rxs.push(recal_rx);
        members.push(member);
    }
    let fb_engine = Arc::new(Engine::from_parts(manifest.clone(), &bundle)?);
    let fallback: cirptc::coordinator::worker::BackendFactory =
        Box::new(move || {
            Box::new(EngineBackend { engine: fb_engine, mode: Backend::Digital })
                as Box<dyn cirptc::coordinator::InferenceBackend>
        });
    let Farm { coord, status } = Farm::start_with_fallback(
        members,
        Some(fallback),
        FarmConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait_us: 2_000,
                queue_cap: 0,
            },
            pass_deadline: Some(Duration::from_secs(10)),
            ..FarmConfig::default()
        },
        Arc::clone(&metrics),
    );

    let images: Vec<Tensor> =
        (0..eval_split.n).map(|i| eval_split.image(i)).collect();
    let mut rounds = 0u64;
    std::thread::scope(|s| -> Result<()> {
        let (_endpoint, smp) = start_obs(s, args, &metrics, &status, 50)?;
        let deadline = std::time::Instant::now() + Duration::from_secs(180);
        loop {
            let responses = coord.classify_all(&images)?;
            if responses.len() != images.len() {
                return Err(Error::msg(format!(
                    "chaos smoke dropped requests: {} of {} answered",
                    responses.len(),
                    images.len()
                )));
            }
            rounds += 1;
            let serving = status
                .iter()
                .filter(|st| st.health() != ChipHealth::Failed)
                .count();
            let retried = !wants_retry || metrics.retries.get() >= 1;
            let healed = !wants_hard
                || (metrics.quarantines.get() >= 1
                    && metrics.degraded_batches.get() >= 1
                    && serving >= status.len().min(2));
            if retried && healed {
                break;
            }
            if std::time::Instant::now() >= deadline {
                return Err(Error::msg(format!(
                    "chaos smoke did not converge (retried={retried} \
                     healed={healed} serving={serving}): {}",
                    metrics.summary()
                )));
            }
        }
        if let Some(smp) = smp {
            smp.stop();
        }
        Ok(())
    })?;
    if metrics.rejected.get() != 0 {
        return Err(Error::msg(format!(
            "chaos smoke rejected requests: {}",
            metrics.summary()
        )));
    }
    if metrics.completed.get() != metrics.submitted.get() {
        return Err(Error::msg(format!(
            "chaos smoke lost requests: {}",
            metrics.summary()
        )));
    }
    // when tracing, the fault span families must actually be in the ring
    if let Some(rec) = trace::global() {
        let snap = rec.snapshot();
        let mut want: Vec<&str> = Vec::new();
        if wants_retry {
            want.push("retry");
        }
        if wants_hard {
            want.extend(["quarantine", "restore", "degraded"]);
        }
        for name in want {
            if !snap.iter().any(|e| e.name == name) {
                return Err(Error::msg(format!(
                    "chaos smoke trace is missing the `{name}` span family"
                )));
            }
        }
    }
    println!("chaos smoke: converged after {rounds} rounds");
    obs::report(&metrics, &[], args.has("json"));
    drop(coord);
    drop(status);
    drop(recal_rxs);
    Ok(())
}

/// Read one `/metrics` scrape back from our own endpoint.
fn self_scrape(addr: std::net::SocketAddr) -> Result<String> {
    use std::io::{Read, Write};
    let mut conn = std::net::TcpStream::connect(addr)
        .map_err(|e| Error::msg(format!("connect {addr}: {e}")))?;
    conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n")
        .map_err(|e| Error::msg(format!("scrape write: {e}")))?;
    let mut resp = String::new();
    conn.read_to_string(&mut resp)
        .map_err(|e| Error::msg(format!("scrape read: {e}")))?;
    Ok(resp)
}

/// Shard a wide synthetic model's circulant block-rows across a small
/// partition so the smoke trace also carries farm `shard_pass` spans.
fn smoke_partitioned(chips_n: usize) -> Result<()> {
    // both circ layers carry 4 block-rows, so every width here shards
    // them evenly
    let part_n = if chips_n >= 4 {
        4
    } else if chips_n >= 2 {
        2
    } else {
        1
    };
    let manifest = Manifest::parse(
        r#"{
          "dataset": "synth_smoke_farm", "classes": 16,
          "layers": [
            {"kind": "conv", "cin": 1, "cout": 16, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0},
            {"kind": "fc", "cin": 4096, "cout": 16, "k": 3, "pool": 2,
             "arch": "circ", "l": 4, "act_scale": 4.0}
          ]}"#,
    )?;
    let mut bundle = Bundle::default();
    let mut rng = Rng::new(0x51_0C);
    let mut w0 = vec![0.0f32; 4 * 3 * 4];
    rng.fill_uniform(&mut w0);
    for v in w0.iter_mut() {
        *v = (*v - 0.5) * 0.5;
    }
    bundle.insert_f32("layer0.w", &[4, 3, 4], w0);
    bundle.insert_f32("layer0.b", &[16], vec![0.0; 16]);
    let mut w4 = vec![0.0f32; 4 * 1024 * 4];
    rng.fill_uniform(&mut w4);
    for v in w4.iter_mut() {
        *v = (*v - 0.5) * 0.1;
    }
    bundle.insert_f32("layer4.w", &[4, 1024, 4], w4);
    bundle.insert_f32("layer4.b", &[16], vec![0.1; 16]);
    let mut engine = Engine::from_parts(manifest, &bundle)?;
    // one fixed-rate compute lane per chip (see benches/serving.rs §farm)
    engine.threads = 1;
    let engine = Arc::new(engine);
    let plan = PartitionPlan::plan(&engine.manifest, part_n);
    let part = PartitionedEngine::new(Arc::clone(&engine), plan)?;
    let mut chips: Vec<Backend> = (0..part_n)
        .map(|_| {
            Backend::PhotonicSim(ChipSim::deterministic(
                ChipDescription::ideal(4),
            ))
        })
        .collect();
    let mut irng = Rng::new(0x51_0D);
    let imgs: Vec<Tensor> = (0..8)
        .map(|_| {
            let mut d = vec![0.0f32; 32 * 32];
            irng.fill_uniform(&mut d);
            Tensor::new(&[1, 32, 32], d)
        })
        .collect();
    let out = part.forward_batch(&imgs, &mut chips)?;
    println!(
        "partitioned smoke: {part_n}-chip shard pass over {} images OK",
        out.len()
    );
    Ok(())
}

fn mvm(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let size = args.usize_or("size", 48);
    let (p, q, l, b) = (size / 4, size / 4, 4usize, 16usize);
    let mut rng = Rng::new(1);
    let mut w = vec![0.0f32; p * q * l];
    rng.fill_uniform(&mut w);
    let bcm = Bcm::new(p, q, l, w.clone());
    let mut x = vec![0.0f32; size * b];
    rng.fill_uniform(&mut x);
    let xt = Tensor::new(&[size, b], x);

    // rust photonic-sim path vs the direct compressed reference
    let chip = ChipDescription::load(&dir.join("chip.json"))
        .unwrap_or_else(|_| ChipDescription::ideal(4));
    let mut sim = ChipSim::deterministic(chip);
    let y_sim = sim.forward(&bcm, &xt);
    let y_ref = bcm.matmul(&xt);
    println!(
        "mvm {size}x{size}: sim vs digital max |Δ| = {:.2e} ({} outputs)",
        y_sim.max_abs_diff(&y_ref),
        y_ref.numel()
    );

    // XLA AOT path (if the pjrt feature is on and the artifact exists)
    #[cfg(feature = "pjrt")]
    {
        let mut rt = Runtime::new(&dir)?;
        let name = format!("crossbar_{size}x{size}_b{b}");
        match rt.load(&name) {
            Ok(exe) => {
                let wt = Tensor::new(&[p, q, l], w);
                let y_xla = exe.run(&[&wt, &xt])?;
                let diff = y_sim
                    .data
                    .iter()
                    .zip(&y_xla)
                    .fold(0.0f32, |m, (a, c)| m.max((a - c).abs()));
                println!(
                    "mvm {size}x{size}: sim vs XLA max |Δ| = {diff:.2e} \
                     ({} outputs)",
                    y_xla.len()
                );
            }
            Err(e) => println!("mvm {size}x{size}: sim OK; XLA artifact: {e:#}"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("mvm {size}x{size}: XLA path disabled (build with --features pjrt)");
    Ok(())
}

fn analyze() -> Result<()> {
    let area = AreaModel::paper();
    let power = PowerModel::paper();
    for (label, cfg, tech) in [
        ("48x48 thermo", CirPtcConfig::scaled_48(), WeightTech::ThermoOptic),
        ("48x48 r=4 thermo", CirPtcConfig::folded_48(), WeightTech::ThermoOptic),
        ("48x48 r=4 MOSCAP", CirPtcConfig::folded_48(), WeightTech::Moscap),
    ] {
        println!(
            "{label:<18} density={:.2} TOPS/mm²  efficiency={:.2} TOPS/W  \
             (vs uncompressed ×{:.2})",
            area.computing_density_tops_mm2(&cfg),
            power.efficiency_tops_w(&cfg, tech),
            power.efficiency_tops_w(&cfg, tech)
                / power.uncompressed_efficiency_tops_w(&cfg, tech),
        );
    }
    Ok(())
}
