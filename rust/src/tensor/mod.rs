//! Minimal dense tensor library for the L3 request path.
//!
//! Row-major `f32` storage with explicit shapes; implements exactly the ops
//! the StrC-ONN inference engine needs (matmul, im2col, conv-as-matmul,
//! max-pool, batch-norm, activations).  Mirrors the semantics of
//! `python/compile/kernels/ref.py` and is validated against golden files
//! exported from it.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessor helpers (row-major).
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        let w = self.shape[1];
        self.data[r * w + c] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// C = A(m,k) @ B(k,n), cache-friendly ikj loop order.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        self.matmul_par(other, 1)
    }

    /// [`Tensor::matmul`] with output rows split across scoped threads.
    ///
    /// The per-row accumulation order is unchanged (each output row is
    /// still filled by one thread with the same ikj inner loop), so the
    /// result is bit-identical to the serial path — batched and
    /// per-image engine forwards stay element-wise equal.  Small
    /// products run serially: the scoped-spawn overhead only pays off
    /// once the madd count clears a ~512k threshold (sized so per-image
    /// conv multiplies stay serial but a batch ≥ 8 goes wide).
    pub fn matmul_par(&self, other: &Tensor, threads: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim {k} vs {k2}");
        let threads = if m >= 2 && m * k * n >= (1 << 19) {
            threads.min(m)
        } else {
            1
        };
        let mut out = vec![0.0f32; m * n];
        if n > 0 {
            crate::util::threadpool::scoped_chunks(threads, &mut out, n, |i, orow| {
                let arow = &self.data[i * k..(i + 1) * k];
                for (kk, &a) in arow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            });
        }
        Tensor::new(&[m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add_scalar(&self, s: f32) -> Tensor {
        self.map(|x| x + s)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Root-mean-square error against another tensor, normalised by the
    /// other's dynamic range (the paper's Fig. 3d metric).
    pub fn normalized_rmse(&self, ideal: &Tensor) -> f32 {
        assert_eq!(self.shape, ideal.shape);
        let mse: f64 = self
            .data
            .iter()
            .zip(&ideal.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / self.numel() as f64;
        let lo = ideal.data.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = ideal.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let range = (hi - lo).max(1e-9);
        (mse.sqrt() as f32) / range
    }
}

// ---------------------------------------------------------------------------
// image ops (paper Fig. 1a pipeline)
// ---------------------------------------------------------------------------

/// im2col for a (C, H, W) image, stride 1, no padding:
/// -> (C*k*k, (H-k+1)*(W-k+1)); mirrors `ref.im2col_ref`.
pub fn im2col(img: &Tensor, k: usize) -> Tensor {
    assert_eq!(img.rank(), 3);
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    assert!(h >= k && w >= k);
    let (oh, ow) = (h - k + 1, w - k + 1);
    let rows = c * k * k;
    let cols = oh * ow;
    let mut out = vec![0.0f32; rows * cols];
    for ci in 0..c {
        for di in 0..k {
            for dj in 0..k {
                let r = ci * k * k + di * k + dj;
                for i in 0..oh {
                    let src = &img.data[ci * h * w + (i + di) * w + dj..];
                    let dst = &mut out[r * cols + i * ow..r * cols + i * ow + ow];
                    dst.copy_from_slice(&src[..ow]);
                }
            }
        }
    }
    Tensor::new(&[rows, cols], out)
}

/// Same-padding im2col: pads by k/2 with zeros (matches `lax.conv` SAME).
pub fn im2col_same(img: &Tensor, k: usize) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    let pad = k / 2;
    let mut padded = Tensor::zeros(&[c, h + 2 * pad, w + 2 * pad]);
    let (ph, pw) = (h + 2 * pad, w + 2 * pad);
    for ci in 0..c {
        for i in 0..h {
            let src = &img.data[ci * h * w + i * w..ci * h * w + (i + 1) * w];
            let off = ci * ph * pw + (i + pad) * pw + pad;
            padded.data[off..off + w].copy_from_slice(src);
        }
    }
    im2col(&padded, k)
}

/// Batched same-padding im2col: (B, C, H, W) -> (C·k·k, B·H·W), with each
/// image's patch columns contiguous (image `bi` owns columns
/// `[bi·H·W, (bi+1)·H·W)`).  This is the batch-major layout the engine
/// streams through one BCM tile per layer: every column is an independent
/// operand, so a single sign-split chip pass covers the whole batch.
pub fn im2col_same_batch(imgs: &Tensor, k: usize) -> Tensor {
    assert_eq!(imgs.rank(), 4);
    let (b, c, h, w) = (imgs.shape[0], imgs.shape[1], imgs.shape[2], imgs.shape[3]);
    let rows = c * k * k;
    let hw = h * w;
    let total = b * hw;
    let mut out = vec![0.0f32; rows * total];
    for bi in 0..b {
        let img = Tensor::new(
            &[c, h, w],
            imgs.data[bi * c * hw..(bi + 1) * c * hw].to_vec(),
        );
        let xm = im2col_same(&img, k); // (rows, hw), identical per-image math
        for r in 0..rows {
            out[r * total + bi * hw..r * total + (bi + 1) * hw]
                .copy_from_slice(&xm.data[r * hw..(r + 1) * hw]);
        }
    }
    Tensor::new(&[rows, total], out)
}

/// Convolution via im2col: img (C,H,W), weight (Cout, C*k*k) -> (Cout,OH,OW).
pub fn conv2d(img: &Tensor, wmat: &Tensor, k: usize, same: bool) -> Tensor {
    let (h, w) = (img.shape[1], img.shape[2]);
    let xm = if same { im2col_same(img, k) } else { im2col(img, k) };
    let (oh, ow) = if same { (h, w) } else { (h - k + 1, w - k + 1) };
    let y = wmat.matmul(&xm);
    let cout = wmat.shape[0];
    y.reshape(&[cout, oh, ow])
}

/// 2x2 (or pxp) max pooling on (C, H, W).
pub fn maxpool(img: &Tensor, p: usize) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    let (oh, ow) = (h / p, w / p);
    let mut out = vec![f32::NEG_INFINITY; c * oh * ow];
    for ci in 0..c {
        for i in 0..oh {
            for j in 0..ow {
                let mut m = f32::NEG_INFINITY;
                for di in 0..p {
                    for dj in 0..p {
                        m = m.max(img.data[ci * h * w + (i * p + di) * w + j * p + dj]);
                    }
                }
                out[ci * oh * ow + i * ow + j] = m;
            }
        }
    }
    Tensor::new(&[c, oh, ow], out)
}

/// Batched max pooling on (B, C, H, W): per-(image, channel) windows are
/// independent, so this is [`maxpool`] applied to each image slice.
pub fn maxpool_batch(x: &Tensor, p: usize) -> Tensor {
    assert_eq!(x.rank(), 4);
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (h / p, w / p);
    let mut out = vec![f32::NEG_INFINITY; b * c * oh * ow];
    for ci in 0..b * c {
        for i in 0..oh {
            for j in 0..ow {
                let mut m = f32::NEG_INFINITY;
                for di in 0..p {
                    for dj in 0..p {
                        m = m.max(
                            x.data[ci * h * w + (i * p + di) * w + j * p + dj],
                        );
                    }
                }
                out[ci * oh * ow + i * ow + j] = m;
            }
        }
    }
    Tensor::new(&[b, c, oh, ow], out)
}

/// Batch-norm inference transform on (C, H, W) with per-channel stats.
pub fn batchnorm(
    img: &Tensor,
    mean: &[f32],
    var: &[f32],
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    assert!(mean.len() == c && var.len() == c && gamma.len() == c && beta.len() == c);
    let mut out = img.data.clone();
    for ci in 0..c {
        let inv = 1.0 / (var[ci] + eps).sqrt();
        for v in &mut out[ci * h * w..(ci + 1) * h * w] {
            *v = (*v - mean[ci]) * inv * gamma[ci] + beta[ci];
        }
    }
    Tensor::new(&[c, h, w], out)
}

/// Batch-norm inference transform on (B, C, H, W): the per-channel affine
/// of [`batchnorm`] applied image-by-image (identical op order per image).
pub fn batchnorm_batch(
    x: &Tensor,
    mean: &[f32],
    var: &[f32],
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
) -> Tensor {
    assert_eq!(x.rank(), 4);
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert!(mean.len() == c && var.len() == c && gamma.len() == c && beta.len() == c);
    let hw = h * w;
    let mut out = x.data.clone();
    for bi in 0..b {
        for ci in 0..c {
            let inv = 1.0 / (var[ci] + eps).sqrt();
            for v in &mut out[(bi * c + ci) * hw..(bi * c + ci + 1) * hw] {
                *v = (*v - mean[ci]) * inv * gamma[ci] + beta[ci];
            }
        }
    }
    Tensor::new(&[b, c, h, w], out)
}

/// Numerically-stable softmax over the last axis of a 1-D tensor.
pub fn softmax(x: &[f32]) -> Vec<f32> {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = x.iter().map(|&v| (v - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

pub fn argmax(x: &[f32]) -> usize {
    x.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut i3 = Tensor::zeros(&[3, 3]);
        for k in 0..3 {
            i3.set2(k, k, 1.0);
        }
        assert_eq!(a.matmul(&i3).data, a.data);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        a.matmul(&b);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose2().transpose2(), a);
        assert_eq!(a.transpose2().at2(2, 1), 6.0);
    }

    #[test]
    fn im2col_counts_patches() {
        let img = Tensor::new(&[1, 4, 4], (0..16).map(|i| i as f32).collect());
        let cols = im2col(&img, 3);
        assert_eq!(cols.shape, vec![9, 4]);
        // first patch = rows 0..3 x cols 0..3
        assert_eq!(cols.at2(0, 0), 0.0);
        assert_eq!(cols.at2(8, 0), 10.0);
        // last patch starts at (1,1)
        assert_eq!(cols.at2(0, 3), 5.0);
    }

    #[test]
    fn conv_blur_flat_image() {
        let img = Tensor::full(&[1, 5, 5], 2.0);
        let wm = Tensor::full(&[1, 9], 1.0 / 9.0);
        let y = conv2d(&img, &wm, 3, false);
        assert_eq!(y.shape, vec![1, 3, 3]);
        for v in &y.data {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn conv_same_preserves_shape() {
        let img = Tensor::full(&[2, 6, 6], 1.0);
        let wm = Tensor::full(&[3, 2 * 9], 1.0);
        let y = conv2d(&img, &wm, 3, true);
        assert_eq!(y.shape, vec![3, 6, 6]);
        // interior pixels see all 18 ones
        assert!((y.data[2 * 6 + 1] - 18.0).abs() < 1e-5);
    }

    #[test]
    fn matmul_par_matches_serial() {
        // large enough to clear the parallel threshold (m*k*n >= 1<<19)
        let (m, k, n) = (64, 32, 1024);
        let a = Tensor::new(
            &[m, k],
            (0..m * k).map(|i| (i % 13) as f32 - 6.0).collect(),
        );
        let b = Tensor::new(
            &[k, n],
            (0..k * n).map(|i| (i % 7) as f32 * 0.25 - 0.75).collect(),
        );
        let serial = a.matmul(&b);
        let par = a.matmul_par(&b, 4);
        assert_eq!(serial.data, par.data, "parallel split must be bit-identical");
    }

    #[test]
    fn im2col_same_batch_matches_per_image() {
        let mk = |seed: f32| {
            Tensor::new(
                &[2, 4, 4],
                (0..32).map(|i| (i as f32 * 0.37 + seed).sin()).collect(),
            )
        };
        let (a, b) = (mk(0.0), mk(5.0));
        let mut packed = a.data.clone();
        packed.extend_from_slice(&b.data);
        let batch = Tensor::new(&[2, 2, 4, 4], packed);
        let big = im2col_same_batch(&batch, 3);
        assert_eq!(big.shape, vec![2 * 9, 32]);
        for (bi, img) in [&a, &b].iter().enumerate() {
            let xm = im2col_same(img, 3); // (18, 16)
            for r in 0..18 {
                for col in 0..16 {
                    assert_eq!(
                        big.at2(r, bi * 16 + col),
                        xm.at2(r, col),
                        "row {r} col {col} image {bi}"
                    );
                }
            }
        }
    }

    #[test]
    fn maxpool_batch_matches_per_image() {
        let img = Tensor::new(&[1, 2, 2], vec![1.0, 5.0, 3.0, 2.0]);
        let img2 = Tensor::new(&[1, 2, 2], vec![9.0, 0.0, -1.0, 4.0]);
        let mut d = img.data.clone();
        d.extend_from_slice(&img2.data);
        let y = maxpool_batch(&Tensor::new(&[2, 1, 2, 2], d), 2);
        assert_eq!(y.shape, vec![2, 1, 1, 1]);
        assert_eq!(y.data, vec![5.0, 9.0]);
    }

    #[test]
    fn batchnorm_batch_matches_per_image() {
        let img = Tensor::new(&[1, 1, 4], vec![2.0, 4.0, 6.0, 8.0]);
        let single = batchnorm(&img, &[5.0], &[5.0], &[1.5], &[0.25], 0.0);
        let mut d = img.data.clone();
        d.extend_from_slice(&img.data);
        let y = batchnorm_batch(
            &Tensor::new(&[2, 1, 1, 4], d),
            &[5.0],
            &[5.0],
            &[1.5],
            &[0.25],
            0.0,
        );
        assert_eq!(&y.data[..4], &single.data[..]);
        assert_eq!(&y.data[4..], &single.data[..]);
    }

    #[test]
    fn maxpool_reduces() {
        let img = Tensor::new(&[1, 2, 2], vec![1.0, 5.0, 3.0, 2.0]);
        let y = maxpool(&img, 2);
        assert_eq!(y.shape, vec![1, 1, 1]);
        assert_eq!(y.data[0], 5.0);
    }

    #[test]
    fn batchnorm_normalizes() {
        let img = Tensor::new(&[1, 1, 4], vec![2.0, 4.0, 6.0, 8.0]);
        let y = batchnorm(&img, &[5.0], &[5.0], &[1.0], &[0.0], 0.0);
        let s: f32 = y.data.iter().sum();
        assert!(s.abs() < 1e-5);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_stable_large_logits() {
        let p = softmax(&[1000.0, 1001.0]);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
    }

    #[test]
    fn normalized_rmse_zero_for_identical() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert!(a.normalized_rmse(&a) < 1e-9);
    }
}
