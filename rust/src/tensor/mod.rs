//! Minimal dense tensor library for the L3 request path.
//!
//! Row-major `f32` storage with explicit shapes; implements exactly the ops
//! the StrC-ONN inference engine needs (matmul, im2col, conv-as-matmul,
//! max-pool, batch-norm, activations).  Mirrors the semantics of
//! `python/compile/kernels/ref.py` and is validated against golden files
//! exported from it.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessor helpers (row-major).
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        let w = self.shape[1];
        self.data[r * w + c] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// C = A(m,k) @ B(k,n), cache-friendly ikj loop order.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        self.matmul_par(other, 1)
    }

    /// [`Tensor::matmul`] with output rows split across scoped threads.
    ///
    /// The per-row accumulation order is unchanged (each output row is
    /// still filled by one thread with the same ikj inner loop), so the
    /// result is bit-identical to the serial path — batched and
    /// per-image engine forwards stay element-wise equal.  Small
    /// products run serially: the scoped-spawn overhead only pays off
    /// once the madd count clears a ~512k threshold (sized so per-image
    /// conv multiplies stay serial but a batch ≥ 8 goes wide).
    pub fn matmul_par(&self, other: &Tensor, threads: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim {k} vs {k2}");
        let threads = if m >= 2 && m * k * n >= (1 << 19) {
            threads.min(m)
        } else {
            1
        };
        let mut out = vec![0.0f32; m * n];
        if n > 0 {
            crate::util::threadpool::scoped_chunks(threads, &mut out, n, |i, orow| {
                let arow = &self.data[i * k..(i + 1) * k];
                for (kk, &a) in arow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            });
        }
        Tensor::new(&[m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add_scalar(&self, s: f32) -> Tensor {
        self.map(|x| x + s)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Root-mean-square error against another tensor, normalised by the
    /// other's dynamic range (the paper's Fig. 3d metric).
    pub fn normalized_rmse(&self, ideal: &Tensor) -> f32 {
        assert_eq!(self.shape, ideal.shape);
        let mse: f64 = self
            .data
            .iter()
            .zip(&ideal.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / self.numel() as f64;
        let lo = ideal.data.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = ideal.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let range = (hi - lo).max(1e-9);
        (mse.sqrt() as f32) / range
    }
}

// ---------------------------------------------------------------------------
// image ops (paper Fig. 1a pipeline)
// ---------------------------------------------------------------------------

/// im2col for a (C, H, W) image, stride 1, no padding:
/// -> (C*k*k, (H-k+1)*(W-k+1)); mirrors `ref.im2col_ref`.
pub fn im2col(img: &Tensor, k: usize) -> Tensor {
    assert_eq!(img.rank(), 3);
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    assert!(h >= k && w >= k);
    let (oh, ow) = (h - k + 1, w - k + 1);
    let rows = c * k * k;
    let cols = oh * ow;
    let mut out = vec![0.0f32; rows * cols];
    for ci in 0..c {
        for di in 0..k {
            for dj in 0..k {
                let r = ci * k * k + di * k + dj;
                for i in 0..oh {
                    let src = &img.data[ci * h * w + (i + di) * w + dj..];
                    let dst = &mut out[r * cols + i * ow..r * cols + i * ow + ow];
                    dst.copy_from_slice(&src[..ow]);
                }
            }
        }
    }
    Tensor::new(&[rows, cols], out)
}

/// Same-padding im2col: pads by k/2 with zeros (matches `lax.conv` SAME).
pub fn im2col_same(img: &Tensor, k: usize) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    let pad = k / 2;
    let mut padded = Tensor::zeros(&[c, h + 2 * pad, w + 2 * pad]);
    let (ph, pw) = (h + 2 * pad, w + 2 * pad);
    for ci in 0..c {
        for i in 0..h {
            let src = &img.data[ci * h * w + i * w..ci * h * w + (i + 1) * w];
            let off = ci * ph * pw + (i + pad) * pw + pad;
            padded.data[off..off + w].copy_from_slice(src);
        }
    }
    im2col(&padded, k)
}

/// Batched same-padding im2col: (B, C, H, W) -> (C·k·k, B·H·W), with each
/// image's patch columns contiguous (image `bi` owns columns
/// `[bi·H·W, (bi+1)·H·W)`).  This is the batch-major layout the engine
/// streams through one BCM tile per layer: every column is an independent
/// operand, so a single sign-split chip pass covers the whole batch.
///
/// Hot-path form (DESIGN.md §perf): the output and the one reused padded
/// image come from the thread-local scratch arena
/// ([`crate::util::scratch`]) instead of a fresh padded copy + im2col
/// tensor per image per batch.  The gather order per image is unchanged
/// (pure copies), so values are bit-identical to the per-image
/// [`im2col_same`] for odd `k` (every model uses k=3).
pub fn im2col_same_batch(imgs: &Tensor, k: usize) -> Tensor {
    assert_eq!(imgs.rank(), 4);
    let (b, c, h, w) = (imgs.shape[0], imgs.shape[1], imgs.shape[2], imgs.shape[3]);
    let rows = c * k * k;
    let hw = h * w;
    let total = b * hw;
    let pad = k / 2;
    let (ph, pw) = (h + 2 * pad, w + 2 * pad);
    let mut out = crate::util::scratch::take(rows * total);
    // one zeroed padded image, reused across the batch: the interior is
    // fully overwritten per image, the zero margins are written once
    let mut padded = crate::util::scratch::take(c * ph * pw);
    for bi in 0..b {
        let img = &imgs.data[bi * c * hw..(bi + 1) * c * hw];
        for ci in 0..c {
            for i in 0..h {
                let src = &img[ci * hw + i * w..ci * hw + (i + 1) * w];
                let off = ci * ph * pw + (i + pad) * pw + pad;
                padded[off..off + w].copy_from_slice(src);
            }
        }
        for ci in 0..c {
            for di in 0..k {
                for dj in 0..k {
                    let r = ci * k * k + di * k + dj;
                    for i in 0..h {
                        let src = &padded
                            [ci * ph * pw + (i + di) * pw + dj..];
                        let dst = r * total + bi * hw + i * w;
                        out[dst..dst + w].copy_from_slice(&src[..w]);
                    }
                }
            }
        }
    }
    crate::util::scratch::put(padded);
    Tensor::new(&[rows, total], out)
}

/// Convolution via im2col: img (C,H,W), weight (Cout, C*k*k) -> (Cout,OH,OW).
pub fn conv2d(img: &Tensor, wmat: &Tensor, k: usize, same: bool) -> Tensor {
    let (h, w) = (img.shape[1], img.shape[2]);
    let xm = if same { im2col_same(img, k) } else { im2col(img, k) };
    let (oh, ow) = if same { (h, w) } else { (h - k + 1, w - k + 1) };
    let y = wmat.matmul(&xm);
    let cout = wmat.shape[0];
    y.reshape(&[cout, oh, ow])
}

/// 2x2 (or pxp) max pooling on (C, H, W).
pub fn maxpool(img: &Tensor, p: usize) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    let (oh, ow) = (h / p, w / p);
    let mut out = vec![f32::NEG_INFINITY; c * oh * ow];
    for ci in 0..c {
        for i in 0..oh {
            for j in 0..ow {
                let mut m = f32::NEG_INFINITY;
                for di in 0..p {
                    for dj in 0..p {
                        m = m.max(img.data[ci * h * w + (i * p + di) * w + j * p + dj]);
                    }
                }
                out[ci * oh * ow + i * ow + j] = m;
            }
        }
    }
    Tensor::new(&[c, oh, ow], out)
}

/// Batched max pooling on (B, C, H, W): per-(image, channel) windows are
/// independent, so this is [`maxpool`] applied to each image slice.
pub fn maxpool_batch(x: &Tensor, p: usize) -> Tensor {
    assert_eq!(x.rank(), 4);
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (h / p, w / p);
    let mut out = vec![f32::NEG_INFINITY; b * c * oh * ow];
    for ci in 0..b * c {
        for i in 0..oh {
            for j in 0..ow {
                let mut m = f32::NEG_INFINITY;
                for di in 0..p {
                    for dj in 0..p {
                        m = m.max(
                            x.data[ci * h * w + (i * p + di) * w + j * p + dj],
                        );
                    }
                }
                out[ci * oh * ow + i * ow + j] = m;
            }
        }
    }
    Tensor::new(&[b, c, oh, ow], out)
}

/// Batch-norm inference transform on (C, H, W) with per-channel stats.
pub fn batchnorm(
    img: &Tensor,
    mean: &[f32],
    var: &[f32],
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
) -> Tensor {
    let (c, h, w) = (img.shape[0], img.shape[1], img.shape[2]);
    assert!(mean.len() == c && var.len() == c && gamma.len() == c && beta.len() == c);
    let mut out = img.data.clone();
    for ci in 0..c {
        let inv = 1.0 / (var[ci] + eps).sqrt();
        for v in &mut out[ci * h * w..(ci + 1) * h * w] {
            *v = (*v - mean[ci]) * inv * gamma[ci] + beta[ci];
        }
    }
    Tensor::new(&[c, h, w], out)
}

/// Batch-norm inference transform on (B, C, H, W): the per-channel affine
/// of [`batchnorm`] applied image-by-image (identical op order per image).
pub fn batchnorm_batch(
    x: &Tensor,
    mean: &[f32],
    var: &[f32],
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
) -> Tensor {
    assert_eq!(x.rank(), 4);
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert!(mean.len() == c && var.len() == c && gamma.len() == c && beta.len() == c);
    let hw = h * w;
    let mut out = x.data.clone();
    for bi in 0..b {
        for ci in 0..c {
            let inv = 1.0 / (var[ci] + eps).sqrt();
            for v in &mut out[(bi * c + ci) * hw..(bi * c + ci + 1) * hw] {
                *v = (*v - mean[ci]) * inv * gamma[ci] + beta[ci];
            }
        }
    }
    Tensor::new(&[b, c, h, w], out)
}

// ---------------------------------------------------------------------------
// backward kernels (train path; see DESIGN.md §train)
// ---------------------------------------------------------------------------

/// Adjoint of [`im2col_same_batch`]: scatter-add a (C·k·k, B·H·W) column
/// gradient back into the (B, C, H, W) image batch it was gathered from.
/// Patch positions that read the zero padding simply drop their gradient
/// (the padding has no parameters).
pub fn col2im_same_batch(
    cols: &Tensor,
    bsz: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
) -> Tensor {
    assert_eq!(cols.rank(), 2);
    let rows = c * k * k;
    let hw = h * w;
    let total = bsz * hw;
    assert_eq!(cols.shape[0], rows, "col2im row count");
    assert_eq!(cols.shape[1], total, "col2im column count");
    let pad = (k / 2) as isize;
    let mut out = Tensor::zeros(&[bsz, c, h, w]);
    for ci in 0..c {
        for di in 0..k {
            for dj in 0..k {
                let r = ci * k * k + di * k + dj;
                for bi in 0..bsz {
                    for i in 0..h {
                        let y = i as isize + di as isize - pad;
                        if y < 0 || y >= h as isize {
                            continue;
                        }
                        for j in 0..w {
                            let x = j as isize + dj as isize - pad;
                            if x < 0 || x >= w as isize {
                                continue;
                            }
                            out.data[((bi * c + ci) * h + y as usize) * w
                                + x as usize] +=
                                cols.data[r * total + bi * hw + i * w + j];
                        }
                    }
                }
            }
        }
    }
    out
}

/// [`maxpool_batch`] that also records the flat input index of every
/// window's maximum (first occurrence wins on ties), so the backward pass
/// can scatter gradients to exactly the winning elements.
pub fn maxpool_batch_argmax(x: &Tensor, p: usize) -> (Tensor, Vec<u32>) {
    assert_eq!(x.rank(), 4);
    assert!(x.numel() < u32::MAX as usize, "argmax indices overflow u32");
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (h / p, w / p);
    let mut out = vec![f32::NEG_INFINITY; b * c * oh * ow];
    let mut arg = vec![0u32; b * c * oh * ow];
    for ci in 0..b * c {
        for i in 0..oh {
            for j in 0..ow {
                let mut m = f32::NEG_INFINITY;
                let mut mi = 0usize;
                for di in 0..p {
                    for dj in 0..p {
                        let idx = ci * h * w + (i * p + di) * w + j * p + dj;
                        let v = x.data[idx];
                        if v > m {
                            m = v;
                            mi = idx;
                        }
                    }
                }
                out[ci * oh * ow + i * ow + j] = m;
                arg[ci * oh * ow + i * ow + j] = mi as u32;
            }
        }
    }
    (Tensor::new(&[b, c, oh, ow], out), arg)
}

/// Backward of max pooling: route each output gradient to the input
/// element that won its window (`argmax` from [`maxpool_batch_argmax`]).
pub fn maxpool_batch_backward(
    dy: &Tensor,
    argmax: &[u32],
    in_shape: &[usize],
) -> Tensor {
    assert_eq!(dy.numel(), argmax.len());
    let mut dx = Tensor::zeros(in_shape);
    for (g, &idx) in dy.data.iter().zip(argmax) {
        dx.data[idx as usize] += g;
    }
    dx
}

/// Per-channel batch statistics captured by the training-mode batch-norm
/// forward, reused by [`batchnorm_backward`].
#[derive(Clone, Debug)]
pub struct BnBatchStats {
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
    pub inv_std: Vec<f32>,
}

/// Training-mode batch-norm on (B, C, H, W): normalize with the *batch*
/// statistics (biased variance over the B·H·W elements of each channel,
/// matching `jnp.var` in `model.apply`).  Returns the output, the
/// normalized activations x̂ (cached for the backward pass) and the batch
/// statistics.
pub fn batchnorm_train(
    x: &Tensor,
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
) -> (Tensor, Tensor, BnBatchStats) {
    assert_eq!(x.rank(), 4);
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert!(gamma.len() == c && beta.len() == c);
    let hw = h * w;
    let n = (b * hw) as f64;
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    let mut inv_std = vec![0.0f32; c];
    for ci in 0..c {
        let mut s = 0.0f64;
        for bi in 0..b {
            for v in &x.data[(bi * c + ci) * hw..(bi * c + ci + 1) * hw] {
                s += *v as f64;
            }
        }
        let m = s / n;
        let mut s2 = 0.0f64;
        for bi in 0..b {
            for v in &x.data[(bi * c + ci) * hw..(bi * c + ci + 1) * hw] {
                let d = *v as f64 - m;
                s2 += d * d;
            }
        }
        mean[ci] = m as f32;
        var[ci] = (s2 / n) as f32;
        inv_std[ci] = 1.0 / (var[ci] + eps).sqrt();
    }
    let mut xhat = Tensor::zeros(&x.shape);
    let mut y = Tensor::zeros(&x.shape);
    for bi in 0..b {
        for ci in 0..c {
            let off = (bi * c + ci) * hw;
            for i in 0..hw {
                let xh = (x.data[off + i] - mean[ci]) * inv_std[ci];
                xhat.data[off + i] = xh;
                y.data[off + i] = xh * gamma[ci] + beta[ci];
            }
        }
    }
    (y, xhat, BnBatchStats { mean, var, inv_std })
}

/// Backward of [`batchnorm_train`]: returns (dx, dgamma, dbeta).
///
/// Standard batch-norm gradient with the batch statistics in the graph:
/// `dx = γ·inv_std/N · (N·dy − Σdy − x̂·Σ(dy·x̂))` per channel.
pub fn batchnorm_backward(
    dy: &Tensor,
    xhat: &Tensor,
    gamma: &[f32],
    stats: &BnBatchStats,
) -> (Tensor, Vec<f32>, Vec<f32>) {
    assert_eq!(dy.shape, xhat.shape);
    let (b, c, h, w) = (dy.shape[0], dy.shape[1], dy.shape[2], dy.shape[3]);
    let hw = h * w;
    let n = (b * hw) as f32;
    let mut dgamma = vec![0.0f32; c];
    let mut dbeta = vec![0.0f32; c];
    for ci in 0..c {
        let mut sg = 0.0f64;
        let mut sb = 0.0f64;
        for bi in 0..b {
            let off = (bi * c + ci) * hw;
            for i in 0..hw {
                sg += (dy.data[off + i] * xhat.data[off + i]) as f64;
                sb += dy.data[off + i] as f64;
            }
        }
        dgamma[ci] = sg as f32;
        dbeta[ci] = sb as f32;
    }
    let mut dx = Tensor::zeros(&dy.shape);
    for bi in 0..b {
        for ci in 0..c {
            let off = (bi * c + ci) * hw;
            let coef = gamma[ci] * stats.inv_std[ci] / n;
            for i in 0..hw {
                dx.data[off + i] = coef
                    * (n * dy.data[off + i]
                        - dbeta[ci]
                        - xhat.data[off + i] * dgamma[ci]);
            }
        }
    }
    (dx, dgamma, dbeta)
}

/// Numerically-stable softmax over the last axis of a 1-D tensor.
pub fn softmax(x: &[f32]) -> Vec<f32> {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = x.iter().map(|&v| (v - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

pub fn argmax(x: &[f32]) -> usize {
    x.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut i3 = Tensor::zeros(&[3, 3]);
        for k in 0..3 {
            i3.set2(k, k, 1.0);
        }
        assert_eq!(a.matmul(&i3).data, a.data);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        a.matmul(&b);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose2().transpose2(), a);
        assert_eq!(a.transpose2().at2(2, 1), 6.0);
    }

    #[test]
    fn im2col_counts_patches() {
        let img = Tensor::new(&[1, 4, 4], (0..16).map(|i| i as f32).collect());
        let cols = im2col(&img, 3);
        assert_eq!(cols.shape, vec![9, 4]);
        // first patch = rows 0..3 x cols 0..3
        assert_eq!(cols.at2(0, 0), 0.0);
        assert_eq!(cols.at2(8, 0), 10.0);
        // last patch starts at (1,1)
        assert_eq!(cols.at2(0, 3), 5.0);
    }

    #[test]
    fn conv_blur_flat_image() {
        let img = Tensor::full(&[1, 5, 5], 2.0);
        let wm = Tensor::full(&[1, 9], 1.0 / 9.0);
        let y = conv2d(&img, &wm, 3, false);
        assert_eq!(y.shape, vec![1, 3, 3]);
        for v in &y.data {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn conv_same_preserves_shape() {
        let img = Tensor::full(&[2, 6, 6], 1.0);
        let wm = Tensor::full(&[3, 2 * 9], 1.0);
        let y = conv2d(&img, &wm, 3, true);
        assert_eq!(y.shape, vec![3, 6, 6]);
        // interior pixels see all 18 ones
        assert!((y.data[2 * 6 + 1] - 18.0).abs() < 1e-5);
    }

    #[test]
    fn matmul_par_matches_serial() {
        // large enough to clear the parallel threshold (m*k*n >= 1<<19)
        let (m, k, n) = (64, 32, 1024);
        let a = Tensor::new(
            &[m, k],
            (0..m * k).map(|i| (i % 13) as f32 - 6.0).collect(),
        );
        let b = Tensor::new(
            &[k, n],
            (0..k * n).map(|i| (i % 7) as f32 * 0.25 - 0.75).collect(),
        );
        let serial = a.matmul(&b);
        let par = a.matmul_par(&b, 4);
        assert_eq!(serial.data, par.data, "parallel split must be bit-identical");
    }

    #[test]
    fn im2col_same_batch_matches_per_image() {
        let mk = |seed: f32| {
            Tensor::new(
                &[2, 4, 4],
                (0..32).map(|i| (i as f32 * 0.37 + seed).sin()).collect(),
            )
        };
        let (a, b) = (mk(0.0), mk(5.0));
        let mut packed = a.data.clone();
        packed.extend_from_slice(&b.data);
        let batch = Tensor::new(&[2, 2, 4, 4], packed);
        let big = im2col_same_batch(&batch, 3);
        assert_eq!(big.shape, vec![2 * 9, 32]);
        for (bi, img) in [&a, &b].iter().enumerate() {
            let xm = im2col_same(img, 3); // (18, 16)
            for r in 0..18 {
                for col in 0..16 {
                    assert_eq!(
                        big.at2(r, bi * 16 + col),
                        xm.at2(r, col),
                        "row {r} col {col} image {bi}"
                    );
                }
            }
        }
    }

    #[test]
    fn maxpool_batch_matches_per_image() {
        let img = Tensor::new(&[1, 2, 2], vec![1.0, 5.0, 3.0, 2.0]);
        let img2 = Tensor::new(&[1, 2, 2], vec![9.0, 0.0, -1.0, 4.0]);
        let mut d = img.data.clone();
        d.extend_from_slice(&img2.data);
        let y = maxpool_batch(&Tensor::new(&[2, 1, 2, 2], d), 2);
        assert_eq!(y.shape, vec![2, 1, 1, 1]);
        assert_eq!(y.data, vec![5.0, 9.0]);
    }

    #[test]
    fn batchnorm_batch_matches_per_image() {
        let img = Tensor::new(&[1, 1, 4], vec![2.0, 4.0, 6.0, 8.0]);
        let single = batchnorm(&img, &[5.0], &[5.0], &[1.5], &[0.25], 0.0);
        let mut d = img.data.clone();
        d.extend_from_slice(&img.data);
        let y = batchnorm_batch(
            &Tensor::new(&[2, 1, 1, 4], d),
            &[5.0],
            &[5.0],
            &[1.5],
            &[0.25],
            0.0,
        );
        assert_eq!(&y.data[..4], &single.data[..]);
        assert_eq!(&y.data[4..], &single.data[..]);
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), C> == <x, col2im(C)> — the defining adjoint identity
        let mut x = Tensor::zeros(&[2, 2, 5, 5]);
        let mut cmat = Tensor::zeros(&[2 * 9, 2 * 25]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 31) % 17) as f32 * 0.1 - 0.8;
        }
        for (i, v) in cmat.data.iter_mut().enumerate() {
            *v = ((i * 13) % 23) as f32 * 0.05 - 0.5;
        }
        let cols = im2col_same_batch(&x, 3);
        let lhs: f64 = cols
            .data
            .iter()
            .zip(&cmat.data)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        let back = col2im_same_batch(&cmat, 2, 2, 5, 5, 3);
        let rhs: f64 = x
            .data
            .iter()
            .zip(&back.data)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        assert!(
            (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
            "{lhs} vs {rhs}"
        );
    }

    #[test]
    fn maxpool_argmax_matches_plain_and_scatters_back() {
        // well-separated values so the argmax is unambiguous
        let mut x = Tensor::zeros(&[1, 2, 4, 4]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 7) % 32) as f32 * 0.25;
        }
        let (y, arg) = maxpool_batch_argmax(&x, 2);
        assert_eq!(y.data, maxpool_batch(&x, 2).data);
        // backward of a ones-gradient: each window's winner gets 1
        let dy = Tensor::full(&y.shape, 1.0);
        let dx = maxpool_batch_backward(&dy, &arg, &x.shape);
        assert_eq!(dx.data.iter().sum::<f32>(), y.numel() as f32);
        for (i, v) in dx.data.iter().enumerate() {
            if *v != 0.0 {
                assert!(arg.contains(&(i as u32)));
            }
        }
    }

    #[test]
    fn batchnorm_train_normalizes_batch() {
        let mut x = Tensor::zeros(&[2, 1, 2, 2]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let (y, xhat, stats) = batchnorm_train(&x, &[1.0], &[0.0], 0.0);
        assert!((stats.mean[0] - 3.5).abs() < 1e-5);
        let s: f32 = y.data.iter().sum();
        assert!(s.abs() < 1e-4, "normalized batch sums to 0, got {s}");
        let v: f32 = xhat.data.iter().map(|a| a * a).sum::<f32>() / 8.0;
        assert!((v - 1.0).abs() < 1e-4, "unit variance, got {v}");
    }

    #[test]
    fn batchnorm_backward_matches_finite_differences() {
        // per-element central differences of L = Σ y ⊙ R against the
        // analytic dx / dgamma / dbeta
        let (b, c, h, w) = (2usize, 2usize, 3usize, 3usize);
        let mut x = Tensor::zeros(&[b, c, h, w]);
        let mut r = Tensor::zeros(&[b, c, h, w]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 29) % 19) as f32 * 0.11 - 1.0;
        }
        for (i, v) in r.data.iter_mut().enumerate() {
            *v = ((i * 17) % 13) as f32 * 0.13 - 0.8;
        }
        let gamma = vec![1.2, 0.7];
        let beta = vec![0.1, -0.2];
        let eps = 1e-5;
        let loss = |xt: &Tensor, g: &[f32], bt: &[f32]| -> f64 {
            let (y, _, _) = batchnorm_train(xt, g, bt, eps);
            y.data
                .iter()
                .zip(&r.data)
                .map(|(a, c)| (*a as f64) * (*c as f64))
                .sum()
        };
        let (y, xhat, stats) = batchnorm_train(&x, &gamma, &beta, eps);
        assert_eq!(y.shape, x.shape);
        let (dx, dgamma, dbeta) = batchnorm_backward(&r, &xhat, &gamma, &stats);
        let h_ = 1e-2f32;
        let tol = |a: f32, n: f32| (a - n).abs() <= 1e-3 * a.abs().max(n.abs()).max(1.0);
        for i in 0..x.numel() {
            let mut xp = x.clone();
            xp.data[i] += h_;
            let mut xm = x.clone();
            xm.data[i] -= h_;
            let fd = ((loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta))
                / (2.0 * h_ as f64)) as f32;
            assert!(tol(dx.data[i], fd), "dx[{i}]: {} vs {fd}", dx.data[i]);
        }
        for ci in 0..c {
            let mut gp = gamma.clone();
            gp[ci] += h_;
            let mut gm = gamma.clone();
            gm[ci] -= h_;
            let fd = ((loss(&x, &gp, &beta) - loss(&x, &gm, &beta))
                / (2.0 * h_ as f64)) as f32;
            assert!(tol(dgamma[ci], fd), "dgamma[{ci}]: {} vs {fd}", dgamma[ci]);
            let mut bp = beta.clone();
            bp[ci] += h_;
            let mut bm = beta.clone();
            bm[ci] -= h_;
            let fd = ((loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm))
                / (2.0 * h_ as f64)) as f32;
            assert!(tol(dbeta[ci], fd), "dbeta[{ci}]: {} vs {fd}", dbeta[ci]);
        }
    }

    #[test]
    fn maxpool_reduces() {
        let img = Tensor::new(&[1, 2, 2], vec![1.0, 5.0, 3.0, 2.0]);
        let y = maxpool(&img, 2);
        assert_eq!(y.shape, vec![1, 1, 1]);
        assert_eq!(y.data[0], 5.0);
    }

    #[test]
    fn batchnorm_normalizes() {
        let img = Tensor::new(&[1, 1, 4], vec![2.0, 4.0, 6.0, 8.0]);
        let y = batchnorm(&img, &[5.0], &[5.0], &[1.0], &[0.0], 0.0);
        let s: f32 = y.data.iter().sum();
        assert!(s.abs() < 1e-5);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_stable_large_logits() {
        let p = softmax(&[1000.0, 1001.0]);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
    }

    #[test]
    fn normalized_rmse_zero_for_identical() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert!(a.normalized_rmse(&a) < 1e-9);
    }
}
