//! # cirptc — block-circulant photonic tensor core (StrC-ONN) reproduction
//!
//! Production-quality reproduction of *"A Hardware-Efficient Photonic
//! Tensor Core: Accelerating Deep Neural Networks with Structured
//! Compression"* (Ning et al., Optica 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serving coordinator, photonic-chip
//!   simulator, analytical benchmark models and every substrate;
//! * **L2** (`python/compile/model.py`) — the StrC-ONN in JAX, AOT-lowered
//!   to the HLO artifacts this crate loads via PJRT;
//! * **L1** (`python/compile/kernels/`) — Pallas block-circulant kernels.
//!
//! Python never runs on the request path: `make artifacts` once, then the
//! `cirptc` binary serves from `artifacts/` alone.  Since the [`train`]
//! subsystem landed, the compile side has a pure-rust path too: `make
//! train` runs the hardware-aware training loop (chip-in-the-loop forward,
//! FFT-domain circulant gradients) and writes the same manifest + CPT1
//! artifacts.  The [`drift`] subsystem keeps the serving stack calibrated
//! after deployment: on-line probe monitoring of a drifting chip and
//! zero-downtime background recalibration with engine hot swaps.  See
//! DESIGN.md for the full system inventory and the per-experiment index.
//!
//! ## Features
//!
//! The default build is **hermetic pure rust** — no external crates, no
//! native libraries, no network.  The serving stack runs on the digital
//! engine and the photonic-chip simulator ([`onn::Backend`]).
//!
//! * `pjrt` — re-enables the XLA execution path ([`runtime`]'s `Runtime`
//!   / `Executable` and `coordinator::worker::XlaBackend`).  Type-checks
//!   offline against the vendored `xla` stub; executing artifacts needs a
//!   real xla binding patched in (README §PJRT).

// Style lints that fight the numerical-kernel idiom used throughout
// (explicit index loops over multi-strided buffers, manual ceil-div).
#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]

pub mod analysis;
pub mod arch;
pub mod circulant;
pub mod coordinator;
pub mod data;
pub mod drift;
pub mod farm;
pub mod fault;
pub mod obs;
pub mod onn;
pub mod photonic;
pub mod quant;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod train;
pub mod util;
pub mod verify;
