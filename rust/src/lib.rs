//! # cirptc — block-circulant photonic tensor core (StrC-ONN) reproduction
//!
//! Production-quality reproduction of *"A Hardware-Efficient Photonic
//! Tensor Core: Accelerating Deep Neural Networks with Structured
//! Compression"* (Ning et al., Optica 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serving coordinator, photonic-chip
//!   simulator, analytical benchmark models and every substrate;
//! * **L2** (`python/compile/model.py`) — the StrC-ONN in JAX, AOT-lowered
//!   to the HLO artifacts this crate loads via PJRT;
//! * **L1** (`python/compile/kernels/`) — Pallas block-circulant kernels.
//!
//! Python never runs on the request path: `make artifacts` once, then the
//! `cirptc` binary serves from `artifacts/` alone.  See DESIGN.md for the
//! full system inventory and the per-experiment index.

pub mod analysis;
pub mod arch;
pub mod circulant;
pub mod coordinator;
pub mod data;
pub mod onn;
pub mod photonic;
pub mod quant;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod util;
