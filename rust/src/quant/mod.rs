//! DAC/ADC quantization (paper: 4-bit activation, 6-bit weight encoding).
//!
//! Mirrors `ref.quantize_ref` exactly — uniform affine quantization over a
//! closed range with round-half-to-even-free `round()` semantics matching
//! jnp.round (ties away from zero is fine here: levels are non-negative and
//! jnp.round's banker-rounding differences land below the 1e-5 tolerance
//! used in cross-validation for the bit-depths we use).

/// Uniform quantizer over [lo, hi] with 2^bits levels.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    pub bits: u32,
    pub lo: f32,
    pub hi: f32,
}

impl Quantizer {
    pub fn new(bits: u32) -> Quantizer {
        Quantizer { bits, lo: 0.0, hi: 1.0 }
    }

    pub fn with_range(bits: u32, lo: f32, hi: f32) -> Quantizer {
        assert!(hi > lo);
        Quantizer { bits, lo, hi }
    }

    pub fn levels(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Quantize one value (clips to range first).
    #[inline]
    pub fn q(&self, x: f32) -> f32 {
        if self.bits == 0 {
            return x;
        }
        let lv = self.levels() as f32;
        let t = ((x.clamp(self.lo, self.hi) - self.lo) / (self.hi - self.lo)
            * lv)
            .round();
        t / lv * (self.hi - self.lo) + self.lo
    }

    /// Integer code for a value (the DAC word actually programmed).
    pub fn code(&self, x: f32) -> u32 {
        let lv = self.levels() as f32;
        (((x.clamp(self.lo, self.hi) - self.lo) / (self.hi - self.lo)) * lv)
            .round() as u32
    }

    /// Reconstruct from an integer code.
    pub fn decode(&self, code: u32) -> f32 {
        let lv = self.levels() as f32;
        (code.min(self.levels()) as f32) / lv * (self.hi - self.lo) + self.lo
    }

    /// Straight-through-estimator gradient of [`Quantizer::q`]: identity
    /// (1.0) inside the clamp range, **zero outside [lo, hi]** — the
    /// saturated branch of the clamp has no slope, so gradients must not
    /// leak through values the DAC cannot represent.  A 0-bit quantizer
    /// is the identity and passes gradient everywhere.
    #[inline]
    pub fn ste_grad(&self, x: f32) -> f32 {
        if self.bits == 0 || (self.lo..=self.hi).contains(&x) {
            1.0
        } else {
            0.0
        }
    }

    /// Forward quantize + STE gradient factor in one call.
    pub fn q_ste(&self, x: f32) -> (f32, f32) {
        (self.q(x), self.ste_grad(x))
    }

    pub fn q_slice(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = self.q(*x);
        }
    }

    /// Worst-case quantization error (half an LSB).
    pub fn max_error(&self) -> f32 {
        0.5 * (self.hi - self.lo) / self.levels() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck;

    #[test]
    fn endpoints_exact() {
        for bits in [1, 4, 6, 8] {
            let q = Quantizer::new(bits);
            assert_eq!(q.q(0.0), 0.0);
            assert_eq!(q.q(1.0), 1.0);
        }
    }

    #[test]
    fn level_count() {
        let q = Quantizer::new(4);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..=1000 {
            seen.insert((q.q(i as f32 / 1000.0) * 1e6) as i64);
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn error_bound() {
        propcheck::check("quant error ≤ lsb/2", 200, |g| {
            let bits = *g.choose(&[2u32, 4, 6, 8]);
            let q = Quantizer::new(bits);
            let x = g.f32_in(0.0, 1.0);
            prop_assert!((q.q(x) - x).abs() <= q.max_error() + 1e-7);
            Ok(())
        });
    }

    #[test]
    fn idempotent() {
        propcheck::check("quant idempotent", 100, |g| {
            let q = Quantizer::new(6);
            let x = g.f32_in(0.0, 1.0);
            let once = q.q(x);
            prop_assert!((q.q(once) - once).abs() < 1e-7);
            Ok(())
        });
    }

    #[test]
    fn clips() {
        let q = Quantizer::new(4);
        assert_eq!(q.q(-2.0), 0.0);
        assert_eq!(q.q(5.0), 1.0);
    }

    #[test]
    fn code_roundtrip() {
        let q = Quantizer::new(6);
        for code in 0..=q.levels() {
            assert_eq!(q.code(q.decode(code)), code);
        }
    }

    #[test]
    fn custom_range() {
        let q = Quantizer::with_range(4, -1.0, 1.0);
        assert_eq!(q.q(-1.0), -1.0);
        assert_eq!(q.q(1.0), 1.0);
        assert!(q.q(0.03).abs() < q.max_error() + 0.04);
    }

    #[test]
    fn zero_bits_is_identity() {
        let q = Quantizer::new(0);
        assert_eq!(q.q(0.123456), 0.123456);
        assert_eq!(q.ste_grad(-100.0), 1.0, "0-bit quantizer passes gradient");
    }

    #[test]
    fn saturation_pins_exact_boundary_levels() {
        // the clamp runs *before* rounding: values far outside the range
        // must land exactly on the boundary codes, not on an extrapolated
        // rounded level
        let q = Quantizer::with_range(4, -1.0, 1.0);
        assert_eq!(q.q(-37.5), -1.0);
        assert_eq!(q.q(512.0), 1.0);
        assert_eq!(q.code(-37.5), 0);
        assert_eq!(q.code(512.0), q.levels());
        // one ulp past the boundary still saturates to the exact endpoint
        assert_eq!(q.q(1.0 + f32::EPSILON), 1.0);
        assert_eq!(q.q(-1.0 - f32::EPSILON), -1.0);
        let q01 = Quantizer::new(6);
        assert_eq!(q01.q(1.0000001), 1.0);
        assert_eq!(q01.q(-0.0000001), 0.0);
    }

    #[test]
    fn ste_gradient_zero_outside_range() {
        propcheck::check("ste grad mask", 200, |g| {
            let bits = *g.choose(&[2u32, 4, 6]);
            let q = Quantizer::with_range(bits, -0.5, 0.75);
            let x = g.f32_in(-2.0, 2.0);
            let (fwd, grad) = q.q_ste(x);
            if x < q.lo || x > q.hi {
                prop_assert!(grad == 0.0, "grad must be 0 outside at x={x}");
                prop_assert!(
                    fwd == q.lo || fwd == q.hi,
                    "saturated forward at x={x} gave {fwd}"
                );
            } else {
                prop_assert!(grad == 1.0, "grad must be 1 inside at x={x}");
            }
            Ok(())
        });
        // boundary values are *inside* (jnp.clip convention)
        let q = Quantizer::new(4);
        assert_eq!(q.ste_grad(0.0), 1.0);
        assert_eq!(q.ste_grad(1.0), 1.0);
    }
}
