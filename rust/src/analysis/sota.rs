//! Table S6: comparison with state-of-the-art optical and electrical
//! accelerators.  Literature numbers are transcribed from the papers the
//! table cites; CirPTC rows are *computed* by this crate's models so the
//! bench regenerates the table rather than hard-coding our own entry.

use crate::analysis::{AreaModel, PowerModel, WeightTech};
use crate::arch::CirPtcConfig;

/// One row of the comparison table.
#[derive(Clone, Debug)]
pub struct SotaEntry {
    pub name: &'static str,
    pub technology: &'static str,
    pub density_tops_mm2: Option<f64>,
    pub efficiency_tops_w: Option<f64>,
    pub notes: &'static str,
}

/// Literature rows (cited in the paper's references / Table S6).
pub fn literature() -> Vec<SotaEntry> {
    vec![
        SotaEntry {
            name: "MZI mesh ONN (Shen 2017)",
            technology: "coherent MZI mesh",
            density_tops_mm2: Some(0.04),
            efficiency_tops_w: Some(0.08),
            notes: "56-device mesh prototype; scaling limited by mesh area",
        },
        SotaEntry {
            name: "PCM crossbar PTC (Feldmann 2021)",
            technology: "PCM in-memory photonic",
            density_tops_mm2: Some(1.2),
            efficiency_tops_w: Some(0.4),
            notes: "parallel convolutional processing, nonvolatile weights",
        },
        SotaEntry {
            name: "11-TOPS conv accelerator (Xu 2021)",
            technology: "time-wavelength interleaved",
            density_tops_mm2: Some(1.0),
            efficiency_tops_w: Some(1.3),
            notes: "soliton microcomb source",
        },
        SotaEntry {
            name: "Taichi chiplet (Xu 2024)",
            technology: "diffractive-interference hybrid",
            density_tops_mm2: None,
            efficiency_tops_w: Some(160.0),
            notes: "large-scale AGI demo; efficiency includes sparsity",
        },
        SotaEntry {
            name: "Butterfly PTC (Feng 2022)",
            technology: "butterfly-mesh photonic",
            density_tops_mm2: Some(0.5),
            efficiency_tops_w: Some(1.4),
            notes: "the authors' prior compressed-ONN chip",
        },
        SotaEntry {
            name: "TPU v1 (Jouppi 2017)",
            technology: "28-nm digital ASIC",
            density_tops_mm2: Some(0.28),
            efficiency_tops_w: Some(2.3),
            notes: "92 TOPS INT8 / 331 mm² / 40 W",
        },
        SotaEntry {
            name: "A100 (INT8)",
            technology: "7-nm digital GPU",
            density_tops_mm2: Some(0.76),
            efficiency_tops_w: Some(1.56),
            notes: "624 TOPS / 826 mm² / 400 W",
        },
    ]
}

/// Computed CirPTC rows (regenerated from our models, not transcribed).
pub fn cirptc_rows() -> Vec<SotaEntry> {
    let area = AreaModel::paper();
    let power = PowerModel::paper();
    let base = CirPtcConfig::scaled_48();
    let folded = CirPtcConfig::folded_48();

    let mk = |name: &'static str,
              c: &CirPtcConfig,
              tech: WeightTech,
              notes: &'static str| SotaEntry {
        name,
        technology: "block-circulant MRR crossbar",
        density_tops_mm2: Some(area.computing_density_tops_mm2(c)),
        efficiency_tops_w: Some(power.efficiency_tops_w(c, tech)),
        notes,
    };

    vec![
        mk("CirPTC 48x48 (this work)", &base, WeightTech::ThermoOptic,
           "paper: 4.85 TOPS/mm2, 9.53 TOPS/W"),
        mk("CirPTC 48x48 r=4 folded", &folded, WeightTech::ThermoOptic,
           "paper: 5.48 TOPS/mm2, 17.13 TOPS/W"),
        mk("CirPTC 48x48 r=4 + MOSCAP", &folded, WeightTech::Moscap,
           "paper: 47.94 TOPS/W"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_nonempty_and_labeled() {
        assert!(literature().len() >= 6);
        assert_eq!(cirptc_rows().len(), 3);
    }

    #[test]
    fn cirptc_rows_are_computed_not_constant() {
        let rows = cirptc_rows();
        let base = rows[0].efficiency_tops_w.unwrap();
        let folded = rows[1].efficiency_tops_w.unwrap();
        let moscap = rows[2].efficiency_tops_w.unwrap();
        assert!(folded > base);
        assert!(moscap > folded);
    }

    #[test]
    fn cirptc_beats_mesh_onn_density() {
        let d = cirptc_rows()[0].density_tops_mm2.unwrap();
        assert!(d > 0.04 * 10.0, "orders above the 2017 MZI mesh");
    }
}
