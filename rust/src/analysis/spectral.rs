//! Spectral scalability (paper Fig. S5): the Q factor required to pack N
//! WDM channels into one FSR at a given weight resolution.
//!
//! Criterion: the summed *amplitude* leakage from the two adjacent
//! channels into a switch's passband must stay below half an LSB of the
//! weight resolution:
//!
//! ```text
//! 2 * sqrt(T(d)) / 2 = FWHM / (2d) * 2 <= 2^-(bits+1),  d = FSR / N
//! ```
//!
//! giving  Q = lambda * N * 2^(bits+1) / FSR — paper's 2.49e5 at N=48,
//! 6-bit emerges with the prototype's ~38 nm FSR.

use crate::photonic::Mrr;
pub use crate::photonic::LAMBDA_NM;

/// Required loaded Q for `n` channels at `bits` weight resolution in an
/// FSR of `fsr_nm`, at wavelength `lambda_nm`.
pub fn required_q(n: usize, bits: u32, fsr_nm: f64, lambda_nm: f64) -> f64 {
    let half_lsb = 2f64.powi(-(bits as i32 + 1));
    // FWHM/Δ = half_lsb  =>  FWHM = Δ · half_lsb
    let delta = fsr_nm / n as f64;
    lambda_nm / (delta * half_lsb)
}

/// Worst-case aggregate amplitude crosstalk for a given Q (all channels,
/// both sides, 1/k falloff of the Lorentzian amplitude wings).
pub fn aggregate_crosstalk(n: usize, q: f64, fsr_nm: f64, lambda_nm: f64) -> f64 {
    let ring = Mrr { q, lambda_nm, peak: 1.0, through_loss_db: 0.0 };
    let delta = fsr_nm / n as f64;
    (1..n)
        .map(|k| ring.drop_amplitude(k as f64 * delta))
        .sum::<f64>()
        * 2.0
}

/// Effective weight resolution (bits) achievable with Q at N channels.
pub fn achievable_bits(n: usize, q: f64, fsr_nm: f64, lambda_nm: f64) -> f64 {
    let ring = Mrr { q, lambda_nm, peak: 1.0, through_loss_db: 0.0 };
    let delta = fsr_nm / n as f64;
    // criterion: summed amplitude leakage of the two neighbours = half LSB
    let adj = 2.0 * ring.drop_amplitude(delta);
    -(adj.log2()) - 1.0
}

/// Default FSR used in the paper-scale analysis (nm).
pub const FSR_NM: f64 = 38.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_matches_paper_headline() {
        // paper Fig. S5: Q = 2.49e5 for 6-bit weights at N = 48
        let q = required_q(48, 6, FSR_NM, LAMBDA_NM);
        assert!(
            (2.0e5..3.0e5).contains(&q),
            "required Q = {q:.3e}, paper 2.49e5"
        );
    }

    #[test]
    fn q_grows_with_channels_and_bits() {
        let q48 = required_q(48, 6, FSR_NM, LAMBDA_NM);
        assert!(required_q(96, 6, FSR_NM, LAMBDA_NM) > q48);
        assert!(required_q(48, 8, FSR_NM, LAMBDA_NM) > q48);
        assert!(required_q(48, 4, FSR_NM, LAMBDA_NM) < q48);
    }

    #[test]
    fn required_q_satisfies_its_own_criterion() {
        for (n, bits) in [(16usize, 4u32), (48, 6), (64, 6)] {
            let q = required_q(n, bits, FSR_NM, LAMBDA_NM);
            let b = achievable_bits(n, q, FSR_NM, LAMBDA_NM);
            assert!(
                (b - bits as f64).abs() < 0.2,
                "n={n} bits={bits}: achievable {b}"
            );
        }
    }

    #[test]
    fn aggregate_close_to_adjacent_pair() {
        // the 1/k wing falloff means adjacent channels dominate
        let q = required_q(48, 6, FSR_NM, LAMBDA_NM);
        let total = aggregate_crosstalk(48, q, FSR_NM, LAMBDA_NM);
        let ring = Mrr { q, lambda_nm: LAMBDA_NM, peak: 1.0, through_loss_db: 0.0 };
        let adjacent = 2.0 * ring.drop_amplitude(FSR_NM / 48.0);
        assert!(total < 6.0 * adjacent);
    }

    #[test]
    fn feasible_with_reported_high_q() {
        // paper cites demonstrated Q > 2e7 — far above the 2.49e5 needed
        let q_needed = required_q(48, 6, FSR_NM, LAMBDA_NM);
        assert!(2e7 > 10.0 * q_needed);
    }
}
