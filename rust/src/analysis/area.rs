//! Chip-area model → computing density (paper: 4.85 TOPS/mm² for a 48×48
//! CirPTC @ 10 GHz; 5.48–5.84 TOPS/mm² with r=4 spectral folding).

use crate::arch::CirPtcConfig;

/// Component footprints (mm²).  PDK-representative values; the high-speed
/// carrier-depletion MZM dominates ("modulators based on the carrier
/// effect typically require larger footprints", paper Discussion).
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// traveling-wave carrier-depletion/MOSCAP MZM incl. electrodes+driver
    pub mzm_mm2: f64,
    /// MRR incl. heater and pitch allowance (25 µm pitch → 6.25e-4 mm²)
    pub mrr_mm2: f64,
    /// photodiode + pad
    pub pd_mm2: f64,
    /// routing / bus waveguide overhead multiplier
    pub routing_overhead: f64,
}

impl AreaModel {
    pub fn paper() -> AreaModel {
        AreaModel {
            mzm_mm2: 0.10,
            mrr_mm2: 6.25e-4,
            pd_mm2: 2.5e-3,
            routing_overhead: 1.40,
        }
    }

    /// Total die area (mm²) of a CirPTC instance.
    pub fn cirptc_area_mm2(&self, c: &CirPtcConfig) -> f64 {
        let mzms = c.input_mzms() as f64 * self.mzm_mm2;
        let rings =
            (c.switch_mrrs() + c.active_weight_mrrs()) as f64 * self.mrr_mm2;
        let pds = c.receivers() as f64 * self.pd_mm2;
        (mzms + rings + pds) * self.routing_overhead
    }

    /// Uncompressed MRR-crossbar ONN of the same logical size: M·N_eff
    /// *active* weight rings and no shared serial rails.
    pub fn uncompressed_area_mm2(&self, c: &CirPtcConfig) -> f64 {
        let n_eff = c.effective_n();
        let mzms = n_eff as f64 * self.mzm_mm2;
        let rings = (c.m * n_eff) as f64 * self.mrr_mm2;
        let pds = c.receivers() as f64 * self.pd_mm2;
        (mzms + rings + pds) * self.routing_overhead
    }

    /// Computing density (TOPS/mm²) — paper Discussion headline.
    pub fn computing_density_tops_mm2(&self, c: &CirPtcConfig) -> f64 {
        c.ops() / 1e12 / self.cirptc_area_mm2(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_48x48_near_paper() {
        // paper: 4.85 TOPS/mm² at 48×48, 10 GHz
        let d = AreaModel::paper()
            .computing_density_tops_mm2(&CirPtcConfig::scaled_48());
        assert!((4.0..6.0).contains(&d), "density {d}");
    }

    #[test]
    fn folding_improves_density() {
        let a = AreaModel::paper();
        let base = a.computing_density_tops_mm2(&CirPtcConfig::scaled_48());
        let folded = a.computing_density_tops_mm2(&CirPtcConfig::folded_48());
        assert!(folded > base, "folded {folded} vs {base}");
    }

    #[test]
    fn folded_cirptc_denser_than_uncompressed_same_capability() {
        // at r=1 the two arrays have comparable area (CirPTC adds serial
        // weight rails but shares the crossbar); the density win is that a
        // folded CirPTC serves an M×(rN) BCM with the same physical array,
        // where the uncompressed design must physically grow r-fold.
        let a = AreaModel::paper();
        let folded = CirPtcConfig::folded_48();
        let dens_cir = CirPtcConfig::folded_48().ops() / 1e12
            / a.cirptc_area_mm2(&folded);
        let dens_unc = folded.ops() / 1e12 / a.uncompressed_area_mm2(&folded);
        assert!(dens_cir > dens_unc, "{dens_cir} vs {dens_unc}");
    }

    #[test]
    fn area_grows_with_size() {
        let a = AreaModel::paper();
        let mut prev = 0.0;
        for s in [16usize, 32, 48, 64] {
            let c = CirPtcConfig { n: s, m: s, l: 4, fold: 1, f_op: 10e9 };
            let area = a.cirptc_area_mm2(&c);
            assert!(area > prev);
            prev = area;
        }
    }
}
