//! Power model → power efficiency (paper: 9.53 TOPS/W peak at 48×48;
//! 3.82× over the uncompressed MRR crossbar; 17.13 TOPS/W with r=4
//! folding = 6.87×; 47.94 TOPS/W with MOSCAP weight rings; laser becomes
//! dominant past ~64 — Figs. S16 & S18).

use crate::arch::CirPtcConfig;
use crate::photonic::waveguide::LossBudget;
use crate::photonic::{db_to_lin, Adc, Mzm, Photodiode, Tia};

/// Weight-programming device technology (paper Discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightTech {
    /// thermo-optic heaters: 3 mW/MRR static hold power
    ThermoOptic,
    /// depletion-mode / MOSCAP rings: "potentially eliminate static power"
    Moscap,
}

/// Per-component totals (W) for one configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct PowerBreakdown {
    pub laser_w: f64,
    pub input_mzm_w: f64,
    pub weight_mrr_w: f64,
    pub adc_w: f64,
    pub tia_w: f64,
}

impl PowerBreakdown {
    pub fn total_w(&self) -> f64 {
        self.laser_w + self.input_mzm_w + self.weight_mrr_w + self.adc_w + self.tia_w
    }

    pub fn laser_fraction(&self) -> f64 {
        self.laser_w / self.total_w()
    }
}

/// The power model with all paper-cited constants.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// thermal hold power per weight MRR (paper: 3 mW)
    pub mrr_hold_mw: f64,
    /// PD thermal-noise-equivalent current (A RMS) — laser-budget floor
    pub pd_thermal_a: f64,
    /// required linear *power* SNR at the PD for 6-bit weight fidelity
    pub required_snr: f64,
    /// wall-plug efficiency of the laser
    pub laser_wallplug: f64,
    pub losses: LossBudget,
}

impl PowerModel {
    pub fn paper() -> PowerModel {
        PowerModel {
            mrr_hold_mw: 3.0,
            pd_thermal_a: 10.0e-6,
            required_snr: 64.0, // 2^6: 6-bit amplitude fidelity at the PD
            laser_wallplug: 0.25,
            losses: LossBudget::paper(),
        }
    }

    /// Minimum laser power (W, wall-plug) for a CirPTC of config `c`:
    /// per-line received-power floor from PD sensitivity, multiplied back
    /// up the critical-path insertion loss (exponential in size, Fig. S16e)
    /// and by the number of WDM lines.
    pub fn laser_w(&self, c: &CirPtcConfig, uncompressed: bool) -> f64 {
        let pd = Photodiode::typical();
        let p_rx = pd.sensitivity_w(self.required_snr.sqrt(), self.pd_thermal_a);
        let il_db = if uncompressed {
            self.losses.uncompressed_critical_path_db(c.n, c.m)
        } else {
            self.losses.cirptc_critical_path_db(c.n, c.m, c.l)
        };
        // folding sums r× more channels per PD toward the same output-SNR
        // target, so each line carries 1/r of the receive budget (paper
        // Fig. S18: folding raises throughput without raising receiver
        // power — the laser comb widens but per-line power drops).
        let lines = (c.effective_n()).max(c.l);
        let per_line = p_rx / c.fold as f64;
        lines as f64 * per_line * db_to_lin(il_db) / self.laser_wallplug
    }

    /// Full breakdown for CirPTC (paper Fig. S16 / S18b).
    pub fn cirptc(&self, c: &CirPtcConfig, tech: WeightTech) -> PowerBreakdown {
        let mzm = Mzm::moscap();
        let hold_w = match tech {
            WeightTech::ThermoOptic => self.mrr_hold_mw * 1e-3,
            WeightTech::Moscap => 0.0,
        };
        PowerBreakdown {
            laser_w: self.laser_w(c, false),
            input_mzm_w: c.input_mzms() as f64 * mzm.encode_power_w(c.f_op),
            weight_mrr_w: c.active_weight_mrrs() as f64 * hold_w,
            adc_w: c.receivers() as f64 * Adc::paper().power_w(c.f_op),
            tia_w: c.receivers() as f64 * Tia::paper().power_w(c.f_op),
        }
    }

    /// Uncompressed MRR-crossbar baseline at the same logical size: M·N_eff
    /// active weight rings (l× more), lossier critical path.
    pub fn uncompressed(&self, c: &CirPtcConfig, tech: WeightTech) -> PowerBreakdown {
        let mzm = Mzm::moscap();
        let hold_w = match tech {
            WeightTech::ThermoOptic => self.mrr_hold_mw * 1e-3,
            WeightTech::Moscap => 0.0,
        };
        let n_eff = c.effective_n();
        PowerBreakdown {
            laser_w: self.laser_w(c, true) * c.fold as f64,
            input_mzm_w: n_eff as f64 * mzm.encode_power_w(c.f_op),
            weight_mrr_w: (c.m * n_eff) as f64 * hold_w,
            adc_w: c.receivers() as f64 * Adc::paper().power_w(c.f_op),
            tia_w: c.receivers() as f64 * Tia::paper().power_w(c.f_op),
        }
    }

    /// Power efficiency in TOPS/W.
    pub fn efficiency_tops_w(&self, c: &CirPtcConfig, tech: WeightTech) -> f64 {
        c.ops() / 1e12 / self.cirptc(c, tech).total_w()
    }

    /// Efficiency of the uncompressed baseline (denominator for the
    /// paper's 3.82× / 6.87× claims).
    pub fn uncompressed_efficiency_tops_w(
        &self,
        c: &CirPtcConfig,
        tech: WeightTech,
    ) -> f64 {
        c.ops() / 1e12 / self.uncompressed(c, tech).total_w()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(s: usize) -> CirPtcConfig {
        CirPtcConfig { n: s, m: s, l: 4, fold: 1, f_op: 10e9 }
    }

    #[test]
    fn efficiency_48_near_paper() {
        // paper: 9.53 TOPS/W peak at 48×48 (thermo-optic weights)
        let e = PowerModel::paper()
            .efficiency_tops_w(&cfg(48), WeightTech::ThermoOptic);
        assert!((6.0..13.0).contains(&e), "48x48 efficiency {e}");
    }

    #[test]
    fn efficiency_peaks_then_declines() {
        // paper Fig. S16: efficiency rises with size, peaks near 48, then
        // the exponential laser term wins and it declines
        let m = PowerModel::paper();
        let e: Vec<f64> = [8usize, 16, 32, 48, 96, 128]
            .iter()
            .map(|&s| m.efficiency_tops_w(&cfg(s), WeightTech::ThermoOptic))
            .collect();
        assert!(e[1] > e[0] && e[2] > e[1], "rising small sizes {e:?}");
        assert!(e[5] < e[3], "declining past the knee {e:?}");
    }

    #[test]
    fn cirptc_beats_uncompressed_severalfold() {
        // paper: 3.82× at 48×48
        let m = PowerModel::paper();
        let c = cfg(48);
        let ratio = m.efficiency_tops_w(&c, WeightTech::ThermoOptic)
            / m.uncompressed_efficiency_tops_w(&c, WeightTech::ThermoOptic);
        assert!((2.0..6.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn laser_fraction_grows_with_size() {
        // paper: laser is 43.14 % of total at M=N=64
        let m = PowerModel::paper();
        let f48 = m.cirptc(&cfg(48), WeightTech::ThermoOptic).laser_fraction();
        let f96 = m.cirptc(&cfg(96), WeightTech::ThermoOptic).laser_fraction();
        assert!(f96 > f48);
        let f64_ = m.cirptc(&cfg(64), WeightTech::ThermoOptic).laser_fraction();
        assert!((0.1..0.7).contains(&f64_), "laser fraction @64 = {f64_}");
    }

    #[test]
    fn folding_improves_efficiency() {
        // paper Fig. S18: 17.13 TOPS/W at r=4 (6.87× vs uncompressed)
        let m = PowerModel::paper();
        let base = m.efficiency_tops_w(
            &CirPtcConfig::scaled_48(),
            WeightTech::ThermoOptic,
        );
        let folded = m.efficiency_tops_w(
            &CirPtcConfig::folded_48(),
            WeightTech::ThermoOptic,
        );
        assert!(folded > base, "folded {folded} vs base {base}");
    }

    #[test]
    fn moscap_removes_ring_hold_power() {
        // paper: "this component of power can be potentially eliminated and
        // the power efficiency can be increased to 47.94 TOPS/W"
        let m = PowerModel::paper();
        let c = CirPtcConfig::folded_48();
        let thermo = m.cirptc(&c, WeightTech::ThermoOptic);
        let moscap = m.cirptc(&c, WeightTech::Moscap);
        assert_eq!(moscap.weight_mrr_w, 0.0);
        assert!(moscap.total_w() < thermo.total_w());
        let e = m.efficiency_tops_w(&c, WeightTech::Moscap);
        assert!(e > m.efficiency_tops_w(&c, WeightTech::ThermoOptic));
    }

    #[test]
    fn folded_weight_rings_dominate_thermo() {
        // paper Fig. S18b: with folding, MRR thermal power dominates
        let m = PowerModel::paper();
        let b = m.cirptc(&CirPtcConfig::folded_48(), WeightTech::ThermoOptic);
        assert!(b.weight_mrr_w > b.adc_w);
        assert!(b.weight_mrr_w > b.input_mzm_w);
    }
}
