//! Benchmark analysis (paper Discussion + Supplementary Notes 8, Figs.
//! S5/S14/S16/S18, Table S6): analytical area / power / latency /
//! efficiency models of CirPTC and the uncompressed MRR-crossbar baseline.
//!
//! The paper's own numbers here are *numerical analysis over cited device
//! constants*, not testbed measurements, so this module re-derives them
//! from the same constants (0.35 pJ/sym MOSCAP MZM, 3 mW/MRR thermal,
//! 39/194 mW ADC, 0.65 pJ/bit TIA, PD-sensitivity-driven laser budget).
//! Where the paper leaves a constant implicit (waveguide losses, MZM
//! footprint) we use PDK-representative values, documented on each field;
//! EXPERIMENTS.md records paper-vs-measured for every headline figure.

pub mod area;
pub mod power;
pub mod sota;
pub mod spectral;
pub mod throughput;

pub use area::AreaModel;
pub use power::{PowerBreakdown, PowerModel, WeightTech};
pub use spectral::required_q;
pub use throughput::LatencyModel;
