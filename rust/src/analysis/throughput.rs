//! Throughput & latency (paper Eq. 3 and the single-cycle-MVM constraint:
//! "the system clock period 1/f_op should be no less than the total
//! latency of the CirPTC, which increases linearly with the matrix size").

use crate::arch::CirPtcConfig;
use crate::photonic::C_M_S;

/// Optical + electrical latency of one MVM through the PIC.
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    /// group index of the silicon bus waveguides
    pub ng: f64,
    /// physical pitch between crossbar cells (µm)
    pub cell_pitch_um: f64,
    /// fixed E-O + O-E conversion latency (s)
    pub conversion_s: f64,
}

impl LatencyModel {
    pub fn paper() -> LatencyModel {
        LatencyModel { ng: 4.2, cell_pitch_um: 25.0, conversion_s: 20e-12 }
    }

    /// Critical optical path length (m): across M columns plus down N rows.
    pub fn path_m(&self, c: &CirPtcConfig) -> f64 {
        (c.m + c.n) as f64 * self.cell_pitch_um * 1e-6
    }

    /// Total single-MVM latency (s) — linear in matrix size.
    pub fn latency_s(&self, c: &CirPtcConfig) -> f64 {
        self.path_m(c) * self.ng / C_M_S + self.conversion_s
    }

    /// Maximum f_op (Hz) honouring the single-cycle constraint.
    pub fn max_f_op(&self, c: &CirPtcConfig) -> f64 {
        1.0 / self.latency_s(c)
    }

    /// True if the configured clock satisfies the latency bound.
    pub fn clock_feasible(&self, c: &CirPtcConfig) -> bool {
        c.f_op <= self.max_f_op(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_linear_in_size() {
        let l = LatencyModel::paper();
        let t = |s: usize| {
            l.latency_s(&CirPtcConfig { n: s, m: s, l: 4, fold: 1, f_op: 1e9 })
                - l.conversion_s
        };
        let (t16, t32, t64) = (t(16), t(32), t(64));
        assert!(((t32 / t16) - 2.0).abs() < 1e-6);
        assert!(((t64 / t32) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn latency_order_of_magnitude() {
        // 48+48 cells at 25 µm = 2.4 mm optical path; ~34 ps + 20 ps conv
        let l = LatencyModel::paper();
        let t = l.latency_s(&CirPtcConfig::scaled_48());
        assert!(t > 20e-12 && t < 200e-12, "latency {t}");
    }

    #[test]
    fn ten_ghz_feasible_at_48() {
        // paper quotes 10 GHz for the scaled 48×48 analysis
        let l = LatencyModel::paper();
        assert!(l.clock_feasible(&CirPtcConfig::scaled_48()));
    }

    #[test]
    fn very_large_array_limits_clock() {
        let l = LatencyModel::paper();
        let big = CirPtcConfig { n: 2048, m: 2048, l: 4, fold: 1, f_op: 10e9 };
        assert!(!l.clock_feasible(&big));
        assert!(l.max_f_op(&big) < 10e9);
    }
}
