//! Span tracing for the serving stack (DESIGN.md §obs).
//!
//! A bounded, lock-striped ring-buffer recorder with near-zero cost when
//! tracing is off: every record helper first checks one process-wide
//! relaxed [`AtomicBool`] and returns immediately when it is false — no
//! allocation, no lock, no clock read.  When tracing is on, events are
//! `Copy` structs written into per-stripe rings preallocated at install
//! time, so the record path never allocates either (enforced by the
//! `obs-record-alloc` repo_lint rule); a full ring overwrites its oldest
//! events and counts them in `dropped`.
//!
//! The span taxonomy (who records what) is tabulated in DESIGN.md §obs:
//! request lifecycle (`submit`/`shed` instants), batcher (`batch_form`),
//! worker (`infer`), pipeline lanes (`pre`/`chip`/`post` with batch seq +
//! encode generation), farm (`route`/`health` instants, `shard_pass`
//! spans), drift (`probe`/`recal_trigger`/`hot_swap` instants,
//! `recalibrate` spans) and the engine (`forward_batch`).
//!
//! Export is Chrome trace-event JSON (an array of `ph: "X"` complete
//! events and `ph: "i"` instants), loadable in `chrome://tracing` or
//! Perfetto: `cirptc serve --trace out.json`.

use std::cell::Cell;
use std::path::Path;
use std::time::Instant;

use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex, OnceLock, PoisonError};

/// Lock stripes: writers on different threads hash to different rings,
/// so concurrent recording contends only within a stripe.
const STRIPES: usize = 8;

/// Per-event argument slots; an empty-string key marks an unused slot
/// (fixed-size so [`TraceEvent`] stays `Copy` and the record path stays
/// allocation-free).
pub type SpanArgs = [(&'static str, i64); 2];

/// No arguments — both slots unused.
pub const NO_ARGS: SpanArgs = [("", 0), ("", 0)];

/// One argument, second slot unused.
pub const fn arg1(k: &'static str, v: i64) -> SpanArgs {
    [(k, v), ("", 0)]
}

/// Chrome trace-event phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// `ph: "X"` — a complete span with a duration.
    Complete,
    /// `ph: "i"` — a thread-scoped instant.
    Instant,
}

/// One recorded event.  `Copy` by construction: names and argument keys
/// are `&'static str`, so recording moves a fixed-size value into a
/// preallocated slot.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    pub cat: &'static str,
    pub ph: Phase,
    /// Microseconds since the recorder's epoch.
    pub ts_us: u64,
    /// Span duration in µs (0 for instants).
    pub dur_us: u64,
    /// Recorder-assigned thread id (stable per OS thread).
    pub tid: u64,
    pub args: SpanArgs,
}

/// One lock stripe: a fixed-capacity ring.  `buf` is reserved to the
/// stripe capacity up front; once full, `head` marks the oldest slot and
/// new events overwrite it.
struct Ring {
    buf: Vec<TraceEvent>,
    head: usize,
}

/// The bounded, lock-striped trace recorder.  Create with
/// [`TraceRecorder::new`], publish process-wide with [`install`], switch
/// recording with [`set_enabled`].
pub struct TraceRecorder {
    stripes: Vec<Mutex<Ring>>,
    per_stripe: usize,
    dropped: AtomicU64,
    epoch: Instant,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: OnceLock<Arc<TraceRecorder>> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Recorder thread id, lazily assigned (0 = unassigned).
    static TID: Cell<u64> = const { Cell::new(0) };
}

fn tid() -> u64 {
    TID.with(|c| {
        let mut t = c.get();
        if t == 0 {
            t = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            c.set(t);
        }
        t
    })
}

impl TraceRecorder {
    /// A recorder holding at most `capacity` events in total, split over
    /// the lock stripes (each stripe gets `max(capacity/STRIPES, 1)`
    /// slots, reserved up front).
    pub fn new(capacity: usize) -> Arc<TraceRecorder> {
        let per_stripe = (capacity / STRIPES).max(1);
        Arc::new(TraceRecorder {
            stripes: (0..STRIPES)
                .map(|_| {
                    Mutex::new(Ring {
                        buf: Vec::with_capacity(per_stripe),
                        head: 0,
                    })
                })
                .collect(),
            per_stripe,
            dropped: AtomicU64::new(0),
            epoch: Instant::now(),
        })
    }

    /// Total event capacity across all stripes.
    pub fn capacity(&self) -> usize {
        self.per_stripe * self.stripes.len()
    }

    /// Events overwritten because their ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Microseconds since this recorder's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one event (no allocation: the ring was reserved at
    /// construction, so `push` below capacity reuses reserved space and
    /// at capacity overwrites the oldest slot).
    fn push(&self, ev: TraceEvent) {
        let stripe = (ev.tid as usize) % self.stripes.len();
        let mut r = self.stripes[stripe]
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        if r.buf.len() < self.per_stripe {
            r.buf.push(ev);
        } else {
            let h = r.head;
            r.buf[h] = ev;
            r.head = (h + 1) % self.per_stripe;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record an instant on the calling thread, stamped now.
    pub fn record_instant(
        &self,
        name: &'static str,
        cat: &'static str,
        args: SpanArgs,
    ) {
        let ts_us = self.now_us();
        self.push(TraceEvent {
            name,
            cat,
            ph: Phase::Instant,
            ts_us,
            dur_us: 0,
            tid: tid(),
            args,
        });
    }

    /// Record a complete span on the calling thread.
    pub fn record_complete(
        &self,
        name: &'static str,
        cat: &'static str,
        ts_us: u64,
        dur_us: u64,
        args: SpanArgs,
    ) {
        self.push(TraceEvent {
            name,
            cat,
            ph: Phase::Complete,
            ts_us,
            dur_us,
            tid: tid(),
            args,
        });
    }

    /// Copy out every retained event, oldest-first per stripe, merged and
    /// sorted by timestamp.  Non-destructive: writers racing with a
    /// snapshot keep their events (they land in the rings either before
    /// the stripe lock, and are included, or after, and are retained for
    /// the next snapshot — never lost).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.capacity());
        for s in &self.stripes {
            let r = s.lock().unwrap_or_else(PoisonError::into_inner);
            if r.buf.len() < self.per_stripe {
                out.extend_from_slice(&r.buf);
            } else {
                out.extend_from_slice(&r.buf[r.head..]);
                out.extend_from_slice(&r.buf[..r.head]);
            }
        }
        out.sort_by_key(|e| e.ts_us);
        out
    }

    /// Write the retained events as a Chrome trace-event JSON array.
    pub fn write_chrome_trace(&self, path: &Path) -> Result<()> {
        let json = chrome_trace(&self.snapshot());
        std::fs::write(path, json.dump()).map_err(|e| {
            Error::msg(format!("write trace {}: {e}", path.display()))
        })
    }
}

/// Render events as a Chrome trace-event JSON array (the "JSON Array
/// Format": complete events carry `ph: "X"` + `dur`; instants carry
/// `ph: "i"` with thread scope `s: "t"`; everything runs under `pid` 1).
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|e| {
                let mut pairs = vec![
                    ("name", Json::Str(e.name.to_string())),
                    ("cat", Json::Str(e.cat.to_string())),
                    ("ts", Json::Num(e.ts_us as f64)),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(e.tid as f64)),
                ];
                match e.ph {
                    Phase::Complete => {
                        pairs.push(("ph", Json::Str("X".to_string())));
                        pairs.push(("dur", Json::Num(e.dur_us as f64)));
                    }
                    Phase::Instant => {
                        pairs.push(("ph", Json::Str("i".to_string())));
                        pairs.push(("s", Json::Str("t".to_string())));
                    }
                }
                let args: Vec<(&str, Json)> = e
                    .args
                    .iter()
                    .filter(|(k, _)| !k.is_empty())
                    .map(|(k, v)| (*k, Json::Num(*v as f64)))
                    .collect();
                pairs.push(("args", Json::obj(args)));
                Json::obj(pairs)
            })
            .collect(),
    )
}

/// Publish a recorder process-wide.  Returns false (and keeps the first)
/// if one was already installed.  Recording still requires
/// [`set_enabled`]`(true)`.
pub fn install(rec: Arc<TraceRecorder>) -> bool {
    GLOBAL.set(rec).is_ok()
}

/// Switch recording on or off.  Off is the default and costs the hot
/// paths exactly one relaxed atomic load per record call.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether recording is currently on.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The installed recorder, if any.
pub fn global() -> Option<&'static Arc<TraceRecorder>> {
    GLOBAL.get()
}

/// Opaque span-start token from [`begin`]; cheap to hold across the
/// traced section (a single `u64`, sentinel when tracing is off).
#[derive(Clone, Copy, Debug)]
pub struct SpanStart(u64);

const DISABLED_SPAN: u64 = u64::MAX;

/// Start a span: a timestamp when tracing is on, a sentinel (making the
/// matching [`end`] free) when off.
#[inline]
pub fn begin() -> SpanStart {
    if !enabled() {
        return SpanStart(DISABLED_SPAN);
    }
    match GLOBAL.get() {
        Some(r) => SpanStart(r.now_us()),
        None => SpanStart(DISABLED_SPAN),
    }
}

/// Finish a span started with [`begin`], recording a complete event.
#[inline]
pub fn end(start: SpanStart, name: &'static str, cat: &'static str, args: SpanArgs) {
    if start.0 == DISABLED_SPAN || !enabled() {
        return;
    }
    if let Some(r) = GLOBAL.get() {
        let now = r.now_us();
        r.record_complete(
            name,
            cat,
            start.0,
            now.saturating_sub(start.0).max(1),
            args,
        );
    }
}

/// Record an instant event on the calling thread.
#[inline]
pub fn instant(name: &'static str, cat: &'static str, args: SpanArgs) {
    if !enabled() {
        return;
    }
    if let Some(r) = GLOBAL.get() {
        r.record_instant(name, cat, args);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn ring_bounded_and_accounts_overwrites() {
        let rec = TraceRecorder::new(16);
        // all from one thread → one stripe of max(16/8, 1) = 2 slots
        for _ in 0..5 {
            rec.record_instant("e", "test", NO_ARGS);
        }
        let snap = rec.snapshot();
        assert_eq!(snap.len() as u64 + rec.dropped(), 5);
        assert!(snap.len() <= rec.capacity());
        for _ in 0..100 {
            rec.record_instant("e", "test", NO_ARGS);
        }
        let snap = rec.snapshot();
        assert!(snap.len() <= rec.capacity(), "ring stays bounded");
        assert_eq!(snap.len() as u64 + rec.dropped(), 105, "no event lost silently");
    }

    #[test]
    fn concurrent_writers_bounded_memory_no_lost_events() {
        let rec = TraceRecorder::new(1024);
        let writers = 8usize;
        let per_writer = 5_000u64;
        thread::scope(|s| {
            for _ in 0..writers {
                let rec = &rec;
                s.spawn(move || {
                    for i in 0..per_writer {
                        rec.record_instant("w", "stress", arg1("i", i as i64));
                        // drains racing with writers must not lose events
                        if i % 1024 == 0 {
                            let snap = rec.snapshot();
                            assert!(snap.len() <= rec.capacity());
                        }
                    }
                });
            }
        });
        let snap = rec.snapshot();
        assert!(snap.len() <= rec.capacity(), "bounded under 8 writers");
        assert_eq!(
            snap.len() as u64 + rec.dropped(),
            writers as u64 * per_writer,
            "every write is retained or counted as overwritten"
        );
    }

    #[test]
    fn chrome_trace_round_trips_through_parser() {
        let rec = TraceRecorder::new(64);
        rec.record_complete("pre", "stage", 10, 5, [("batch", 3), ("gen", 1)]);
        rec.record_instant("probe", "drift", arg1("residual_ppm", 412));
        let dump = chrome_trace(&rec.snapshot()).dump();
        let parsed = Json::parse(&dump).expect("emitted trace must parse");
        let events = parsed.as_arr().expect("top-level array");
        assert_eq!(events.len(), 2);
        let complete = events
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .expect("complete event");
        assert_eq!(complete.get("name").and_then(Json::as_str), Some("pre"));
        assert_eq!(complete.get("cat").and_then(Json::as_str), Some("stage"));
        assert_eq!(complete.get("ts").and_then(Json::as_f64), Some(10.0));
        assert_eq!(complete.get("dur").and_then(Json::as_f64), Some(5.0));
        assert_eq!(complete.get("pid").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            complete.get("args").and_then(|a| a.get("batch")).and_then(Json::as_f64),
            Some(3.0)
        );
        let inst = events
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
            .expect("instant event");
        assert_eq!(inst.get("s").and_then(Json::as_str), Some("t"));
        assert_eq!(inst.get("dur"), None, "instants carry no duration");
        assert_eq!(
            inst.get("args")
                .and_then(|a| a.get("residual_ppm"))
                .and_then(Json::as_f64),
            Some(412.0)
        );
    }

    #[test]
    fn disabled_recording_is_a_no_op_and_global_path_records() {
        // the one test that touches the process-wide recorder
        let rec = TraceRecorder::new(256);
        install(Arc::clone(&rec));
        assert!(!enabled(), "tracing starts disabled");
        instant("before_enable", "test", NO_ARGS);
        let t = begin();
        end(t, "span_before_enable", "test", NO_ARGS);
        assert!(
            !rec.snapshot().iter().any(|e| e.cat == "test"),
            "disabled helpers must not record"
        );
        set_enabled(true);
        instant("after_enable", "test", NO_ARGS);
        let t = begin();
        end(t, "span_after_enable", "test", arg1("k", 7));
        set_enabled(false);
        instant("after_disable", "test", NO_ARGS);
        let snap = rec.snapshot();
        assert!(snap.iter().any(|e| e.name == "after_enable"));
        let span = snap
            .iter()
            .find(|e| e.name == "span_after_enable")
            .expect("span recorded while enabled");
        assert!(span.dur_us >= 1, "complete spans clamp dur to ≥1µs");
        assert!(!snap.iter().any(|e| e.name == "after_disable"));
    }

    #[test]
    fn snapshot_orders_by_timestamp() {
        let rec = TraceRecorder::new(64);
        rec.record_complete("b", "t", 20, 1, NO_ARGS);
        rec.record_complete("a", "t", 5, 1, NO_ARGS);
        let snap = rec.snapshot();
        assert_eq!(snap[0].name, "a");
        assert_eq!(snap[1].name, "b");
    }
}
