//! Prometheus text exposition for [`Metrics`] plus per-chip farm health,
//! served by a minimal `std::net` `/metrics` endpoint (DESIGN.md §obs).
//!
//! [`render`] is a pure function from a metrics snapshot to the text
//! exposition format (version 0.0.4): counters as `_total`, gauges
//! verbatim, histograms with cumulative `_bucket{le=...}` lines over the
//! exact log₂ buckets [`Metrics::export`] exposes, and two per-chip
//! series (`cirptc_chip_health`, `cirptc_chip_residual_ppm`) labeled by
//! member index.  [`serve_scoped`] binds a `TcpListener` and answers
//! every connection with a fresh render on a named scoped thread, so the
//! endpoint cannot outlive the serving stack it reports on.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use crate::coordinator::Metrics;
use crate::farm::ChipStatus;
use crate::util::error::{Error, Result};
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Arc;
use crate::util::threadpool::spawn_scoped_named;

use super::trace;

/// Render the full metrics state as Prometheus text exposition.  Every
/// series carries the `cirptc_` prefix; histogram buckets are the exact
/// log₂ upper edges from [`crate::coordinator::Histogram`], cumulative
/// as the format requires, with the final open bucket as `+Inf`.
pub fn render(metrics: &Metrics, chips: &[Arc<ChipStatus>]) -> String {
    let mut out = String::with_capacity(8192);
    for (name, v) in metrics.counters() {
        out.push_str(&format!(
            "# TYPE cirptc_{name}_total counter\ncirptc_{name}_total {v}\n"
        ));
    }
    for (name, v) in metrics.gauges() {
        out.push_str(&format!(
            "# TYPE cirptc_{name} gauge\ncirptc_{name} {v}\n"
        ));
    }
    for (name, h) in metrics.histograms() {
        out.push_str(&format!("# TYPE cirptc_{name} histogram\n"));
        let buckets = h.bucket_counts();
        let mut cum = 0u64;
        for (i, b) in buckets.iter().enumerate() {
            cum += b;
            let le = if i + 1 == buckets.len() {
                "+Inf".to_string()
            } else {
                crate::coordinator::Histogram::bucket_edge(i).to_string()
            };
            out.push_str(&format!(
                "cirptc_{name}_bucket{{le=\"{le}\"}} {cum}\n"
            ));
        }
        out.push_str(&format!("cirptc_{name}_sum {}\n", h.sum()));
        out.push_str(&format!("cirptc_{name}_count {}\n", h.count()));
    }
    if !chips.is_empty() {
        out.push_str("# TYPE cirptc_chip_health gauge\n");
        for (i, st) in chips.iter().enumerate() {
            let h = st.health();
            out.push_str(&format!(
                "cirptc_chip_health{{chip=\"{i}\",state=\"{}\"}} {}\n",
                h.name(),
                h.code()
            ));
        }
        out.push_str("# TYPE cirptc_chip_residual_ppm gauge\n");
        for (i, st) in chips.iter().enumerate() {
            out.push_str(&format!(
                "cirptc_chip_residual_ppm{{chip=\"{i}\"}} {}\n",
                st.residual_ppm()
            ));
        }
    }
    out
}

/// Handle to a running `/metrics` endpoint: the bound address (for
/// `--metrics-addr 127.0.0.1:0` the OS-assigned port) and the stop flag.
pub struct MetricsEndpoint {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl MetricsEndpoint {
    /// The address actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the accept loop to exit.  The flag alone is not enough — the
    /// listener blocks in `accept` — so nudge it awake with a throwaway
    /// self-connection; the scoped spawn then joins at scope exit.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for MetricsEndpoint {
    /// Shut down on drop so an early return (`?`) inside the owning
    /// `thread::scope` can never leave the accept loop blocking the
    /// scope's implicit join.  Idempotent: after an explicit
    /// [`MetricsEndpoint::shutdown`] the extra nudge connection just
    /// fails and is ignored.
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve `/metrics` on `addr` from a named thread inside `scope`.  Every
/// connection gets a fresh [`render`] over HTTP/1.0 with
/// `Connection: close`, which is all Prometheus scrapers and `curl`
/// need.  The thread is scoped so the endpoint can borrow nothing and
/// leak nothing: it must be shut down (or the scope must end) before the
/// serving stack it samples is dropped.
pub fn serve_scoped<'scope, 'env>(
    scope: &'scope thread::Scope<'scope, 'env>,
    addr: &str,
    metrics: Arc<Metrics>,
    chips: Vec<Arc<ChipStatus>>,
) -> Result<MetricsEndpoint> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::msg(format!("bind {addr}: {e}")))?;
    let local = listener
        .local_addr()
        .map_err(|e| Error::msg(format!("local_addr: {e}")))?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    spawn_scoped_named(scope, "cirptc-metrics", move || {
        for conn in listener.incoming() {
            if stop_flag.load(Ordering::SeqCst) {
                break;
            }
            if let Ok(mut stream) = conn {
                handle(&mut stream, &metrics, &chips);
            }
        }
    });
    Ok(MetricsEndpoint { addr: local, stop })
}

/// Answer one connection.  The request head is read (and discarded — a
/// single-route endpoint needs no routing) so the peer's write never
/// fails before the response lands; a short read timeout keeps a stalled
/// scraper from wedging the accept loop.
fn handle(stream: &mut TcpStream, metrics: &Metrics, chips: &[Arc<ChipStatus>]) {
    trace::instant("scrape", "obs", trace::NO_ARGS);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut head = [0u8; 1024];
    let _ = stream.read(&mut head);
    let body = render(metrics, chips);
    let resp = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    let _ = stream.write_all(resp.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_covers_counters_gauges_histograms() {
        let m = Metrics::default();
        m.submitted.add(3);
        m.batch_compute_us.record(100);
        m.batch_compute_us.record(5000);
        let text = render(&m, &[]);
        assert!(text.contains("# TYPE cirptc_submitted_total counter"));
        assert!(text.contains("cirptc_submitted_total 3"));
        assert!(text.contains("# TYPE cirptc_queue_depth gauge"));
        assert!(text.contains("# TYPE cirptc_batch_compute_us histogram"));
        // 100 lands in bucket ⌊log₂ 100⌋ = 6 (upper edge 127); the
        // cumulative count at that edge must include it
        assert!(text.contains("cirptc_batch_compute_us_bucket{le=\"127\"} 1"));
        assert!(text.contains("cirptc_batch_compute_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("cirptc_batch_compute_us_sum 5100"));
        assert!(text.contains("cirptc_batch_compute_us_count 2"));
        assert!(
            !text.contains("cirptc_chip_health"),
            "no chip series without chips"
        );
    }

    #[test]
    fn render_labels_chip_health_and_residual() {
        let m = Metrics::default();
        let healthy = ChipStatus::new(None, i64::MAX);
        let failed = ChipStatus::new(None, i64::MAX);
        failed.fail();
        failed.set_residual_ppm(42);
        let text = render(&m, &[healthy, failed]);
        assert!(text
            .contains("cirptc_chip_health{chip=\"0\",state=\"healthy\"} 0"));
        assert!(text.contains("cirptc_chip_health{chip=\"1\",state=\"failed\"} 3"));
        assert!(text.contains("cirptc_chip_residual_ppm{chip=\"0\"} 0"));
        assert!(text.contains("cirptc_chip_residual_ppm{chip=\"1\"} 42"));
    }

    #[test]
    fn endpoint_serves_a_scrape_and_shuts_down() {
        let metrics = Arc::new(Metrics::default());
        metrics.completed.add(7);
        thread::scope(|s| {
            let ep = serve_scoped(
                s,
                "127.0.0.1:0",
                Arc::clone(&metrics),
                vec![ChipStatus::new(None, i64::MAX)],
            )
            .expect("bind ephemeral port");
            let mut conn = TcpStream::connect(ep.addr()).expect("connect");
            conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
            let mut resp = String::new();
            conn.read_to_string(&mut resp).expect("read response");
            assert!(resp.starts_with("HTTP/1.0 200 OK"), "resp: {resp}");
            assert!(resp.contains("cirptc_completed_total 7"), "resp: {resp}");
            assert!(resp.contains("cirptc_chip_health{chip=\"0\""), "{resp}");
            ep.shutdown();
        });
    }
}
