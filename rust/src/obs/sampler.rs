//! Background telemetry sampler: snapshots [`Metrics`] (full-resolution
//! [`Metrics::export`], not the summary string) plus per-chip health into
//! a JSONL stream at a fixed interval (DESIGN.md §obs).
//!
//! One line per tick: `{"t_ms": …, "metrics": {…}, "chips": [{…}]}`,
//! plus `"event": "recalibration"` on any tick where the recalibration
//! counter advanced since the last one — the drift-recal e2e test pins
//! that a forced recalibration is visible in the stream — and
//! `"fault_event": "quarantine"` on any tick where the supervisor's
//! quarantine counter advanced (a distinct key, so a tick that spans both
//! keeps both).  A final line is written on stop so short runs always
//! produce at least one sample.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::{Duration, Instant};

use crate::coordinator::{worker, Metrics};
use crate::farm::ChipStatus;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::sync::{mpsc, Arc};

/// Running sampler thread.  Dropping it (or calling [`Sampler::stop`])
/// signals the thread, which writes one last sample and exits; the
/// embedded [`worker::JoinOnDrop`] then joins, so the JSONL file is
/// complete and flushed by the time the handle is gone.
pub struct Sampler {
    stop: mpsc::SyncSender<()>,
    _handle: worker::JoinOnDrop,
}

impl Sampler {
    /// Start sampling `metrics` (and `chips`, possibly empty) every
    /// `interval` into the JSONL file at `path`.
    pub fn start(
        path: &Path,
        interval: Duration,
        metrics: Arc<Metrics>,
        chips: Vec<Arc<ChipStatus>>,
    ) -> Result<Sampler> {
        let file = File::create(path).map_err(|e| {
            Error::msg(format!("create {}: {e}", path.display()))
        })?;
        // bounded (capacity 1): the only message ever sent is the single
        // stop signal, and try_send keeps Drop non-blocking
        let (stop_tx, stop_rx) = mpsc::sync_channel::<()>(1);
        let handle = worker::spawn_named("cirptc-sampler", move || {
            run(file, interval, metrics, chips, stop_rx);
        });
        Ok(Sampler { stop: stop_tx, _handle: handle })
    }

    /// Stop the sampler and wait for the final sample to be flushed.
    pub fn stop(self) {}
}

impl Drop for Sampler {
    fn drop(&mut self) {
        // a full buffer means a stop is already signalled; either way the
        // thread exits and _handle joins it
        let _ = self.stop.try_send(());
    }
}

fn run(
    file: File,
    interval: Duration,
    metrics: Arc<Metrics>,
    chips: Vec<Arc<ChipStatus>>,
    stop_rx: mpsc::Receiver<()>,
) {
    let mut out = BufWriter::new(file);
    let epoch = Instant::now();
    let mut last_recals = metrics.recalibrations.get();
    let mut last_quarantines = metrics.quarantines.get();
    loop {
        // a stop signal (or a dropped sender) ends the loop after one
        // final sample; only a timeout means "keep sampling"
        let stop_now = !matches!(
            stop_rx.recv_timeout(interval),
            Err(mpsc::RecvTimeoutError::Timeout)
        );
        let recals = metrics.recalibrations.get();
        let mut fields = vec![
            ("t_ms", Json::Num(epoch.elapsed().as_millis() as f64)),
            ("metrics", metrics.export()),
            (
                "chips",
                Json::Arr(
                    chips
                        .iter()
                        .enumerate()
                        .map(|(i, st)| {
                            Json::obj(vec![
                                ("chip", Json::Num(i as f64)),
                                (
                                    "health",
                                    Json::Str(st.health().name().to_string()),
                                ),
                                (
                                    "residual_ppm",
                                    Json::Num(st.residual_ppm() as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if recals > last_recals {
            fields.push(("event", Json::Str("recalibration".to_string())));
            last_recals = recals;
        }
        let quarantines = metrics.quarantines.get();
        if quarantines > last_quarantines {
            fields.push(("fault_event", Json::Str("quarantine".to_string())));
            last_quarantines = quarantines;
        }
        let line = Json::obj(fields).dump();
        if writeln!(out, "{line}").is_err() {
            return; // sink gone (disk full, pipe closed): stop sampling
        }
        if stop_now {
            let _ = out.flush();
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_jsonl(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("cirptc_sampler_{tag}_{}.jsonl", std::process::id()))
    }

    #[test]
    fn sampler_writes_parseable_lines_and_final_sample() {
        let path = temp_jsonl("basic");
        let metrics = Arc::new(Metrics::default());
        metrics.submitted.add(5);
        let chips = vec![ChipStatus::new(None, i64::MAX)];
        let s = Sampler::start(
            &path,
            Duration::from_millis(5),
            Arc::clone(&metrics),
            chips,
        )
        .expect("start sampler");
        std::thread::sleep(Duration::from_millis(30));
        s.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> =
            text.lines().filter(|l| !l.is_empty()).collect();
        assert!(!lines.is_empty(), "at least the final sample must land");
        for line in &lines {
            let j = Json::parse(line).expect("every line parses");
            assert!(j.get("t_ms").and_then(Json::as_f64).is_some());
            let sub = j
                .get("metrics")
                .and_then(|m| m.get("counters"))
                .and_then(|c| c.get("submitted"))
                .and_then(Json::as_f64);
            assert_eq!(sub, Some(5.0));
            let chips = j.get("chips").and_then(Json::as_arr).unwrap();
            assert_eq!(chips.len(), 1);
            assert_eq!(
                chips[0].get("health").and_then(Json::as_str),
                Some("healthy")
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recalibration_tick_is_tagged_as_event() {
        let path = temp_jsonl("recal");
        let metrics = Arc::new(Metrics::default());
        let s = Sampler::start(
            &path,
            Duration::from_millis(5),
            Arc::clone(&metrics),
            vec![],
        )
        .expect("start sampler");
        std::thread::sleep(Duration::from_millis(15));
        metrics.recalibrations.add(1);
        std::thread::sleep(Duration::from_millis(30));
        s.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let events: Vec<Json> = text
            .lines()
            .filter(|l| !l.is_empty())
            .map(|l| Json::parse(l).unwrap())
            .collect();
        let tagged = events
            .iter()
            .filter(|j| {
                j.get("event").and_then(Json::as_str) == Some("recalibration")
            })
            .count();
        assert_eq!(
            tagged, 1,
            "exactly one tick spans the counter increment: {text}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn quarantine_tick_is_tagged_as_fault_event() {
        let path = temp_jsonl("quarantine");
        let metrics = Arc::new(Metrics::default());
        let s = Sampler::start(
            &path,
            Duration::from_millis(5),
            Arc::clone(&metrics),
            vec![],
        )
        .expect("start sampler");
        std::thread::sleep(Duration::from_millis(15));
        metrics.quarantines.add(1);
        std::thread::sleep(Duration::from_millis(30));
        s.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let tagged = text
            .lines()
            .filter(|l| !l.is_empty())
            .map(|l| Json::parse(l).unwrap())
            .filter(|j| {
                j.get("fault_event").and_then(Json::as_str)
                    == Some("quarantine")
            })
            .count();
        assert_eq!(
            tagged, 1,
            "exactly one tick spans the quarantine increment: {text}"
        );
        let _ = std::fs::remove_file(&path);
    }
}
