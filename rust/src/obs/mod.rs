//! Observability for the serving stack (DESIGN.md §obs): span tracing,
//! telemetry export, and the shared end-of-run report.
//!
//! Three pillars, each dependency-free:
//!
//! * [`trace`] — a bounded, lock-striped ring-buffer [`trace::TraceRecorder`]
//!   recording request / stage / farm / drift spans, exported as Chrome
//!   trace-event JSON (`chrome://tracing` / Perfetto).  Near-zero cost
//!   when disabled: one relaxed atomic load, no allocation.
//! * [`prom`] + [`sampler`] — the same [`Metrics::export`] snapshot
//!   rendered two ways: Prometheus text exposition on a `/metrics`
//!   TCP endpoint (pull), and a periodic JSONL stream (push).
//! * [`report`] (here) — the single end-of-run report every serving
//!   entry point emits, replacing the ad-hoc `println!("metrics: …")`
//!   sites; `--json` switches it to a machine-readable export.

pub mod prom;
pub mod sampler;
pub mod trace;

use crate::coordinator::Metrics;
use crate::util::json::Json;

/// Render the end-of-run report.  Text mode stays line-compatible with
/// the historical `metrics: <summary>` shape (extras appended as
/// `key=value`); JSON mode emits the full-resolution [`Metrics::export`]
/// plus the extras under one parseable object.
pub fn render_report(
    metrics: &Metrics,
    extra: &[(&str, f64)],
    json: bool,
) -> String {
    if json {
        let mut fields = vec![("metrics", metrics.export())];
        if !extra.is_empty() {
            fields.push((
                "extra",
                Json::obj(
                    extra.iter().map(|(k, v)| (*k, Json::Num(*v))).collect(),
                ),
            ));
        }
        Json::obj(fields).dump()
    } else {
        let mut s = format!("metrics: {}", metrics.summary());
        for (k, v) in extra {
            s.push_str(&format!(" {k}={v}"));
        }
        s
    }
}

/// Print the end-of-run report to stdout (see [`render_report`]).
pub fn report(metrics: &Metrics, extra: &[(&str, f64)], json: bool) {
    println!("{}", render_report(metrics, extra, json));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_report_is_summary_compatible() {
        let m = Metrics::default();
        m.submitted.add(2);
        let line = render_report(&m, &[], false);
        assert_eq!(line, format!("metrics: {}", m.summary()));
        let with_extra = render_report(&m, &[("rps", 123.5)], false);
        assert!(with_extra.starts_with(&line));
        assert!(with_extra.ends_with(" rps=123.5"));
    }

    #[test]
    fn json_report_parses_and_carries_extras() {
        let m = Metrics::default();
        m.completed.add(9);
        let line = render_report(&m, &[("rps", 42.0)], true);
        let j = Json::parse(&line).expect("json report parses");
        assert_eq!(
            j.get("metrics")
                .and_then(|x| x.get("counters"))
                .and_then(|c| c.get("completed"))
                .and_then(Json::as_f64),
            Some(9.0)
        );
        assert_eq!(
            j.get("extra").and_then(|e| e.get("rps")).and_then(Json::as_f64),
            Some(42.0)
        );
    }
}
