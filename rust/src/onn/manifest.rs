//! Model manifest: the JSON layer-stack description exported by
//! `python/compile/export.py::write_manifest`.

use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
    Bn,
    Relu,
    Pool,
    Flatten,
}

impl LayerKind {
    fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv" => LayerKind::Conv,
            "fc" => LayerKind::Fc,
            "bn" => LayerKind::Bn,
            "relu" => LayerKind::Relu,
            "pool" => LayerKind::Pool,
            "flatten" => LayerKind::Flatten,
            other => bail!("unknown layer kind '{other}'"),
        })
    }
}

/// One layer of the stack (mirror of python `LayerCfg`).
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub kind: LayerKind,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub pool: usize,
    /// "circ" or "gemm"
    pub arch: String,
    pub l: usize,
    pub act_scale: f32,
}

/// Parsed model manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dataset: String,
    pub classes: usize,
    pub layers: Vec<LayerSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let dataset = j
            .get("dataset")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let classes =
            j.get("classes").and_then(Json::as_usize).context("classes")?;
        let layers = j
            .get("layers")
            .and_then(Json::as_arr)
            .context("layers array")?
            .iter()
            .map(|lj| {
                let get = |k: &str| lj.get(k).and_then(Json::as_usize).unwrap_or(0);
                Ok(LayerSpec {
                    kind: LayerKind::parse(
                        lj.get("kind").and_then(Json::as_str).context("kind")?,
                    )?,
                    cin: get("cin"),
                    cout: get("cout"),
                    k: get("k"),
                    pool: get("pool").max(2),
                    arch: lj
                        .get("arch")
                        .and_then(Json::as_str)
                        .unwrap_or("circ")
                        .to_string(),
                    l: get("l").max(1),
                    act_scale: lj
                        .get("act_scale")
                        .and_then(Json::as_f64)
                        .unwrap_or(4.0) as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if layers.is_empty() {
            bail!("manifest has no layers");
        }
        Ok(Manifest { dataset, classes, layers })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    /// (channels, height) of the expected input.
    pub fn input_shape(&self) -> (usize, usize) {
        match self.dataset.as_str() {
            "synth_cxr" => (1, 64),
            _ => (3, 32),
        }
    }

    /// Trainable-parameter counts: (dense-equivalent, stored-compressed).
    pub fn param_counts(&self) -> (usize, usize) {
        let ceil_to = |x: usize, m: usize| (x + m - 1) / m * m;
        let mut dense = 0;
        let mut stored = 0;
        for l in &self.layers {
            match l.kind {
                LayerKind::Conv => {
                    let n = l.cin * l.k * l.k;
                    dense += l.cout * n;
                    stored += if l.arch == "circ" {
                        ceil_to(l.cout, l.l) / l.l * ceil_to(n, l.l)
                    } else {
                        l.cout * n
                    };
                }
                LayerKind::Fc => {
                    dense += l.cout * l.cin;
                    stored += if l.arch == "circ" {
                        ceil_to(l.cout, l.l) / l.l * ceil_to(l.cin, l.l)
                    } else {
                        l.cout * l.cin
                    };
                }
                _ => {}
            }
        }
        (dense, stored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dataset": "synth_cxr", "classes": 3,
      "layers": [
        {"kind": "conv", "cin": 1, "cout": 8, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "bn", "cin": 8, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "fc", "cin": 8192, "cout": 3, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.dataset, "synth_cxr");
        assert_eq!(m.classes, 3);
        assert_eq!(m.layers.len(), 6);
        assert_eq!(m.layers[0].kind, LayerKind::Conv);
        assert_eq!(m.layers[5].kind, LayerKind::Fc);
        assert_eq!(m.input_shape(), (1, 64));
    }

    #[test]
    fn param_counts_quarter_for_circ() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let (dense, stored) = m.param_counts();
        // conv: 8×9 dense=72, stored=2×12=24 (padding); fc: 3·8192 dense,
        // stored ceil(3,4)/4 * 8192 = 8192
        assert_eq!(dense, 72 + 3 * 8192);
        assert_eq!(stored, 24 + 8192);
        assert!((stored as f64) < 0.35 * dense as f64);
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = SAMPLE.replace("\"conv\"", "\"wizard\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_empty_layers() {
        assert!(Manifest::parse(
            r#"{"dataset": "x", "classes": 2, "layers": []}"#
        )
        .is_err());
    }
}
