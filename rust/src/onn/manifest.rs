//! Model manifest: the JSON layer-stack description exported by
//! `python/compile/export.py::write_manifest` — and, since the rust-native
//! training subsystem ([`crate::train`]) landed, written symmetrically by
//! [`Manifest::save`] so `make train` never leaves cargo.

use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
    Bn,
    Relu,
    Pool,
    Flatten,
}

impl LayerKind {
    fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv" => LayerKind::Conv,
            "fc" => LayerKind::Fc,
            "bn" => LayerKind::Bn,
            "relu" => LayerKind::Relu,
            "pool" => LayerKind::Pool,
            "flatten" => LayerKind::Flatten,
            other => bail!("unknown layer kind '{other}'"),
        })
    }

    /// The JSON tag [`LayerKind::parse`] accepts (writer ↔ parser symmetry).
    pub fn as_str(self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Fc => "fc",
            LayerKind::Bn => "bn",
            LayerKind::Relu => "relu",
            LayerKind::Pool => "pool",
            LayerKind::Flatten => "flatten",
        }
    }
}

/// One layer of the stack (mirror of python `LayerCfg`).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpec {
    pub kind: LayerKind,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub pool: usize,
    /// "circ" or "gemm"
    pub arch: String,
    pub l: usize,
    pub act_scale: f32,
}

impl LayerSpec {
    /// Flattened input width of a linear layer (conv: cin·k², fc: cin).
    pub fn n_in(&self) -> usize {
        if self.kind == LayerKind::Conv {
            self.cin * self.k * self.k
        } else {
            self.cin
        }
    }

    /// Block-circulant grid (P, Q): `cout` and [`LayerSpec::n_in`] rounded
    /// up to multiples of the block order.  The single source of the
    /// padding rule — the engine loader, the parameter accounting and the
    /// trainer's init/export must all agree on it for rust-trained
    /// weights to load.
    pub fn bcm_dims(&self) -> (usize, usize) {
        let blocks = |x: usize| (x + self.l - 1) / self.l;
        (blocks(self.cout), blocks(self.n_in()))
    }
}

/// Parsed model manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub dataset: String,
    pub classes: usize,
    pub layers: Vec<LayerSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let dataset = j
            .get("dataset")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let classes =
            j.get("classes").and_then(Json::as_usize).context("classes")?;
        let layers = j
            .get("layers")
            .and_then(Json::as_arr)
            .context("layers array")?
            .iter()
            .map(|lj| {
                let get = |k: &str| lj.get(k).and_then(Json::as_usize).unwrap_or(0);
                Ok(LayerSpec {
                    kind: LayerKind::parse(
                        lj.get("kind").and_then(Json::as_str).context("kind")?,
                    )?,
                    cin: get("cin"),
                    cout: get("cout"),
                    k: get("k"),
                    pool: get("pool").max(2),
                    arch: lj
                        .get("arch")
                        .and_then(Json::as_str)
                        .unwrap_or("circ")
                        .to_string(),
                    l: get("l").max(1),
                    act_scale: lj
                        .get("act_scale")
                        .and_then(Json::as_f64)
                        .unwrap_or(4.0) as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if layers.is_empty() {
            bail!("manifest has no layers");
        }
        Ok(Manifest { dataset, classes, layers })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    /// Serialize back to the JSON layout of `export.py::write_manifest`
    /// ([`Manifest::parse`] round-trips it; key order is stable because
    /// [`Json`] objects are BTreeMap-backed).
    pub fn to_json(&self) -> String {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("kind", Json::Str(l.kind.as_str().to_string())),
                    ("cin", Json::Num(l.cin as f64)),
                    ("cout", Json::Num(l.cout as f64)),
                    ("k", Json::Num(l.k as f64)),
                    ("pool", Json::Num(l.pool as f64)),
                    ("arch", Json::Str(l.arch.clone())),
                    ("l", Json::Num(l.l as f64)),
                    ("act_scale", Json::Num(l.act_scale as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("dataset", Json::Str(self.dataset.clone())),
            ("classes", Json::Num(self.classes as f64)),
            ("layers", Json::Arr(layers)),
        ])
        .dump()
    }

    /// Write the manifest to disk (creating parent directories), the rust
    /// half of the python↔rust interchange.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// (channels, height) of the expected input.
    pub fn input_shape(&self) -> (usize, usize) {
        match self.dataset.as_str() {
            "synth_cxr" => (1, 64),
            "synth_shapes" => (1, 16),
            _ => (3, 32),
        }
    }

    /// Trainable-parameter counts: (dense-equivalent, stored-compressed).
    pub fn param_counts(&self) -> (usize, usize) {
        let mut dense = 0;
        let mut stored = 0;
        for l in &self.layers {
            if !matches!(l.kind, LayerKind::Conv | LayerKind::Fc) {
                continue;
            }
            let n = l.n_in();
            dense += l.cout * n;
            stored += if l.arch == "circ" {
                let (p, q) = l.bcm_dims();
                p * q * l.l
            } else {
                l.cout * n
            };
        }
        (dense, stored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dataset": "synth_cxr", "classes": 3,
      "layers": [
        {"kind": "conv", "cin": 1, "cout": 8, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "bn", "cin": 8, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "relu", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "pool", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "flatten", "cin": 0, "cout": 0, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0},
        {"kind": "fc", "cin": 8192, "cout": 3, "k": 3, "pool": 2,
         "arch": "circ", "l": 4, "act_scale": 4.0}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.dataset, "synth_cxr");
        assert_eq!(m.classes, 3);
        assert_eq!(m.layers.len(), 6);
        assert_eq!(m.layers[0].kind, LayerKind::Conv);
        assert_eq!(m.layers[5].kind, LayerKind::Fc);
        assert_eq!(m.input_shape(), (1, 64));
    }

    #[test]
    fn param_counts_quarter_for_circ() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let (dense, stored) = m.param_counts();
        // conv: 8×9 dense=72, stored=2×12=24 (padding); fc: 3·8192 dense,
        // stored ceil(3,4)/4 * 8192 = 8192
        assert_eq!(dense, 72 + 3 * 8192);
        assert_eq!(stored, 24 + 8192);
        assert!((stored as f64) < 0.35 * dense as f64);
    }

    #[test]
    fn writer_roundtrips_through_parser() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let back = Manifest::parse(&m.to_json()).unwrap();
        assert_eq!(m, back, "to_json must round-trip every field");
        // act_scale survives as a float, kind tags match the parser's set
        assert!(m.to_json().contains("\"act_scale\":4"));
        assert!(m.to_json().contains("\"kind\":\"conv\""));
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = SAMPLE.replace("\"conv\"", "\"wizard\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_empty_layers() {
        assert!(Manifest::parse(
            r#"{"dataset": "x", "classes": 2, "layers": []}"#
        )
        .is_err());
    }
}
